"""Kernel-level benchmark: ACK kernels vs their pure-jnp oracles
(correctness residual) + the modeled TPU-v5e roofline occupancy per kernel
configuration from the DSE cost model (this container cannot measure TPU
wall time; the dry-run HLO terms in EXPERIMENTS.md SRoofline are the
authoritative perf numbers)."""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, record_trajectory
from repro.core.dse import TPUSpec
from repro.kernels import ref
from repro.kernels.fused_gnn import fused_gnn_layer
from repro.kernels.gat_attention import gat_attention
from repro.kernels.scatter_gather import scatter_gather_aggregate


def _roofline(flops, hbm_bytes, spec=TPUSpec()):
    t_c = flops / spec.peak_flops
    t_m = hbm_bytes / spec.hbm_bw
    return {"t_compute_us": round(t_c * 1e6, 3),
            "t_memory_us": round(t_m * 1e6, 3),
            "bound": "compute" if t_c >= t_m else "memory",
            "intensity": round(flops / hbm_bytes, 1)}


def run(quick: bool = True):
    """quick=True is the CI smoke mode: one small config per kernel, used
    as a correctness regression canary (max_err vs the jnp oracle)."""
    rows = []
    key = jax.random.PRNGKey(0)
    fused_cfgs = [(8, 64, 512, 256)] if quick else \
        [(8, 64, 512, 256), (8, 128, 512, 256), (8, 256, 512, 256)]
    for (c, n, f_in, f_out) in fused_cfgs:
        ks = jax.random.split(key, 3)
        h = jax.random.normal(ks[0], (c, n, f_in), jnp.float32)
        adj = (jax.random.uniform(ks[1], (c, n, n)) < 0.2).astype(
            jnp.float32)
        w = jax.random.normal(ks[2], (f_in, f_out)) * 0.1
        got = fused_gnn_layer(adj, h, w, None, None, None, interpret=True)
        want = ref.fused_gnn_layer_ref(adj, h, w, None, None, None)
        err = float(jnp.abs(got - want).max())
        flops = c * (2 * n * f_in * f_out + 2 * n * n * f_out)
        hbm = 4 * c * (n * f_in + n * n + n * f_out) + 4 * f_in * f_out
        rows.append({"kernel": "fused_gnn", "cfg": f"C{c} N{n} f{f_in}",
                     "max_err": f"{err:.1e}", **_roofline(flops, hbm)})
    # scatter-gather
    c, n, f, e = (4, 64, 128, 512) if quick else (8, 128, 256, 2048)
    ks = jax.random.split(key, 4)
    src = jax.random.randint(ks[0], (c, e), 0, n).astype(jnp.int32)
    dst = jax.random.randint(ks[1], (c, e), 0, n).astype(jnp.int32)
    wts = jax.random.normal(ks[2], (c, e))
    h = jax.random.normal(ks[3], (c, n, f))
    got = scatter_gather_aggregate(src, dst, wts, h, interpret=True)
    want = ref.scatter_gather_aggregate_ref(src, dst, wts, h)
    err = float(jnp.abs(got - want).max())
    flops = c * 4 * e * n * f            # one-hot routing matmuls
    hbm = 4 * c * (n * f * 2 + 3 * e)
    rows.append({"kernel": "scatter_gather", "cfg": f"C{c} N{n} E{e}",
                 "max_err": f"{err:.1e}", **_roofline(flops, hbm)})
    # gat attention
    c, n, f, heads = (4, 64, 128, 4) if quick else (8, 128, 256, 4)
    z = jax.random.normal(ks[0], (c, n, f))
    ss = jax.random.normal(ks[1], (c, n, heads))
    sd = jax.random.normal(ks[2], (c, n, heads))
    struct = (jax.random.uniform(ks[3], (c, n, n)) < 0.3).astype(
        jnp.float32) + jnp.eye(n)[None]
    got = gat_attention(z, ss, sd, struct, n_heads=heads, interpret=True)
    want = ref.gat_attention_ref(z, ss, sd, struct, n_heads=heads)
    err = float(jnp.abs(got - want).max())
    flops = c * (2 * n * n * f + 8 * n * n * heads)
    hbm = 4 * c * (2 * n * f + n * n)
    rows.append({"kernel": "gat_attention", "cfg": f"C{c} N{n} h{heads}",
                 "max_err": f"{err:.1e}", **_roofline(flops, hbm)})
    print_table(rows, ["kernel", "cfg", "max_err", "t_compute_us",
                       "t_memory_us", "bound", "intensity"])
    payload = {"rows": rows}
    # regress gate scalars: one residual per kernel (lower is better) so
    # a numerics regression in ANY kernel trips python -m repro.obs.regress
    regress: dict = {}
    for r in rows:           # worst residual per kernel (several cfgs in
        k = f"max_err_{r['kernel']}"          # the non-quick sweep)
        regress[k] = max(regress.get(k, 0.0), float(r["max_err"]))
    regress["max_err_worst"] = float(np.max(list(regress.values())))
    record_trajectory("kernels", payload, regress=regress)
    # np.max propagates NaN (python max() would drop a non-leading NaN)
    worst = float(np.max([float(r["max_err"]) for r in rows]))
    if not (worst <= 1e-2):
        raise RuntimeError(f"kernel residual regression: max_err={worst}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small configs only (CI regression canary)")
    run(quick=ap.parse_args().smoke)
