"""Sharded feature store benchmark: 1/2/4 shards under Zipf traffic.

The regime the sharded store exists for: a feature matrix LARGER than any
single shard's HBM budget. The unsharded resident store must then ship a
per-batch miss block (the paper's t_load re-paid on every cold row); the
sharded store splits the table so the UNION of shard budgets covers the
matrix and every batch stays index-only — per-shard int32 slot lists, a
reorder map, and (ideally) an empty miss block.

Per configuration the benchmark reports p50/p99 closed-loop latency,
host->device bytes per batch, the feature-byte share of it (index_only =
no dense fallback), resident hit rate, and per-shard traffic balance. A
final row re-runs the 4-shard config after ``repin()`` (online PPR-mass
rebalancing) to show the observed-mass residency beating the degree
prior. Appends ``results/BENCH_shard.json`` — a trajectory artifact.

    python benchmarks/bench_shard.py [--smoke] [--requests N] [--zipf A]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import print_table, record_trajectory
from repro.core.config import ServingConfig
from repro.core.engine import DecoupledEngine
from repro.gnn.model import GNNConfig
from repro.graphs.synthetic import get_graph, zipf_traffic
from repro.store import StorePolicy



def make_policies(shard_budget: int, nbr_capacity: int) -> dict:
    """Every config gets the SAME per-shard budget (smaller than the
    feature matrix — that is the point): 1 shard can only hold a slice,
    2/4 shards progressively cover it."""
    shard = dict(features="sharded", placement="range",
                 shard_budget_bytes=shard_budget, nbr_cache="lru",
                 nbr_capacity=nbr_capacity)
    return {
        "resident-1shard": StorePolicy(
            features="resident", hbm_budget_bytes=shard_budget,
            nbr_cache="lru", nbr_capacity=nbr_capacity),
        "sharded-1": StorePolicy(**dict(shard, num_shards=1)),
        "sharded-2": StorePolicy(**dict(shard, num_shards=2)),
        "sharded-4": StorePolicy(**dict(shard, num_shards=4)),
    }


def run_policy(name: str, policy: StorePolicy, g, cfg, params,
               batch_size: int, warm: np.ndarray, meas: np.ndarray,
               repin_between: bool = False) -> dict:
    c = batch_size
    with DecoupledEngine(g, cfg, params=params,
                         config=ServingConfig(batch_size=c,
                                              store=policy)) as eng:
        for i in range(0, len(warm), c):           # compile + cache warmup
            eng.submit_chunk(warm[i:i + c]).result()
        if repin_between:                          # online rebalance from
            eng.repin()                            # the warmup's PPR mass
        s = eng.scheduler.stats
        base = (s.bytes_shipped, s.bytes_dense, s.n_batches,
                list(s.shard_bytes))
        st = eng._fsource
        lk0 = getattr(st, "lookups", 0)
        res0 = getattr(st, "resident_lookups", 0)
        miss0 = getattr(st, "miss_rows_shipped", 0)
        lats = []
        t0 = time.perf_counter()
        for i in range(0, len(meas), c):           # one batch in flight
            tb = time.perf_counter()
            eng.submit_chunk(meas[i:i + c]).result()
            lats.append(time.perf_counter() - tb)
        wall = time.perf_counter() - t0
        shipped = s.bytes_shipped - base[0]
        dense = s.bytes_dense - base[1]
        n_batches = s.n_batches - base[2]
        shard_bytes = [b - b0 for b, b0 in
                       zip(s.shard_bytes, base[3])] if s.shard_bytes \
            else []
        lk = getattr(st, "lookups", 0) - lk0
        res = getattr(st, "resident_lookups", 0) - res0
        miss_rows = getattr(st, "miss_rows_shipped", 0) - miss0
        # feature bytes per batch = miss rows only (slot/reorder maps are
        # the index-only traffic); dense fallback would be C*N*f per batch
        feat_bytes = miss_rows * g.feature_dim * 4
        lat = np.array(lats)
        mean = (sum(shard_bytes) / len(shard_bytes)) if shard_bytes else 0
        return {"policy": name,
                "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
                "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3),
                "req_per_s": round(len(meas) / wall, 1),
                "bytes_per_batch": int(shipped / max(1, n_batches)),
                "feat_bytes_per_batch": int(feat_bytes
                                            / max(1, n_batches)),
                "index_only": bool(miss_rows == 0),
                "transfer_savings_x": round(dense / shipped, 2)
                if shipped else 0.0,
                "hit_rate": round(res / lk, 4) if lk else 1.0,
                "shard_balance": round(max(shard_bytes) / mean, 3)
                if mean else 1.0,
                "store": eng.store_report()}


def run(requests: int = 4096, batch_size: int = 16, scale: float = 0.05,
        receptive_field: int = 64, zipf_a: float = 1.1,
        nbr_capacity: int = 1024, warm_fraction: float = 0.25,
        budget_fraction: float = 0.3, seed: int = 0):
    import jax

    from repro.gnn.model import init_gnn

    g = get_graph("flickr", scale=scale, seed=seed)
    cfg = GNNConfig(kind="gcn", n_layers=2,
                    receptive_field=receptive_field, f_in=g.feature_dim)
    params = init_gnn(cfg, jax.random.PRNGKey(seed))
    targets = zipf_traffic(g, requests, zipf_a, seed + 1)
    n_warm = int(len(targets) * warm_fraction) // batch_size * batch_size
    warm, meas = targets[:n_warm], targets[n_warm:]
    matrix_bytes = g.num_vertices * g.feature_dim * 4
    # per-shard budget: a FRACTION of the matrix — no single shard can
    # hold it, 4 shards' union can (4 * 0.3 > 1)
    shard_budget = int(matrix_bytes * budget_fraction)
    print(f"graph: V={g.num_vertices} f={g.feature_dim} "
          f"(matrix {matrix_bytes >> 20} MiB) | Zipf({zipf_a}) "
          f"{requests} requests ({n_warm} warmup), C={batch_size} "
          f"N={receptive_field} | per-shard budget "
          f"{shard_budget >> 20} MiB = {budget_fraction:.0%} of matrix")

    rows = []
    policies = make_policies(shard_budget, nbr_capacity)
    for name, policy in policies.items():
        row = run_policy(name, policy, g, cfg, params, batch_size,
                         warm, meas)
        rows.append(row)
        print(f"  [{name}] p50={row['p50_ms']}ms "
              f"bytes/batch={row['bytes_per_batch']} "
              f"feat_bytes/batch={row['feat_bytes_per_batch']} "
              f"index_only={row['index_only']} "
              f"hit={row['hit_rate']} bal={row['shard_balance']}",
              flush=True)
    # online rebalancing: same 4-shard config, repin() after warmup
    row = run_policy("sharded-4+repin", policies["sharded-4"], g, cfg,
                     params, batch_size, warm, meas, repin_between=True)
    rows.append(row)
    print(f"  [sharded-4+repin] p50={row['p50_ms']}ms "
          f"feat_bytes/batch={row['feat_bytes_per_batch']} "
          f"hit={row['hit_rate']} bal={row['shard_balance']}", flush=True)

    print()
    print_table(rows, ["policy", "p50_ms", "p99_ms", "req_per_s",
                       "bytes_per_batch", "feat_bytes_per_batch",
                       "index_only", "hit_rate", "shard_balance"])
    payload = {"rows": rows, "zipf_a": zipf_a, "requests": requests,
               "batch_size": batch_size,
               "receptive_field": receptive_field,
               "num_vertices": g.num_vertices,
               "feature_dim": g.feature_dim,
               "matrix_bytes": matrix_bytes,
               "shard_budget_bytes": shard_budget}
    record_trajectory("shard", payload)
    return payload


def run_suite(quick: bool = True):
    """benchmarks.run harness entry (quick == CI smoke shape)."""
    if quick:
        return run(requests=640, batch_size=8, scale=0.004,
                   receptive_field=32, nbr_capacity=256,
                   warm_fraction=0.4)
    return run()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4096)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--zipf", type=float, default=1.1)
    ap.add_argument("--budget-fraction", type=float, default=0.3)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph + few requests (CI canary)")
    a = ap.parse_args()
    if a.smoke:
        run_suite(quick=True)
    else:
        run(requests=a.requests, batch_size=a.batch_size, zipf_a=a.zipf,
            budget_fraction=a.budget_fraction)
