"""Store-subsystem benchmark: Zipf-skewed traffic vs. cache policy.

Production mini-batch inference traffic is popularity-skewed: a small hot
set of targets absorbs most requests, and PPR neighborhoods are hub-heavy,
so the dense baseline re-runs local push and re-ships the same feature
rows thousands of times (paper Eq. 2: t_pre + t_load paid in full every
batch). This benchmark drives the same Zipf(a) request stream through one
engine per store policy and reports what the two-level store buys:

  cold      dense shipping, no neighborhood cache   (the seed baseline)
  lru       dense shipping + LRU neighborhood cache
  pinned    dense shipping + LRU + pinned top-degree hot set
  packed    cross-target dedup shipping + LRU cache
  resident  device feature store (full-resident)    + LRU cache

Popularity rank follows vertex degree (hubs are hot — the realistic and
adversarially *cacheable* regime the store targets). Latency is measured
closed-loop, one batch in flight, so p50/p99 reflect per-batch work and
not queueing. Emits ``results/BENCH_store.json`` — a trajectory artifact
appended per run (p50/p99, bytes shipped, hit rates per policy).

    python benchmarks/bench_store.py [--smoke] [--requests N] [--zipf A]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import print_table, record_trajectory
from repro.core.config import ServingConfig
from repro.core.engine import DecoupledEngine
from repro.gnn.model import GNNConfig
from repro.graphs.synthetic import get_graph, zipf_traffic
from repro.store import StorePolicy



def make_policies(nbr_capacity: int) -> dict:
    return {
        "cold": StorePolicy(),
        "lru": StorePolicy(nbr_cache="lru", nbr_capacity=nbr_capacity),
        "pinned": StorePolicy(nbr_cache="pinned",
                              nbr_capacity=nbr_capacity,
                              pinned_count=max(1, nbr_capacity // 4)),
        "packed": StorePolicy(features="packed", nbr_cache="lru",
                              nbr_capacity=nbr_capacity),
        "resident": StorePolicy(features="resident", nbr_cache="lru",
                                nbr_capacity=nbr_capacity),
    }




def run_policy(name: str, policy: StorePolicy, g, cfg, params,
               batch_size: int, warm: np.ndarray, meas: np.ndarray) -> dict:
    c = batch_size
    with DecoupledEngine(g, cfg, params=params,
                         config=ServingConfig(batch_size=c,
                                              store=policy)) as eng:
        for i in range(0, len(warm), c):           # compile + cache warmup
            eng.submit_chunk(warm[i:i + c]).result()
        s = eng.scheduler.stats
        base = (s.bytes_shipped, s.bytes_dense, s.cache_hits,
                s.cache_misses, s.n_batches)
        lats = []
        t0 = time.perf_counter()
        for i in range(0, len(meas), c):           # one batch in flight
            tb = time.perf_counter()
            eng.submit_chunk(meas[i:i + c]).result()
            lats.append(time.perf_counter() - tb)
        wall = time.perf_counter() - t0
        shipped = s.bytes_shipped - base[0]
        dense = s.bytes_dense - base[1]
        hits = s.cache_hits - base[2]
        misses = s.cache_misses - base[3]
        n_batches = s.n_batches - base[4]
        lat = np.array(lats)
        return {"policy": name,
                "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
                "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3),
                "req_per_s": round(len(meas) / wall, 1),
                "bytes_per_batch": int(shipped / max(1, n_batches)),
                "transfer_savings_x": round(dense / shipped, 2)
                if shipped else 0.0,
                "nbr_hit_rate": round(hits / (hits + misses), 4)
                if hits + misses else 0.0,
                "store": eng.store_report()}


def run(requests: int = 4096, batch_size: int = 16, scale: float = 0.05,
        receptive_field: int = 64, zipf_a: float = 1.1,
        nbr_capacity: int = 1024, warm_fraction: float = 0.25,
        seed: int = 0):
    import jax

    from repro.gnn.model import init_gnn

    g = get_graph("flickr", scale=scale, seed=seed)
    cfg = GNNConfig(kind="gcn", n_layers=2,
                    receptive_field=receptive_field, f_in=g.feature_dim)
    # one parameter set shared across policies (same model, so latency
    # differences are purely the store's doing)
    params = init_gnn(cfg, jax.random.PRNGKey(seed))
    # traffic model lives with the synthetic datasets (zipf_traffic) so
    # the benchmark, examples, and cache tests sample one distribution
    targets = zipf_traffic(g, requests, zipf_a, seed + 1)
    n_warm = int(len(targets) * warm_fraction) // batch_size * batch_size
    warm, meas = targets[:n_warm], targets[n_warm:]
    print(f"graph: V={g.num_vertices} f={g.feature_dim} | Zipf({zipf_a}) "
          f"{requests} requests ({n_warm} warmup), C={batch_size} "
          f"N={receptive_field}, nbr_capacity={nbr_capacity}")

    rows = []
    for name, policy in make_policies(nbr_capacity).items():
        row = run_policy(name, policy, g, cfg, params, batch_size,
                         warm, meas)
        rows.append(row)
        print(f"  [{name}] p50={row['p50_ms']}ms p99={row['p99_ms']}ms "
              f"bytes/batch={row['bytes_per_batch']} "
              f"savings={row['transfer_savings_x']}x "
              f"hit_rate={row['nbr_hit_rate']}", flush=True)

    print()
    print_table(rows, ["policy", "p50_ms", "p99_ms", "req_per_s",
                       "bytes_per_batch", "transfer_savings_x",
                       "nbr_hit_rate"])
    payload = {"rows": rows, "zipf_a": zipf_a, "requests": requests,
               "batch_size": batch_size,
               "receptive_field": receptive_field,
               "nbr_capacity": nbr_capacity,
               "num_vertices": g.num_vertices,
               "feature_dim": g.feature_dim}
    best = min(r["p50_ms"] for r in rows)
    record_trajectory("store", payload,
                      regress={"best_policy_p50_ms": best})
    return payload


def run_suite(quick: bool = True):
    """benchmarks.run harness entry (quick == CI smoke shape).

    The quick graph is small enough (V~180) that 640 Zipf(1.1) requests
    reach steady state — hit rate asymptotes only once the stream has
    covered the head of the popularity distribution."""
    if quick:
        return run(requests=640, batch_size=8, scale=0.002,
                   receptive_field=32, nbr_capacity=256,
                   warm_fraction=0.4)
    return run()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4096)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--zipf", type=float, default=1.1)
    ap.add_argument("--nbr-capacity", type=int, default=1024)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph + few requests (CI canary)")
    a = ap.parse_args()
    if a.smoke:
        run_suite(quick=True)
    else:
        run(requests=a.requests, batch_size=a.batch_size, zipf_a=a.zipf,
            nbr_capacity=a.nbr_capacity)
