"""Table 6 reproduction: Important Neighbor Identification overhead
(PPR local-push) in us per vertex, per dataset, single thread — plus the
8-thread batch throughput the paper's host uses."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import QUICK_SCALE, print_table, record_trajectory
from repro.core.ini import ini_batch, select_important
from repro.graphs.synthetic import get_graph


def run(quick: bool = True):
    rows = []
    for ds in ("flickr", "ogbn-arxiv", "reddit"):
        g = get_graph(ds, scale=QUICK_SCALE[ds])
        rng = np.random.default_rng(1)
        targets = rng.integers(0, g.num_vertices, size=16 if quick else 64)
        t0 = time.perf_counter()
        for t in targets:
            select_important(g, int(t), 128)
        t_single = (time.perf_counter() - t0) / len(targets)
        t0 = time.perf_counter()
        ini_batch(g, targets, 128, num_threads=8)
        t_batch = (time.perf_counter() - t0) / len(targets)
        rows.append({"dataset": ds,
                     "us_per_vertex_1thread": round(t_single * 1e6, 1),
                     "us_per_vertex_8threads": round(t_batch * 1e6, 1),
                     "vertices": g.num_vertices,
                     "avg_degree": round(float(g.degrees.mean()), 1)})
    print_table(rows, ["dataset", "us_per_vertex_1thread",
                       "us_per_vertex_8threads", "vertices", "avg_degree"])
    payload = {"rows": rows}
    record_trajectory("table6_ini", payload)
    return payload


if __name__ == "__main__":
    run(quick=False)
