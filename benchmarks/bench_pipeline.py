"""Staged host pipeline benchmark: BatchPlan stages vs the monolithic
host_fn, and the Build-skip win from the subgraph-row cache.

The host side of ``prepare()`` is now three named stages (Select ->
Build -> Pack, core/batchplan.py) that the scheduler pipelines across
consecutive batches, with the Build stage's output cached per target
(``SubgraphRowCache``). This benchmark drives Zipf traffic through four
configurations of the SAME engine:

  monolithic    the one-stage host_fn back-compat spelling (the pre-
                refactor shape: one opaque prepare() on a host pool)
  staged        the per-stage pipelined executor, no caches
  staged+nbr    + neighborhood cache (Select hits skip the PPR push)
  staged+rows   + subgraph-row cache (Build hits skip induced-subgraph
                construction entirely — the ROADMAP's Build-skip win)

Per configuration it reports closed-loop p50/p99, mean host prep time per
batch, and the per-stage wall-time breakdown (the software Fig. 3) with
nbr/build cache hit rates. Appends ``results/BENCH_pipeline.json``.

    python benchmarks/bench_pipeline.py [--smoke] [--requests N] [--zipf A]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import print_table, record_trajectory
from repro.core.config import ServingConfig
from repro.core.engine import DecoupledEngine
from repro.core.scheduler import PipelineScheduler
from repro.gnn.model import GNNConfig
from repro.graphs.synthetic import get_graph, zipf_traffic
from repro.store import StorePolicy



def make_policies(nbr_capacity: int) -> dict:
    return {
        "monolithic": StorePolicy(),
        "staged": StorePolicy(),
        "staged+nbr": StorePolicy(nbr_cache="lru",
                                  nbr_capacity=nbr_capacity,
                                  subgraph_rows="off"),
        "staged+rows": StorePolicy(nbr_cache="lru",
                                   nbr_capacity=nbr_capacity,
                                   subgraph_rows="on"),
    }


def run_policy(name: str, policy: StorePolicy, g, cfg, params,
               batch_size: int, warm: np.ndarray, meas: np.ndarray) -> dict:
    c = batch_size
    with DecoupledEngine(g, cfg, params=params,
                         config=ServingConfig(batch_size=c,
                                              store=policy)) as eng:
        if name == "monolithic":
            # the one-stage back-compat spelling: ONE opaque host_fn on a
            # depth-worker pool (the pre-refactor pipeline shape)
            eng.scheduler = PipelineScheduler(eng.prepare, eng.run_device,
                                              depth=3)
        for i in range(0, len(warm), c):           # compile + cache warmup
            eng.submit_chunk(warm[i:i + c]).result()
        s = eng.scheduler.stats
        base_host = s.t_host_total
        base_batches = s.n_batches
        base_stages = dict(s.stage_times)
        base_build = (s.build_hits, s.build_misses)
        base_nbr = (s.cache_hits, s.cache_misses)
        lats = []
        t0 = time.perf_counter()
        for i in range(0, len(meas), c):           # one batch in flight
            tb = time.perf_counter()
            eng.submit_chunk(meas[i:i + c]).result()
            lats.append(time.perf_counter() - tb)
        wall = time.perf_counter() - t0
        n_batches = s.n_batches - base_batches
        host_ms = (s.t_host_total - base_host) / max(1, n_batches) * 1e3
        stages_ms = {k: round((v - base_stages.get(k, 0.0))
                              / max(1, n_batches) * 1e3, 3)
                     for k, v in s.stage_times.items()}
        bh = s.build_hits - base_build[0]
        bm = s.build_misses - base_build[1]
        nh = s.cache_hits - base_nbr[0]
        nm = s.cache_misses - base_nbr[1]
        lat = np.array(lats)
        return {"config": name,
                "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
                "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3),
                "req_per_s": round(len(meas) / wall, 1),
                "host_ms_per_batch": round(host_ms, 3),
                "stages_ms": stages_ms,
                "select_ms": stages_ms.get("select", ""),
                "build_ms": stages_ms.get("build", ""),
                "pack_ms": stages_ms.get("pack", ""),
                "nbr_hit_rate": round(nh / (nh + nm), 4)
                if nh + nm else 0.0,
                "build_hit_rate": round(bh / (bh + bm), 4)
                if bh + bm else 0.0}


def run(requests: int = 4096, batch_size: int = 16, scale: float = 0.05,
        receptive_field: int = 64, zipf_a: float = 1.1,
        nbr_capacity: int = 1024, warm_fraction: float = 0.25,
        seed: int = 0):
    import jax

    from repro.gnn.model import init_gnn

    g = get_graph("flickr", scale=scale, seed=seed)
    cfg = GNNConfig(kind="gcn", n_layers=2,
                    receptive_field=receptive_field, f_in=g.feature_dim)
    params = init_gnn(cfg, jax.random.PRNGKey(seed))
    targets = zipf_traffic(g, requests, zipf_a, seed + 1)
    n_warm = int(len(targets) * warm_fraction) // batch_size * batch_size
    warm, meas = targets[:n_warm], targets[n_warm:]
    print(f"graph: V={g.num_vertices} f={g.feature_dim} | Zipf({zipf_a}) "
          f"{requests} requests ({n_warm} warmup), C={batch_size} "
          f"N={receptive_field}")

    rows = []
    for name, policy in make_policies(nbr_capacity).items():
        row = run_policy(name, policy, g, cfg, params, batch_size,
                         warm, meas)
        rows.append(row)
        print(f"  [{name}] p50={row['p50_ms']}ms "
              f"host/batch={row['host_ms_per_batch']}ms "
              f"stages={row['stages_ms']} "
              f"nbr_hit={row['nbr_hit_rate']} "
              f"build_hit={row['build_hit_rate']}", flush=True)

    print()
    print_table(rows, ["config", "p50_ms", "p99_ms", "req_per_s",
                       "host_ms_per_batch", "select_ms", "build_ms",
                       "pack_ms", "nbr_hit_rate", "build_hit_rate"])
    by = {r["config"]: r for r in rows}
    if by["staged+rows"]["host_ms_per_batch"] > 0:
        win = by["staged+nbr"]["host_ms_per_batch"] \
            / by["staged+rows"]["host_ms_per_batch"]
        print(f"\nBuild-skip win (staged+nbr -> staged+rows host time): "
              f"{win:.2f}x")
    payload = {"rows": rows, "zipf_a": zipf_a, "requests": requests,
               "batch_size": batch_size,
               "receptive_field": receptive_field,
               "num_vertices": g.num_vertices,
               "feature_dim": g.feature_dim}
    record_trajectory(
        "pipeline", payload,
        regress={"staged_rows_p50_ms": by["staged+rows"]["p50_ms"],
                 "staged_rows_host_ms":
                     by["staged+rows"]["host_ms_per_batch"]})
    return payload


def run_suite(quick: bool = True):
    """benchmarks.run harness entry (quick == CI smoke shape)."""
    if quick:
        return run(requests=640, batch_size=8, scale=0.004,
                   receptive_field=32, nbr_capacity=256,
                   warm_fraction=0.4)
    return run()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4096)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--zipf", type=float, default=1.1)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph + few requests (CI canary)")
    a = ap.parse_args()
    if a.smoke:
        run_suite(quick=True)
    else:
        run(requests=a.requests, batch_size=a.batch_size, zipf_a=a.zipf)
