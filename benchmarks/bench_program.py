"""AckProgram per-op mode dispatch benchmark.

For every model kind, the same engine/traffic is run four ways:

  dense     every mux'd op forced to the systolic datapath
  sg        every mux'd op forced to the scatter-gather datapath
  auto      static per-op dispatch — each Aggregate / AttentionSoftmax
            picks its own mode ONCE from its kernel's FLOP model
            (Transform stays systolic)
  adaptive  per-BATCH dispatch — every batch re-decides from measured
            densities + the calibration table's p50s (warmup passes
            sample both modes, then the table drives; core.dispatch)

Two regimes are driven: the paper's hub-dense PPR subgraphs (auto should
track the dense forcing) and an ultra-sparse graph (auto should flip the
aggregation ops to sg while the wide transforms stay dense — the
heterogeneous program the IR exists for; its per-op decision list is
printed). The acceptance bar for the adaptive lane is printed per cell:
its p50 must track the best forced mode within 5%. Emits
``results/BENCH_program.json`` — a trajectory artifact appended per run;
per-cell adaptive p50s and adaptive/best-forced ratios feed the regress
gate.

    python benchmarks/bench_program.py [--smoke] [--requests N]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import print_table, record_trajectory
from repro.core.config import ServingConfig
from repro.core.engine import DecoupledEngine
from repro.gnn.model import GNNConfig
from repro.graphs.csr import from_edge_list
from repro.graphs.synthetic import get_graph


KINDS = ("gcn", "sage", "gin", "gat")


def sparse_graph(v=2048, edges=256, f=64, seed=0):
    """Mean degree << 1: the regime where sg aggregation wins (N > 2E)."""
    rng = np.random.default_rng(seed)
    src = rng.choice(v, edges, replace=False)
    dst = (src + 1 + rng.integers(0, v - 1, edges)) % v
    feats = rng.standard_normal((v, f)).astype(np.float32)
    return from_edge_list(src, dst, v, feats, name="ultra-sparse")


WARMUP_PASSES = 2      # adaptive lane: forced samples per mode per bucket
REPS = 5               # timed passes over the target list per lane
MODES = ("dense", "sg", "auto", "adaptive")


def run_kind(g, cfg, params, targets, batch_size):
    """Time all four lanes INTERLEAVED chunk-by-chunk in one window.

    These latencies are host-pipeline dominated (~10ms/chunk) on a
    shared CPU whose load drifts over minutes; running the lanes
    sequentially bakes that drift into the cross-lane ratios. Rotating
    every chunk through all four engines back-to-back makes each lane
    sample the same noise distribution, so the p50 ratios isolate the
    dispatch overhead the acceptance bar is about."""
    import jax
    from repro.core.dispatch import DispatchConfig
    lanes = {}
    for mode in MODES:
        if mode == "adaptive":
            sconf = ServingConfig(
                batch_size=batch_size, mode="auto",
                dispatch=DispatchConfig(warmup_passes=WARMUP_PASSES))
        else:
            sconf = ServingConfig(batch_size=batch_size, mode=mode)
        lanes[mode] = DecoupledEngine(g, cfg, params=params, config=sconf)
    lats = {m: [] for m in MODES}
    try:
        for mode, eng in lanes.items():
            # warm the compile out of the measurement; the adaptive lane
            # also burns through the exploration schedule (2*passes
            # forced samples per mode) plus one chunk to jit the
            # exploited variant, so the timed window measures
            # steady-state measured-cost dispatch
            n_warm = 2 * WARMUP_PASSES + 2 if mode == "adaptive" else 1
            for k in range(n_warm):
                lo = (k * batch_size) % max(len(targets) - batch_size, 1)
                w = eng.submit_chunk(targets[lo:lo + batch_size]).result()
            jax.block_until_ready(w)
        for _ in range(REPS):
            for i in range(0, len(targets), batch_size):
                chunk = targets[i:i + batch_size]
                for mode, eng in lanes.items():
                    t0 = time.perf_counter()
                    eng.submit_chunk(chunk).result()
                    lats[mode].append(time.perf_counter() - t0)
        out = {}
        for mode, eng in lanes.items():
            lat = np.array(lats[mode])
            dec = eng.decision
            r = {"mode": mode,
                 "resolved": dec.mode,
                 "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
                 "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3),
                 "ops": [{"site": d.site, "op": d.op, "mode": d.mode}
                         for d in dec],
                 "n_dense": dec.n_dense, "n_sg": dec.n_sg}
            if mode == "adaptive":
                r["dispatch"] = eng.dispatch_report()
            out[mode] = r
    finally:
        for eng in lanes.values():
            eng.close()
    return out


def bench_regime(name, g, kinds, requests, batch_size, receptive_field,
                 f_hidden, seed=0):
    import jax

    from repro.gnn.model import init_gnn
    print(f"\n-- regime: {name} (V={g.num_vertices}, "
          f"E={g.num_edges}, N={receptive_field}) --")
    rng = np.random.default_rng(seed)
    pool = np.unique(np.concatenate(
        [np.where(g.degrees > 0)[0], np.arange(min(64, g.num_vertices))]))
    targets = rng.choice(pool, size=requests)
    rows, details = [], {}
    for kind in kinds:
        cfg = GNNConfig(kind=kind, n_layers=2,
                        receptive_field=receptive_field,
                        f_in=g.feature_dim, f_hidden=f_hidden)
        params = init_gnn(cfg, jax.random.PRNGKey(seed))
        row = {"kind": kind}
        res = run_kind(g, cfg, params, targets, batch_size)
        for mode, r in res.items():
            row[f"{mode}_p50_ms"] = r["p50_ms"]
            if mode == "auto":
                row["auto_program"] = f"{r['n_dense']}d+{r['n_sg']}sg"
                details[kind] = r["ops"]
            if mode == "adaptive":
                row["dispatch_sources"] = r["dispatch"]["sources"]
        best = min(row["dense_p50_ms"], row["sg_p50_ms"])
        row["adaptive_ratio"] = round(
            row["adaptive_p50_ms"] / best, 4) if best else 1.0
        rows.append(row)
        flag = "" if row["adaptive_ratio"] <= 1.05 else \
            "  ** >5% over best forced mode **"
        print(f"  [{kind}] dense={row['dense_p50_ms']}ms "
              f"sg={row['sg_p50_ms']}ms auto={row['auto_p50_ms']}ms "
              f"adaptive={row['adaptive_p50_ms']}ms "
              f"(ratio={row['adaptive_ratio']}) "
              f"auto-program={row['auto_program']}{flag}", flush=True)
    print()
    print_table(rows, ["kind", "dense_p50_ms", "sg_p50_ms", "auto_p50_ms",
                       "adaptive_p50_ms", "adaptive_ratio",
                       "auto_program"])
    return rows, details


def run(requests: int = 256, batch_size: int = 8, scale: float = 0.02,
        receptive_field: int = 64, seed: int = 0,
        kinds=KINDS):
    g_dense = get_graph("flickr", scale=scale, seed=seed)
    dense_rows, dense_ops = bench_regime(
        "ppr-dense (paper regime)", g_dense, kinds, requests, batch_size,
        receptive_field, f_hidden=256, seed=seed)

    g_sparse = sparse_graph(seed=seed)
    sparse_rows, sparse_ops = bench_regime(
        "ultra-sparse (mixed per-op regime)", g_sparse, kinds, requests,
        batch_size, receptive_field=32, f_hidden=256, seed=seed)

    mixed = {k: ops for k, ops in sparse_ops.items()
             if {o["mode"] for o in ops} == {"dense", "sg"}}
    print("\nper-op decisions (ultra-sparse, auto):")
    for kind, ops_list in sparse_ops.items():
        print(f"  {kind}: " + ", ".join(
            f"{o['site']} {o['op']}={o['mode']}" for o in ops_list))
    if mixed:
        print(f"\nheterogeneous auto programs (sg aggregation + dense "
              f"transform in ONE compiled program): {sorted(mixed)}")

    # regress gate scalars: per-cell adaptive p50s + adaptive/best-forced
    # ratios, plus the worst ratio across every (kind x regime) cell —
    # the acceptance bar (<= 1.05 everywhere) as a single scalar
    regress, worst = {}, 0.0
    for regime, rows in (("dense", dense_rows), ("sparse", sparse_rows)):
        for row in rows:
            cell = f"{regime}_{row['kind']}"
            regress[f"adaptive_p50_ms_{cell}"] = row["adaptive_p50_ms"]
            regress[f"adaptive_ratio_{cell}"] = row["adaptive_ratio"]
            worst = max(worst, row["adaptive_ratio"])
    regress["adaptive_worst_ratio"] = worst
    if worst > 1.05:
        print(f"\nWARNING: adaptive p50 {worst:.3f}x best forced mode in "
              f"the worst cell (acceptance bar: <= 1.05x)")
    else:
        print(f"\nadaptive lane within 5% of best forced mode in every "
              f"cell (worst ratio {worst:.3f}x)")

    payload = {"requests": requests, "batch_size": batch_size,
               "receptive_field": receptive_field,
               "dense_regime": dense_rows, "sparse_regime": sparse_rows,
               "sparse_auto_ops": sparse_ops,
               "mixed_program_kinds": sorted(mixed),
               "adaptive_worst_ratio": worst}
    record_trajectory("program", payload, regress=regress)
    return payload


def run_suite(quick: bool = True):
    """benchmarks.run harness entry (quick == CI smoke shape)."""
    if quick:
        return run(requests=64, batch_size=8, scale=0.005,
                   receptive_field=32)
    return run()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph + few requests (CI canary)")
    a = ap.parse_args()
    if a.smoke:
        run_suite(quick=True)
    else:
        run(requests=a.requests, batch_size=a.batch_size)
