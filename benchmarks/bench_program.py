"""AckProgram per-op mode dispatch benchmark.

For every model kind, the same engine/traffic is run three ways:

  dense   every mux'd op forced to the systolic datapath
  sg      every mux'd op forced to the scatter-gather datapath
  auto    per-op dispatch — each Aggregate / AttentionSoftmax picks its
          own mode from ITS kernel's FLOP model (Transform stays systolic)

Two regimes are driven: the paper's hub-dense PPR subgraphs (auto should
track the dense forcing) and an ultra-sparse graph (auto should flip the
aggregation ops to sg while the wide transforms stay dense — the
heterogeneous program the IR exists for; its per-op decision list is
printed). Emits ``results/BENCH_program.json`` — a trajectory artifact
appended per run.

    python benchmarks/bench_program.py [--smoke] [--requests N]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import print_table, record_trajectory
from repro.core.config import ServingConfig
from repro.core.engine import DecoupledEngine
from repro.gnn.model import GNNConfig
from repro.graphs.csr import from_edge_list
from repro.graphs.synthetic import get_graph


KINDS = ("gcn", "sage", "gin", "gat")


def sparse_graph(v=2048, edges=256, f=64, seed=0):
    """Mean degree << 1: the regime where sg aggregation wins (N > 2E)."""
    rng = np.random.default_rng(seed)
    src = rng.choice(v, edges, replace=False)
    dst = (src + 1 + rng.integers(0, v - 1, edges)) % v
    feats = rng.standard_normal((v, f)).astype(np.float32)
    return from_edge_list(src, dst, v, feats, name="ultra-sparse")


def run_mode(g, cfg, params, mode, targets, batch_size):
    import jax
    with DecoupledEngine(g, cfg, params=params,
                         config=ServingConfig(batch_size=batch_size,
                                              mode=mode)) as eng:
        # warm the compile out of the measurement
        w = eng.submit_chunk(targets[:batch_size]).result()
        jax.block_until_ready(w)
        lats = []
        for i in range(0, len(targets), batch_size):
            t0 = time.perf_counter()
            eng.submit_chunk(targets[i:i + batch_size]).result()
            lats.append(time.perf_counter() - t0)
        lat = np.array(lats)
        dec = eng.decision
        return {"mode": mode,
                "resolved": dec.mode,
                "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
                "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3),
                "ops": [{"site": d.site, "op": d.op, "mode": d.mode}
                        for d in dec],
                "n_dense": dec.n_dense, "n_sg": dec.n_sg}


def bench_regime(name, g, kinds, requests, batch_size, receptive_field,
                 f_hidden, seed=0):
    import jax

    from repro.gnn.model import init_gnn
    print(f"\n-- regime: {name} (V={g.num_vertices}, "
          f"E={g.num_edges}, N={receptive_field}) --")
    rng = np.random.default_rng(seed)
    pool = np.unique(np.concatenate(
        [np.where(g.degrees > 0)[0], np.arange(min(64, g.num_vertices))]))
    targets = rng.choice(pool, size=requests)
    rows, details = [], {}
    for kind in kinds:
        cfg = GNNConfig(kind=kind, n_layers=2,
                        receptive_field=receptive_field,
                        f_in=g.feature_dim, f_hidden=f_hidden)
        params = init_gnn(cfg, jax.random.PRNGKey(seed))
        row = {"kind": kind}
        for mode in ("dense", "sg", "auto"):
            r = run_mode(g, cfg, params, mode, targets, batch_size)
            row[f"{mode}_p50_ms"] = r["p50_ms"]
            if mode == "auto":
                row["auto_program"] = f"{r['n_dense']}d+{r['n_sg']}sg"
                details[kind] = r["ops"]
        rows.append(row)
        print(f"  [{kind}] dense={row['dense_p50_ms']}ms "
              f"sg={row['sg_p50_ms']}ms auto={row['auto_p50_ms']}ms "
              f"auto-program={row['auto_program']}", flush=True)
    print()
    print_table(rows, ["kind", "dense_p50_ms", "sg_p50_ms", "auto_p50_ms",
                       "auto_program"])
    return rows, details


def run(requests: int = 256, batch_size: int = 8, scale: float = 0.02,
        receptive_field: int = 64, seed: int = 0,
        kinds=KINDS):
    g_dense = get_graph("flickr", scale=scale, seed=seed)
    dense_rows, dense_ops = bench_regime(
        "ppr-dense (paper regime)", g_dense, kinds, requests, batch_size,
        receptive_field, f_hidden=256, seed=seed)

    g_sparse = sparse_graph(seed=seed)
    sparse_rows, sparse_ops = bench_regime(
        "ultra-sparse (mixed per-op regime)", g_sparse, kinds, requests,
        batch_size, receptive_field=32, f_hidden=256, seed=seed)

    mixed = {k: ops for k, ops in sparse_ops.items()
             if {o["mode"] for o in ops} == {"dense", "sg"}}
    print("\nper-op decisions (ultra-sparse, auto):")
    for kind, ops_list in sparse_ops.items():
        print(f"  {kind}: " + ", ".join(
            f"{o['site']} {o['op']}={o['mode']}" for o in ops_list))
    if mixed:
        print(f"\nheterogeneous auto programs (sg aggregation + dense "
              f"transform in ONE compiled program): {sorted(mixed)}")

    payload = {"requests": requests, "batch_size": batch_size,
               "receptive_field": receptive_field,
               "dense_regime": dense_rows, "sparse_regime": sparse_rows,
               "sparse_auto_ops": sparse_ops,
               "mixed_program_kinds": sorted(mixed)}
    record_trajectory("program", payload)
    return payload


def run_suite(quick: bool = True):
    """benchmarks.run harness entry (quick == CI smoke shape)."""
    if quick:
        return run(requests=64, batch_size=8, scale=0.005,
                   receptive_field=32)
    return run()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph + few requests (CI canary)")
    a = ap.parse_args()
    if a.smoke:
        run_suite(quick=True)
    else:
        run(requests=a.requests, batch_size=a.batch_size)
