"""Hybrid precompute-tier serving benchmark: fast path vs online PPR.

The precompute tier's claim is that a tier-fresh target costs a row
gather — no PPR push, no subgraph build, no device program — so its
serving latency must sit far below the online path's. This suite
measures that, plus what keeping the tier fresh costs under a stream of
edge updates:

  online   ServingConfig(precompute=None)              — the baseline
  hybrid   ServingConfig(precompute=PrecomputeConfig())— tier-routed

The deployment shape makes the two paths EXACTLY comparable (receptive
field = V, tiny ppr_eps): the hybrid engine's answers must be allclose
to the online engine's on the same Zipf traffic, and the fast-path p50
must undercut the online p50 by at least ``SPEEDUP_BAR``x. The refresh
sweep then applies edge-update bursts of increasing size and measures
the demotion footprint + drain (recompute) cost per rate, checking the
post-refresh answers equal a fresh engine built on the updated graph.

Appends ``results/BENCH_precompute.json``.

    python benchmarks/bench_precompute.py [--smoke] [--requests N]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import print_table, record_trajectory
from repro.core.config import ServingConfig
from repro.core.engine import DecoupledEngine
from repro.gnn.model import GNNConfig
from repro.graphs.synthetic import get_graph, zipf_traffic
from repro.precompute import PrecomputeConfig

SPEEDUP_BAR = 5.0            # fast-path p50 must be >= 5x below online
ROUNDS = 4                   # alternating measurement rounds per mode


def _drive(eng, chunks) -> list:
    """Closed-loop per-batch wall latencies (one batch in flight, so the
    fast path's skipped stages are NOT hidden under pipelining)."""
    out = []
    for ch in chunks:
        t0 = time.perf_counter()
        eng.submit_chunk(ch).result(timeout=600)
        out.append(time.perf_counter() - t0)
    return out


def _engine_pair(g, cfg, params, batch_size):
    base = dict(batch_size=batch_size, num_threads=2)
    return {
        "online": DecoupledEngine(
            g, cfg, params=params, config=ServingConfig(**base)),
        "hybrid": DecoupledEngine(
            g, cfg, params=params,
            config=ServingConfig(precompute=PrecomputeConfig(), **base)),
    }


def run(requests: int = 512, batch_size: int = 8, scale: float = 0.004,
        zipf_a: float = 1.1, seed: int = 0,
        dataset: str = "flickr") -> dict:
    """Fast-path vs online latency under Zipf traffic + equality check.

    receptive_field = V and a tiny ppr_eps make the online subgraph the
    FULL graph, so both paths compute the same function and the
    comparison is an equality check, not just a speed race."""
    import jax

    from repro.gnn.model import init_gnn

    g = get_graph(dataset, scale=scale, seed=seed)
    V = g.num_vertices
    cfg = GNNConfig(kind="sgc", n_layers=2, receptive_field=V,
                    f_in=g.feature_dim, ppr_eps=1e-9, readout="target")
    params = init_gnn(cfg, jax.random.PRNGKey(seed))
    traffic = zipf_traffic(g, requests, zipf_a, seed + 1)
    chunks = [traffic[i:i + batch_size]
              for i in range(0, len(traffic) - batch_size + 1,
                             batch_size)]
    warm = chunks[:max(4, len(chunks) // 4)]
    meas = chunks[len(warm):]
    per_round = max(1, len(meas) // ROUNDS)
    print(f"graph: V={V} | {len(meas)} measured batches, "
          f"C={batch_size} N={V} (full coverage), {ROUNDS} alternating "
          f"rounds per mode")

    engines = _engine_pair(g, cfg, params, batch_size)
    lat = {name: [] for name in engines}
    try:
        check = np.concatenate(chunks[:4])
        refs = {name: eng.infer(check, overlap=False).embeddings
                for name, eng in engines.items()}
        assert np.allclose(refs["online"], refs["hybrid"],
                           rtol=1e-4, atol=1e-5), (
            "hybrid serving diverged from online-only serving: max diff "
            f"{np.abs(refs['online'] - refs['hybrid']).max():.3e}")
        for eng in engines.values():            # compile + warm caches
            _drive(eng, warm)
        for r in range(ROUNDS):                 # interleave the modes
            block = meas[r * per_round:(r + 1) * per_round]
            for name, eng in engines.items():
                lat[name].extend(_drive(eng, block))
        rep = engines["hybrid"].precompute_report()
    finally:
        for eng in engines.values():
            eng.close()

    p = {name: {q: float(np.percentile(v, q))
                for q in (50, 90, 99)} for name, v in lat.items()}
    speedup = p["online"][50] / p["hybrid"][50]
    rows = [{"mode": name,
             "p50_ms": round(p[name][50] * 1e3, 3),
             "p90_ms": round(p[name][90] * 1e3, 3),
             "p99_ms": round(p[name][99] * 1e3, 3),
             "batches": len(lat[name])} for name in lat]
    print_table(rows, ["mode", "p50_ms", "p90_ms", "p99_ms", "batches"])
    print(f"fast-path p50 speedup: {speedup:.1f}x (bar "
          f"{SPEEDUP_BAR:.0f}x) | tier hit rate "
          f"{rep['hit_rate']:.3f}, {rep['resident']} resident rows, "
          f"{rep['tier_bytes']} bytes")
    print("hybrid allclose online-only OK")
    assert speedup >= SPEEDUP_BAR, (
        f"fast path p50 only {speedup:.1f}x below online "
        f"({p['hybrid'][50] * 1e3:.3f}ms vs "
        f"{p['online'][50] * 1e3:.3f}ms); bar is {SPEEDUP_BAR:.0f}x")

    return {"rows": rows, "p50_speedup": round(speedup, 2),
            "speedup_bar": SPEEDUP_BAR,
            "tier": {k: rep[k] for k in ("resident", "fresh", "hits",
                                         "misses", "hit_rate",
                                         "tier_bytes")},
            "requests": requests, "batch_size": batch_size,
            "num_vertices": V}


def run_refresh(rates=(1, 4, 16), batch_size: int = 8,
                scale: float = 0.004, seed: int = 0,
                dataset: str = "flickr") -> dict:
    """Refresh cost vs edge-update rate: per burst size, the demotion
    footprint (dependency-ball vertices knocked out of the tier) and the
    wall cost of recomputing them, with a correctness gate — after the
    drain, the hybrid engine's answers must equal a FRESH engine built
    on the updated graph."""
    import jax

    from repro.gnn.model import init_gnn

    rows = []
    for rate in rates:
        g = get_graph(dataset, scale=scale, seed=seed)
        V = g.num_vertices
        cfg = GNNConfig(kind="sgc", n_layers=2, receptive_field=V,
                        f_in=g.feature_dim, ppr_eps=1e-9,
                        readout="target")
        params = init_gnn(cfg, jax.random.PRNGKey(seed))
        sc = ServingConfig(batch_size=batch_size, num_threads=2,
                           precompute=PrecomputeConfig(auto_refresh=False))
        rng = np.random.default_rng(seed + rate)
        edges = [(int(u), int(v)) for u, v in
                 rng.integers(0, V, size=(rate, 2)) if u != v]
        with DecoupledEngine(g, cfg, params=params, config=sc) as eng:
            t0 = time.perf_counter()
            g.apply_edge_updates(insert=edges)
            t_demote = time.perf_counter() - t0
            demoted = eng.precompute_report()["demotions"]
            t0 = time.perf_counter()
            eng.precompute.drain()
            t_refresh = time.perf_counter() - t0
            targets = np.arange(min(4 * batch_size, V))
            got = eng.infer(targets).embeddings
        with DecoupledEngine(g, cfg, params=params,
                             config=ServingConfig(
                                 batch_size=batch_size,
                                 num_threads=2,
                                 precompute=PrecomputeConfig())) as ref:
            want = ref.infer(targets).embeddings
        assert np.allclose(want, got, rtol=1e-4, atol=1e-5), (
            f"post-refresh answers diverged from a fresh engine at "
            f"update rate {rate}")
        rows.append({"edges_per_burst": len(edges), "demoted": demoted,
                     "demote_ms": round(t_demote * 1e3, 3),
                     "refresh_ms": round(t_refresh * 1e3, 3),
                     "refresh_ms_per_vertex":
                         round(t_refresh * 1e3 / max(1, demoted), 4)})
    print_table(rows, ["edges_per_burst", "demoted", "demote_ms",
                       "refresh_ms", "refresh_ms_per_vertex"])
    print("post-refresh == fresh-build equality OK at every rate")
    return {"rows": rows}


def run_suite(quick: bool = True):
    """benchmarks.run harness entry (quick == CI precompute-smoke)."""
    if quick:
        payload = run(requests=256, batch_size=8, scale=0.004)
        payload["refresh"] = run_refresh(rates=(1, 4))
    else:
        payload = run(requests=1024, batch_size=8, scale=0.01)
        payload["refresh"] = run_refresh(rates=(1, 4, 16, 64))
    record_trajectory("precompute", payload)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=1024)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph + few requests (CI gate)")
    a = ap.parse_args()
    if a.smoke:
        run_suite(quick=True)
    else:
        payload = run(requests=a.requests, batch_size=a.batch_size,
                      scale=0.01)
        payload["refresh"] = run_refresh(rates=(1, 4, 16, 64))
        record_trajectory("precompute", payload)
