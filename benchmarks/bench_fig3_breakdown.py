"""Fig. 1/3 reproduction: the Coupled model's exponential receptive-field /
communication growth and low C2C ratio vs the Decoupled model's fixed cost.

Measures per L: average L-hop receptive-field size (full and fanout-
sampled), host->device bytes, compute FLOPs, and the resulting C2C ratio —
the quantities the paper uses to justify decoupling (§2.2, §3.2).
"""
from __future__ import annotations


from benchmarks.common import QUICK_SCALE, print_table, record_trajectory
from repro.core.coupled import receptive_field_size
from repro.core.subgraph import build_batch
from repro.graphs.synthetic import get_graph

F_HIDDEN = 256


def run(quick: bool = True):
    g = get_graph("flickr", scale=QUICK_SCALE["flickr"])
    f_in = g.feature_dim
    targets = list(range(16 if quick else 64))
    rows = []
    fanouts = [25, 10, 10, 10]
    for L in ([1, 2, 3] if quick else [1, 2, 3, 4]):
        n_full = receptive_field_size(g, targets, L)
        n_samp = receptive_field_size(g, targets, L, fanouts[:L])
        bytes_coupled = 4.0 * n_samp * f_in
        flops_coupled = 2.0 * n_samp * f_in * F_HIDDEN
        rows.append({
            "model": "coupled", "L": L,
            "receptive_field": round(n_samp, 1),
            "rf_unsampled": round(n_full, 1),
            "h2d_KB": round(bytes_coupled / 1024, 1),
            "c2c_flops_per_byte": round(flops_coupled / bytes_coupled, 1),
        })
    # decoupled: fixed N regardless of L
    for L in ([3, 8] if quick else [3, 5, 8, 16]):
        N = 128
        sb = build_batch(g, targets[:8], N, num_threads=4)
        nbytes = sb.nbytes("dense") / len(targets[:8])
        flops = (2.0 * N * f_in * F_HIDDEN
                 + (L - 1) * 2.0 * N * F_HIDDEN * F_HIDDEN
                 + L * 2.0 * N * N * F_HIDDEN)
        rows.append({
            "model": "decoupled", "L": L, "receptive_field": N,
            "rf_unsampled": N,
            "h2d_KB": round(nbytes / 1024, 1),
            "c2c_flops_per_byte": round(flops / nbytes, 1),
        })
    print_table(rows, ["model", "L", "receptive_field", "h2d_KB",
                       "c2c_flops_per_byte"])
    # paper claims: coupled rf grows superlinearly; decoupled C2C grows
    # linearly with L while bytes stay constant
    cp = [r for r in rows if r["model"] == "coupled"]
    dc = [r for r in rows if r["model"] == "decoupled"]
    claims = {
        "coupled_rf_explodes": cp[-1]["receptive_field"]
        > 4 * cp[0]["receptive_field"],
        "decoupled_bytes_constant": len({r["h2d_KB"] for r in dc}) == 1,
        "decoupled_c2c_grows_with_L": dc[-1]["c2c_flops_per_byte"]
        > 1.5 * dc[0]["c2c_flops_per_byte"],
    }
    print(claims)
    payload = {"rows": rows, "claims": claims}
    record_trajectory("fig3_breakdown", payload)
    return payload


if __name__ == "__main__":
    run(quick=False)
