"""Table 5 reproduction: average latency of loading one target's induced
subgraph, N in {64, 128, 256}, per dataset.

Two numbers per cell: measured host->device transfer on this container
(jax.device_put, CPU backend) and the PCIe-3.0x16 model the paper uses
(bytes / 15.6 GB/s + t_fixed), which is directly comparable to Table 5.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import QUICK_SCALE, print_table, record_trajectory
from repro.core.subgraph import build_batch
from repro.graphs.synthetic import get_graph

PCIE_BW = 15.6e9
T_FIXED = 0.35e-6           # paper cites 0.3-0.4 us setup per transfer


def run(quick: bool = True):
    rows = []
    datasets = ["flickr", "ogbn-arxiv", "reddit"]
    for ds in datasets:
        g = get_graph(ds, scale=QUICK_SCALE[ds])
        rng = np.random.default_rng(0)
        targets = rng.integers(0, g.num_vertices, size=8 if quick else 32)
        for N in (64, 128, 256):
            sb = build_batch(g, targets, N, num_threads=4)
            per_target = {k: v[:1] for k, v in
                          sb.device_arrays("dense").items()}
            nbytes = sum(a.nbytes for a in per_target.values())
            # measured H2D (CPU backend: memcpy into device buffer)
            t0 = time.perf_counter()
            for _ in range(5):
                jax.block_until_ready(jax.device_put(per_target))
            t_meas = (time.perf_counter() - t0) / 5
            t_pcie = nbytes / PCIE_BW + T_FIXED
            rows.append({
                "dataset": ds, "N": N, "KB_per_target": round(
                    nbytes / 1024, 1),
                "pcie_model_us": round(t_pcie * 1e6, 1),
                "measured_h2d_us": round(t_meas * 1e6, 1),
            })
    # beyond-paper H6: cross-target feature dedup ratio per dataset
    from repro.core.ini import ini_batch
    from repro.core.subgraph import packed_features
    dedup = []
    for ds in datasets:
        g = get_graph(ds, scale=QUICK_SCALE[ds])
        rng = np.random.default_rng(3)
        tg = rng.integers(0, g.num_vertices, size=64)
        nls = ini_batch(g, tg, 128, num_threads=4)
        _, _, ratio = packed_features(nls, g, 128)
        dedup.append({"dataset": ds, "batch": 64, "N": 128,
                      "packed/dense": round(ratio, 3),
                      "t_load_reduction": f"{1/ratio:.1f}x"})
    print_table(rows, ["dataset", "N", "KB_per_target", "pcie_model_us",
                       "measured_h2d_us"])
    print_table(dedup, ["dataset", "batch", "N", "packed/dense",
                        "t_load_reduction"])
    # paper property: load time scales ~O(N f + N^2) and stays 10s of us
    payload = {"rows": rows, "dedup": dedup, "pcie_bw": PCIE_BW, "t_fixed_us": 0.35}
    record_trajectory("table5_load", payload)
    return payload


if __name__ == "__main__":
    run(quick=False)
