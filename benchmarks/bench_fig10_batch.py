"""Fig. 10 reproduction: latency vs batch size (GraphSAGE, Flickr-like),
batch sizes {32, 64, 128, 256, 512} (paper §5.3)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import QUICK_SCALE, print_table, record_trajectory, timeit
from repro.core.config import ServingConfig
from repro.core.engine import DecoupledEngine
from repro.gnn.model import GNNConfig
from repro.graphs.synthetic import get_graph


def run(quick: bool = True):
    g = get_graph("flickr", scale=QUICK_SCALE["flickr"])
    cfg = GNNConfig(kind="sage", n_layers=3, receptive_field=128,
                    f_in=g.feature_dim)
    sizes = [32, 64, 128] if quick else [32, 64, 128, 256, 512]
    rng = np.random.default_rng(0)
    rows = []
    for bs in sizes:
        with DecoupledEngine(
                g, cfg,
                config=ServingConfig(batch_size=min(bs, 64))) as eng:
            targets = rng.integers(0, g.num_vertices, size=bs)
            t = timeit(lambda: eng.infer(targets), warmup=1, iters=2)
            res = eng.infer(targets)
        rows.append({"batch": bs,
                     "latency_ms": round(t["min_s"] * 1e3, 2),
                     "ms_per_target": round(t["min_s"] * 1e3 / bs, 3),
                     "overlap": res.stats.summary()["stages"]["overlap"]})
    print_table(rows, ["batch", "latency_ms", "ms_per_target", "overlap"])
    payload = {"rows": rows, "model": cfg.display}
    record_trajectory("fig10_batch", payload)
    return payload


if __name__ == "__main__":
    run(quick=False)
