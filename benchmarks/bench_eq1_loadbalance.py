"""Eq. 1 / §4.3 reproduction: unified ACK vs hybrid accelerator latency
under varying FA/FT workload ratios.

    unified:  (a1 + a2) / beta
    hybrid:   max(a1 / b1, a2 / (beta - b1))   for the hybrid's FIXED split

The paper's point: the hybrid split b1 is fixed at design time while the
actual a1/a2 ratio varies with receptive-field density, so the hybrid is
load-imbalanced almost everywhere. We sweep REAL workloads: a1 = measured
FA FLOPs of PPR subgraphs at several N (edge density varies), a2 = FT
FLOPs, and report the latency ratio hybrid/unified — always >= 1.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import QUICK_SCALE, print_table, record_trajectory
from repro.core.subgraph import build_batch
from repro.graphs.synthetic import get_graph

F = 256


def run(quick: bool = True):
    g = get_graph("flickr", scale=QUICK_SCALE["flickr"])
    rng = np.random.default_rng(0)
    targets = rng.integers(0, g.num_vertices, size=8 if quick else 32)
    rows = []
    # hybrid split fixed for the N=128 average workload (best case for it)
    sb0 = build_batch(g, targets, 128, num_threads=4)
    e0 = float(sb0.n_edges.mean())
    a1_design = 2.0 * e0 * F           # FA ~ edges
    a2_design = 2.0 * 128 * F * F      # FT ~ N f^2
    b1_frac = a1_design / (a1_design + a2_design)
    for N in (64, 128, 256):
        sb = build_batch(g, targets, N, num_threads=4)
        edges = float(sb.n_edges.mean())
        a1 = 2.0 * edges * F
        a2 = 2.0 * N * F * F
        unified = (a1 + a2)                       # / beta == 1
        hybrid = max(a1 / b1_frac, a2 / (1 - b1_frac))
        rows.append({
            "N": N, "avg_edges": round(edges, 1),
            "FA_share_%": round(100 * a1 / (a1 + a2), 1),
            "hybrid_over_unified": round(hybrid / unified, 3),
        })
    print_table(rows, ["N", "avg_edges", "FA_share_%",
                       "hybrid_over_unified"])
    assert all(r["hybrid_over_unified"] >= 0.999 for r in rows)
    payload = {"rows": rows, "hybrid_split_FA_frac": round(b1_frac, 4)}
    record_trajectory("eq1_loadbalance", payload)
    return payload


if __name__ == "__main__":
    run(quick=False)
