"""Observability overhead benchmark: tracing AND metrics must be
near-free.

The observability contract is (a) bitwise-identical serving outputs
instrumented or not, and (b) <5% p50 per-batch overhead — otherwise
nobody leaves it on and the flight recorder never sees the batch you
needed. This suite measures both, for both subsystems, on the same
engine shape the pipeline benchmarks use:

  untraced   ServingConfig()                        — the baseline
  traced     ServingConfig(trace=TraceConfig())     — every batch sampled
  metered    ServingConfig(telemetry=
                           TelemetryConfig())       — windowed metrics on

Rounds alternate between the deployments so clock drift and cache
warmth cancel instead of biasing one side. The traced run then exports
its chrome trace and re-validates it (every B has an E, parent refs
resolve); the metered run's exposition text is re-validated with the
in-repo Prometheus format checker.

Appends ``results/BENCH_obs.json``.

    python benchmarks/bench_obs.py [--smoke] [--requests N]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import print_table, record_trajectory
from repro.core.config import ServingConfig
from repro.core.engine import DecoupledEngine
from repro.gnn.model import GNNConfig
from repro.graphs.synthetic import get_graph, zipf_traffic
from repro.obs import (TelemetryConfig, TraceConfig,
                       validate_chrome_trace, validate_exposition)

OVERHEAD_BAR = 0.05          # instrumented p50 may exceed baseline by 5%
ROUNDS = 4                   # alternating measurement rounds per mode


def _drive(eng, chunks) -> list:
    """Closed-loop per-batch wall latencies (one batch in flight — the
    per-batch span cost is NOT hidden under pipelining)."""
    out = []
    for ch in chunks:
        t0 = time.perf_counter()
        eng.submit_chunk(ch).result(timeout=600)
        out.append(time.perf_counter() - t0)
    return out


def run(requests: int = 1024, batch_size: int = 8, scale: float = 0.01,
        receptive_field: int = 32, zipf_a: float = 1.1, seed: int = 0,
        dataset: str = "flickr",
        trace_out: str = "results/trace_local.json") -> dict:
    import jax

    from repro.gnn.model import init_gnn

    g = get_graph(dataset, scale=scale, seed=seed)
    cfg = GNNConfig(kind="gcn", n_layers=2,
                    receptive_field=receptive_field, f_in=g.feature_dim)
    params = init_gnn(cfg, jax.random.PRNGKey(seed))
    traffic = zipf_traffic(g, requests, zipf_a, seed + 1)
    chunks = [traffic[i:i + batch_size]
              for i in range(0, len(traffic) - batch_size + 1,
                             batch_size)]
    warm = chunks[:max(8, len(chunks) // 4)]
    meas = chunks[len(warm):]
    per_round = max(1, len(meas) // ROUNDS)
    print(f"graph: V={g.num_vertices} | {len(meas)} measured batches, "
          f"C={batch_size} N={receptive_field}, {ROUNDS} alternating "
          f"rounds per mode")

    base = ServingConfig(batch_size=batch_size, num_threads=2)
    engines = {
        "untraced": DecoupledEngine(g, cfg, params=params, config=base),
        "traced": DecoupledEngine(
            g, cfg, params=params,
            config=ServingConfig(batch_size=batch_size, num_threads=2,
                                 trace=TraceConfig())),
        "metered": DecoupledEngine(
            g, cfg, params=params,
            config=ServingConfig(batch_size=batch_size, num_threads=2,
                                 telemetry=TelemetryConfig())),
    }
    lat = {name: [] for name in engines}
    try:
        check = np.concatenate(chunks[:4])
        refs = {name: eng.infer(check, overlap=False).embeddings
                for name, eng in engines.items()}
        np.testing.assert_array_equal(refs["untraced"], refs["traced"])
        np.testing.assert_array_equal(refs["untraced"], refs["metered"])
        for name, eng in engines.items():       # compile + warm caches
            _drive(eng, warm)
        for r in range(ROUNDS):                 # interleave the modes
            block = meas[r * per_round:(r + 1) * per_round]
            for name, eng in engines.items():
                lat[name].extend(_drive(eng, block))
        traced = engines["traced"]
        rep = traced.trace_report()
        tree = traced.export_trace(trace_out)
        exposition = engines["metered"].metrics_text()
        n_series = exposition.count("# TYPE")
    finally:
        for eng in engines.values():
            eng.close()

    problems = validate_chrome_trace(tree)
    assert problems == [], f"chrome trace invalid: {problems[:5]}"
    expo_problems = validate_exposition(exposition)
    assert expo_problems == [], \
        f"exposition invalid: {expo_problems[:5]}"
    p = {name: {q: float(np.percentile(v, q))
                for q in (50, 90, 99)} for name, v in lat.items()}
    overhead = p["traced"][50] / p["untraced"][50] - 1.0
    m_overhead = p["metered"][50] / p["untraced"][50] - 1.0
    rows = [{"mode": name,
             "p50_ms": round(p[name][50] * 1e3, 3),
             "p90_ms": round(p[name][90] * 1e3, 3),
             "p99_ms": round(p[name][99] * 1e3, 3),
             "batches": len(lat[name])} for name in lat]
    print_table(rows, ["mode", "p50_ms", "p90_ms", "p99_ms", "batches"])
    print(f"tracing p50 overhead: {overhead:+.2%}, metrics "
          f"{m_overhead:+.2%} (bar {OVERHEAD_BAR:.0%}) | "
          f"{rep['spans']} spans recorded, ring dropped "
          f"{rep['spans_dropped']} | {n_series} metric families "
          f"exposed, format valid")
    print(f"bitwise traced == metered == untraced OK; chrome trace "
          f"valid -> {trace_out}")
    for e in rep["flight"]["slowest"][:3]:
        print(f"  flight: seq={e['meta'].get('seq')} "
              f"dur={e['dur'] * 1e3:.3f}ms spans={e['spans']}")
    assert overhead < OVERHEAD_BAR, (
        f"tracing adds {overhead:.2%} to p50 "
        f"({p['traced'][50] * 1e3:.3f}ms vs "
        f"{p['untraced'][50] * 1e3:.3f}ms); bar is {OVERHEAD_BAR:.0%}")
    assert m_overhead < OVERHEAD_BAR, (
        f"metrics add {m_overhead:.2%} to p50 "
        f"({p['metered'][50] * 1e3:.3f}ms vs "
        f"{p['untraced'][50] * 1e3:.3f}ms); bar is {OVERHEAD_BAR:.0%}")

    payload = {"rows": rows, "p50_overhead": round(overhead, 4),
               "metrics_p50_overhead": round(m_overhead, 4),
               "metric_families": n_series,
               "overhead_bar": OVERHEAD_BAR,
               "spans": rep["spans"],
               "spans_dropped": rep["spans_dropped"],
               "hists": {k: {"count": v["count"],
                             "p50": v["p50"], "p99": v["p99"]}
                         for k, v in rep["hists"].items()},
               "requests": requests, "batch_size": batch_size,
               "receptive_field": receptive_field,
               "num_vertices": g.num_vertices}
    record_trajectory(
        "obs", payload,
        regress={"traced_p50_ms": p["traced"][50] * 1e3,
                 "metered_p50_ms": p["metered"][50] * 1e3})
    return payload


def run_calibration(requests: int = 64, batch_size: int = 8,
                    scale: float = 0.004, receptive_field: int = 16,
                    seed: int = 0, dataset: str = "flickr") -> dict:
    """Per-ACK-op measured-latency table: every traced batch also runs
    the instrumented eager pass (calibrate_every=1), bucketing step
    walltimes by op x impl/mode x size. This is the measured-cost input
    the ROADMAP's cost-model dispatch wants."""
    import jax

    from repro.gnn.model import init_gnn

    g = get_graph(dataset, scale=scale, seed=seed)
    cfg = GNNConfig(kind="gcn", n_layers=2,
                    receptive_field=receptive_field, f_in=g.feature_dim)
    params = init_gnn(cfg, jax.random.PRNGKey(seed))
    traffic = zipf_traffic(g, requests, 1.1, seed + 1)
    sc = ServingConfig(batch_size=batch_size, num_threads=2,
                       trace=TraceConfig(calibrate_every=1))
    with DecoupledEngine(g, cfg, params=params, config=sc) as eng:
        eng.infer(traffic)
        rep = eng.trace_report()
    rows = rep.get("calibration", {}).get("rows", [])
    assert rows, "calibration pass produced no rows"
    for r in rows:
        r["mean_us"] = round(r.pop("mean_s") * 1e6, 1)
        r["p50_us"] = round(r.pop("p50_s") * 1e6, 1)
        r["p99_us"] = round(r.pop("p99_s") * 1e6, 1)
    print_table(rows, ["op", "mode", "size_bucket", "count",
                       "mean_us", "p50_us", "p99_us"])
    return {"rows": rows,
            "passes": rep["calibration"]["passes"]}


def run_suite(quick: bool = True):
    """benchmarks.run harness entry (quick == CI obs-smoke shape)."""
    if quick:
        payload = run(requests=512, batch_size=8, scale=0.004,
                      receptive_field=16)
    else:
        payload = run()
    payload["calibration"] = run_calibration()
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=1024)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph + few requests (CI obs-smoke gate)")
    a = ap.parse_args()
    if a.smoke:
        run_suite(quick=True)
    else:
        run(requests=a.requests, batch_size=a.batch_size)
        run_calibration()
