"""Multi-model streaming serving benchmark (paper §4.4/§4.5 at serving
scale): ONE GNNServer hosting GCN + GraphSAGE + GAT engines over one graph
under a single shared DSEPlan, fed a mixed open-loop request stream.

Reports, per model: request latency p50/p90/p99, batch latency, achieved
host/device overlap fraction of its persistent pipeline — plus aggregate
throughput and the shared plan the models were admitted under.

    python benchmarks/bench_serve_multimodel.py [--smoke] [--requests N]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import print_table, record_trajectory
from repro.core.config import ServingConfig
from repro.core.engine import DecoupledEngine
from repro.gnn.model import GNNConfig
from repro.graphs.synthetic import get_graph
from repro.serve.gnn_server import GNNServer

MODEL_KINDS = ("gcn", "sage", "gat")


def run(requests: int = 384, batch_size: int = 16, scale: float = 0.03,
        receptive_field: int = 64, rate_rps: float = 0.0, seed: int = 0):
    g = get_graph("flickr", scale=scale, seed=seed)
    engines = {}
    for kind in MODEL_KINDS:
        cfg = GNNConfig(kind=kind, n_layers=2,
                        receptive_field=receptive_field,
                        f_in=g.feature_dim)
        engines[kind] = DecoupledEngine(
            g, cfg, config=ServingConfig(batch_size=batch_size))

    srv = GNNServer(max_wait_s=0.02)
    for kind, eng in engines.items():
        srv.register(kind, eng)
    print(f"shared plan: BF={srv.plan.block_f} c_core={srv.plan.c_core} "
          f"vmem={srv.plan.vmem_used >> 10}KiB "
          f"models={sorted(srv.models)}")
    srv.start()

    # warm each model's compiled program out of the measurement
    for kind in MODEL_KINDS:
        engines[kind].infer(np.zeros(batch_size, np.int64), overlap=False)

    rng = np.random.default_rng(seed + 1)
    kinds = rng.choice(MODEL_KINDS, size=requests)
    targets = rng.integers(0, g.num_vertices, size=requests)
    gap = 1.0 / rate_rps if rate_rps > 0 else 0.0
    t0 = time.perf_counter()
    reqs = []
    for k, t in zip(kinds, targets):
        reqs.append(srv.submit(int(t), model=str(k)))
        if gap:
            time.sleep(gap)
    srv.drain(reqs, timeout=1200)
    wall = time.perf_counter() - t0
    srv.stop()

    rep = srv.report()
    rows = []
    for kind in MODEL_KINDS:
        m = rep["models"][kind]
        lat = m["latency"]
        rows.append({"model": kind, "n": lat["n"],
                     "p50_ms": round(lat["p50"] * 1e3, 2),
                     "p90_ms": round(lat["p90"] * 1e3, 2),
                     "p99_ms": round(lat["p99"] * 1e3, 2),
                     "batch_ms": round(lat["batch_mean"] * 1e3, 2),
                     "overlap": m["stages"]["overlap"],
                     "sched_batches": m["stages"]["batches"]})
    print_table(rows, ["model", "n", "p50_ms", "p90_ms", "p99_ms",
                       "batch_ms", "overlap", "sched_batches"])
    print(f"\n{requests} requests over {len(MODEL_KINDS)} models in "
          f"{wall:.2f}s ({requests / wall:.0f} req/s aggregate)")
    payload = {"rows": rows, "wall_s": wall,
               "req_per_s": requests / wall, "plan": rep["plan"],
               "batch_size": batch_size, "requests": requests}
    record_trajectory("serve_multimodel", payload)
    for eng in engines.values():
        eng.close()
    return payload


def run_suite(quick: bool = True):
    """benchmarks.run harness entry (quick == CI smoke shape)."""
    if quick:
        return run(requests=48, batch_size=8, scale=0.01,
                   receptive_field=32)
    return run()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=384)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--rate-rps", type=float, default=0.0,
                    help="open-loop arrival rate; 0 = as fast as possible")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph + few requests (CI canary)")
    a = ap.parse_args()
    if a.smoke:
        run_suite(quick=True)
    else:
        run(requests=a.requests, batch_size=a.batch_size,
            rate_rps=a.rate_rps)
