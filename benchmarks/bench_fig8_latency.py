"""Fig. 8 reproduction: decoupled mini-batch inference latency per batch
vs (model, L, N). Batch size 64, hidden 256 (paper §5.2).

The paper's claim being checked: latency grows ~LINEARLY in L at fixed N
(vs the coupled model's exponential growth — bench_fig3), and sub-
quadratically in N. Absolute numbers are container-CPU wall clock; the
modeled TPU-v5e latency from the DSE cost model is reported next to them.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (QUICK_SCALE, print_table, record_trajectory,
                               timeit)
from repro.core.dse import TPUSpec, layer_costs
from repro.core.config import ServingConfig
from repro.core.engine import DecoupledEngine
from repro.gnn.model import GNNConfig
from repro.graphs.synthetic import get_graph


def modeled_tpu_latency(cfg: GNNConfig, batch: int) -> float:
    spec = TPUSpec()
    per_target = sum(
        max(c["t_compute"], c["t_memory"]) for c in
        [layer_costs(cfg, cfg.receptive_field, cfg.f_in, cfg.f_hidden,
                     spec, section="layer0")]
        + [layer_costs(cfg, cfg.receptive_field, cfg.f_hidden,
                       cfg.f_hidden, spec, section="inner")]
        * (cfg.n_layers - 1))
    return per_target * batch   # one chip, C sequential grid cells


def run(quick: bool = True):
    g = get_graph("flickr", scale=QUICK_SCALE["flickr"])
    batch = 64
    models = ["gcn", "sage", "gat"]
    layers = [3, 5] if quick else [3, 5, 8, 16]
    fields = [64, 128] if quick else [64, 128, 256]
    rows = []
    rng = np.random.default_rng(0)
    targets = rng.integers(0, g.num_vertices, size=batch)
    for kind in models:
        for L in layers:
            for N in fields:
                cfg = GNNConfig(kind=kind, n_layers=L, receptive_field=N,
                                f_in=g.feature_dim)
                with DecoupledEngine(
                        g, cfg,
                        config=ServingConfig(batch_size=batch)) as eng:
                    t = timeit(lambda: eng.infer(targets), warmup=1,
                               iters=2 if quick else 3)
                rows.append({
                    "model": kind, "L": L, "N": N,
                    "latency_ms": round(t["min_s"] * 1e3, 2),
                    "modeled_tpu_ms": round(
                        modeled_tpu_latency(cfg, batch) * 1e3, 4),
                })
    # linear-in-L check per (model, N)
    checks = []
    for kind in models:
        for N in fields:
            sub = [r for r in rows if r["model"] == kind and r["N"] == N]
            if len(sub) >= 2:
                l_lo, l_hi = sub[0], sub[-1]
                growth = l_hi["latency_ms"] / max(l_lo["latency_ms"], 1e-9)
                ratio_L = l_hi["L"] / l_lo["L"]
                checks.append({"model": kind, "N": N,
                               "lat_growth": round(growth, 2),
                               "L_growth": ratio_L,
                               "subexponential": growth < ratio_L ** 2})
    print_table(rows, ["model", "L", "N", "latency_ms", "modeled_tpu_ms"])
    print_table(checks, ["model", "N", "lat_growth", "L_growth",
                         "subexponential"])
    payload = {"rows": rows, "linearity": checks, "batch": batch,
               "graph": {"v": g.num_vertices, "e": g.num_edges}}
    record_trajectory("fig8_latency", payload)
    return payload


if __name__ == "__main__":
    run(quick=False)
