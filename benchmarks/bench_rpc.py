"""Multi-host RPC serving benchmark: local vs loopback vs socket.

Two questions the transport layer must answer:

1. Is the remote path the local path? Bitwise equality of embeddings
   over the first 20 batches is asserted on EVERY run across all four
   deployments — that is the CI rpc-smoke gate.
2. How much of the host<->host hop does the staged pipeline hide? On a
   single machine the loopback RTT is ~0, so the hop is isolated by
   running the SAME socket deployment twice: once plain, once against a
   graph host injecting a known link RTT per call (``--delay-ms``, a
   GIL-releasing sleep). The CPU work is identical on both sides of the
   subtraction, so

       added_closed = closed_loop(rtt) - closed_loop(plain)   ~ RTT
       added_piped  = pipelined(rtt)  - pipelined(plain)

   and the overlap recovery ``1 - added_piped / added_closed`` is the
   fraction of the hop the remote stage's concurrent in-flight calls
   hide under pipelined traffic. Acceptance bar: >= 50%.

Deployments of the same (graph, model, params):

  local        Select/Build in-process (the baseline)
  inproc       loopback transport — full wire codec, one process
  socket       graph host SUBPROCESS over TCP, zero injected RTT
  socket+rtt   same, with the simulated link RTT per call

Appends ``results/BENCH_rpc.json``.

``--trace out.json`` additionally runs a TRACED socket deployment
against a live graph-host subprocess and exports a Perfetto-loadable
chrome trace: the graph host's remote.select/remote.build spans are
stitched (after ping-based clock-offset correction) INSIDE the client's
select_build rpc span — the two-process timeline the paper's Fig. 7
overlap claim needs. The run asserts bitwise equality vs local, zero
chrome-trace validation problems, and zero containment violations.

    python benchmarks/bench_rpc.py [--smoke] [--requests N] [--rtt-ms R]
    python benchmarks/bench_rpc.py --trace results/trace.json
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import print_table, record_trajectory
from repro.core.config import ServingConfig
from repro.core.engine import DecoupledEngine
from repro.gnn.model import GNNConfig
from repro.graphs.synthetic import get_graph, zipf_traffic
from repro.store import StorePolicy

BITWISE_BATCHES = 20


def spawn_graph_host(dataset: str, scale: float, seed: int,
                     num_threads: int = 2, delay_ms: float = 0.0):
    """Launch a graph-host subprocess on an ephemeral port; the child
    rebuilds the identical synthetic graph from (dataset, scale, seed)."""
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [src] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.distributed.graph_host",
         "--dataset", dataset, "--scale", str(scale),
         "--seed", str(seed), "--port", "0",
         "--num-threads", str(num_threads),
         "--delay-ms", str(delay_ms)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True)
    t0 = time.time()
    while True:
        line = proc.stdout.readline()
        if line.startswith("GRAPH_HOST_LISTENING"):
            _, host, port = line.split()
            return proc, f"{host}:{port}"
        if proc.poll() is not None or time.time() - t0 > 120:
            proc.kill()
            raise RuntimeError(f"graph host failed to start: {line!r}")


def measure(eng, traffic: np.ndarray, c: int, pipelined: bool) -> dict:
    """Drive one engine over the traffic stream. pipelined=False keeps
    one batch in flight (closed loop — every batch pays the full hop);
    pipelined=True submits everything and lets the scheduler overlap
    stations and in-flight remote calls."""
    chunks = [traffic[i:i + c] for i in range(0, len(traffic) - c + 1, c)]
    s = eng.scheduler.stats
    base_wall = s.t_rpc_wall
    t0 = time.perf_counter()
    if pipelined:
        for t in [eng.submit_chunk(ch) for ch in chunks]:
            t.result(timeout=600)
    else:
        for ch in chunks:
            eng.submit_chunk(ch).result(timeout=600)
    wall = time.perf_counter() - t0
    return {"batches": len(chunks),
            "batch_ms": wall / len(chunks) * 1e3,
            "req_per_s": len(chunks) * c / wall,
            "rpc_wall_ms": (s.t_rpc_wall - base_wall)
            / len(chunks) * 1e3}


def run(requests: int = 2048, batch_size: int = 8, scale: float = 0.01,
        receptive_field: int = 32, zipf_a: float = 1.1, seed: int = 0,
        rtt_ms: float = 5.0, dataset: str = "flickr") -> dict:
    import jax

    from repro.gnn.model import init_gnn

    g = get_graph(dataset, scale=scale, seed=seed)
    cfg = GNNConfig(kind="gcn", n_layers=2,
                    receptive_field=receptive_field, f_in=g.feature_dim)
    params = init_gnn(cfg, jax.random.PRNGKey(seed))
    traffic = zipf_traffic(g, requests, zipf_a, seed + 1)
    warm = traffic[:max(batch_size * 8, len(traffic) // 4)]
    meas = traffic[len(warm):]
    check = np.concatenate(
        [traffic[i:i + batch_size] for i in
         range(0, BITWISE_BATCHES * batch_size, batch_size)])
    print(f"graph: V={g.num_vertices} f={g.feature_dim} | "
          f"Zipf({zipf_a}) {requests} requests ({len(warm)} warmup), "
          f"C={batch_size} N={receptive_field} | simulated link RTT "
          f"{rtt_ms}ms")

    store = StorePolicy(features="resident", nbr_cache="lru",
                        nbr_capacity=1024)
    base = ServingConfig(batch_size=batch_size, num_threads=2,
                         store=store, rpc_timeout_s=300.0)
    hosts = {
        "socket": spawn_graph_host(dataset, scale, seed),
        "socket+rtt": spawn_graph_host(dataset, scale, seed,
                                       delay_ms=rtt_ms),
    }
    configs = {
        "local": base,
        "inproc": dataclasses.replace(base, transport="inproc"),
        **{name: dataclasses.replace(base, transport="socket",
                                     endpoints=(ep,))
           for name, (_, ep) in hosts.items()},
    }
    rows, refs, rpc_stats = [], {}, {}
    try:
        for name, sc in configs.items():
            with DecoupledEngine(g, cfg, params=params,
                                 config=sc) as eng:
                refs[name] = eng.infer(check, overlap=False).embeddings
                for ch in range(0, len(warm) - batch_size + 1,
                                batch_size):          # compile + caches
                    eng.submit_chunk(
                        warm[ch:ch + batch_size]).result(timeout=600)
                closed = measure(eng, meas, batch_size, pipelined=False)
                piped = measure(eng, meas, batch_size, pipelined=True)
                row = {"deployment": name,
                       "closed_ms": round(closed["batch_ms"], 3),
                       "piped_ms": round(piped["batch_ms"], 3),
                       "req_per_s": round(piped["req_per_s"], 1),
                       "rpc_wall_ms": round(closed["rpc_wall_ms"], 3)}
                s = eng.scheduler.stats
                if s.rpc_calls:
                    rpc_stats[name] = s.summary()["rpc"]
                    row["kb_out"] = round(
                        s.rpc_bytes_out / s.rpc_calls / 1024, 1)
                    row["kb_in"] = round(
                        s.rpc_bytes_in / s.rpc_calls / 1024, 1)
                rows.append(row)
                print(f"  [{name}] closed={row['closed_ms']}ms "
                      f"piped={row['piped_ms']}ms "
                      f"({row['req_per_s']} req/s)", flush=True)
    finally:
        for proc, _ in hosts.values():
            proc.kill()
            proc.wait(timeout=10)

    # the CI gate: the remote path IS the local path, bitwise, over
    # every transport (loopback, TCP, TCP behind a slow link)
    for name in ("inproc", "socket", "socket+rtt"):
        np.testing.assert_array_equal(refs[name], refs["local"])
    print(f"bitwise: all deployments == local over "
          f"{BITWISE_BATCHES} batches OK")

    # hop-hiding: same deployment, same CPU work — the only difference
    # between socket and socket+rtt is the known injected RTT
    by = {r["deployment"]: r for r in rows}
    added_closed = by["socket+rtt"]["closed_ms"] - by["socket"]["closed_ms"]
    added_piped = by["socket+rtt"]["piped_ms"] - by["socket"]["piped_ms"]
    recovery = 1.0 - max(0.0, added_piped) / max(added_closed, 1e-9)
    print(f"added hop latency ({rtt_ms}ms RTT): closed-loop "
          f"+{added_closed:.3f}ms/batch, pipelined "
          f"+{added_piped:.3f}ms/batch -> overlap hides {recovery:.0%}")
    assert recovery >= 0.5, (
        f"pipelining hides only {recovery:.0%} of the hop "
        f"(closed +{added_closed:.3f}ms vs piped +{added_piped:.3f}ms); "
        "acceptance bar is 50%")

    print()
    print_table(rows, ["deployment", "closed_ms", "piped_ms",
                       "req_per_s", "rpc_wall_ms", "kb_out", "kb_in"])
    payload = {"rows": rows, "overlap_recovery": round(recovery, 3),
               "rtt_ms": rtt_ms,
               "added_closed_ms": round(added_closed, 3),
               "added_piped_ms": round(added_piped, 3),
               "rpc": rpc_stats, "requests": requests,
               "batch_size": batch_size,
               "receptive_field": receptive_field,
               "bitwise_batches": BITWISE_BATCHES,
               "num_vertices": g.num_vertices, "zipf_a": zipf_a}
    record_trajectory("rpc", payload)
    return payload


def run_traced(out_path: str = "results/trace.json",
               requests: int = 64, batch_size: int = 8,
               scale: float = 0.004, receptive_field: int = 16,
               seed: int = 0, dataset: str = "flickr") -> dict:
    """Two-process traced run: device host here, graph host in a
    subprocess over TCP. Exports the stitched chrome trace to
    ``out_path`` and gates on bitwise equality, trace validity, and
    remote-span containment."""
    import jax

    from repro.gnn.model import init_gnn
    from repro.obs import TraceConfig, containment, validate_chrome_trace

    g = get_graph(dataset, scale=scale, seed=seed)
    cfg = GNNConfig(kind="gcn", n_layers=2,
                    receptive_field=receptive_field, f_in=g.feature_dim)
    params = init_gnn(cfg, jax.random.PRNGKey(seed))
    traffic = zipf_traffic(g, requests, 1.1, seed + 1)
    store = StorePolicy(features="resident", nbr_cache="lru",
                        nbr_capacity=1024)
    base = ServingConfig(batch_size=batch_size, num_threads=2,
                         store=store, rpc_timeout_s=300.0)
    with DecoupledEngine(g, cfg, params=params, config=base) as eng:
        ref = eng.infer(traffic, overlap=False).embeddings
    proc, ep = spawn_graph_host(dataset, scale, seed)
    try:
        sc = dataclasses.replace(base, transport="socket",
                                 endpoints=(ep,),
                                 trace=TraceConfig())
        with DecoupledEngine(g, cfg, params=params, config=sc) as eng:
            out = eng.infer(traffic).embeddings
            spans = eng.tracer.export_spans()
            rep = eng.trace_report()
            tree = eng.export_trace(out_path)
    finally:
        proc.kill()
        proc.wait(timeout=10)
    np.testing.assert_array_equal(ref, out)
    remote = [s for s in spans if s["host"].startswith("graph-host")]
    assert remote, "no remote spans stitched from the graph host"
    problems = validate_chrome_trace(tree)
    assert problems == [], f"chrome trace invalid: {problems[:5]}"
    violations = containment(spans, "select_build", remote[0]["host"])
    assert violations == [], (
        f"remote spans escape their rpc span after clock correction: "
        f"{violations[:3]}")
    sync = rep["clock_sync"][ep]
    print(f"traced socket run: {rep['tickets_traced']} batches, "
          f"{rep['spans']} spans ({len(remote)} remote from {ep}, "
          f"offset {sync['offset_s'] * 1e3:+.3f}ms "
          f"rtt {sync['rtt_s'] * 1e3:.3f}ms)")
    print(f"bitwise vs local OK; containment OK; chrome trace valid "
          f"-> {out_path} (open in https://ui.perfetto.dev)")
    return {"trace_path": out_path, "spans": rep["spans"],
            "remote_spans": len(remote),
            "tickets_traced": rep["tickets_traced"],
            "clock_sync": sync}


def run_suite(quick: bool = True):
    """benchmarks.run harness entry (quick == CI rpc-smoke shape). Both
    shapes finish with the traced two-process run: CI uploads the
    exported results/trace.json as an artifact."""
    if quick:
        payload = run(requests=512, batch_size=8, scale=0.004,
                      receptive_field=16)
    else:
        payload = run()
    payload["trace"] = run_traced()
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=2048)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--zipf", type=float, default=1.1)
    ap.add_argument("--rtt-ms", type=float, default=5.0,
                    help="simulated link RTT injected at the graph host")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph + few requests (CI rpc-smoke gate)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="ONLY run the traced two-process socket "
                         "deployment and export the stitched chrome "
                         "trace to PATH")
    a = ap.parse_args()
    if a.trace:
        run_traced(out_path=a.trace)
    elif a.smoke:
        run_suite(quick=True)
    else:
        run(requests=a.requests, batch_size=a.batch_size, zipf_a=a.zipf,
            rtt_ms=a.rtt_ms)
