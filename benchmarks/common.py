"""Shared benchmark utilities: timing, result persistence, dataset prep.

Result persistence is ONE writer: ``record_trajectory(name, payload)``
appends a timestamped record to the tracked append-only trajectory
``results/BENCH_<name>.json`` (a JSON list, one entry per run). The old
dual scheme — a per-run snapshot under ``results/bench/`` PLUS the
trajectory — left a stray untracked tree in every checkout; the
trajectory's newest entry IS the latest snapshot, so the snapshot dir is
gone. Pass ``regress={...}`` with lower-is-better scalars to gate the
run against its own history via ``python -m repro.obs.regress``.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, Optional

import numpy as np

RESULTS_DIR = os.environ.get("REPRO_BENCH_DIR", "results")

# container-scale dataset knobs (full-scale graphs exceed 1-core CPU time
# budgets; degree structure and feature dims are preserved)
QUICK_SCALE = {"flickr": 0.02, "ogbn-arxiv": 0.01, "reddit": 0.004}
FULL_SCALE = {"flickr": 0.2, "ogbn-arxiv": 0.1, "reddit": 0.02}


def timeit(fn: Callable, *, warmup: int = 1, iters: int = 3) -> Dict:
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    a = np.array(ts)
    return {"mean_s": float(a.mean()), "min_s": float(a.min()),
            "std_s": float(a.std()), "iters": iters}


def trajectory_path(name: str) -> str:
    """The tracked trajectory artifact for one suite, governed by
    REPRO_BENCH_DIR (default results/): results/BENCH_<name>.json."""
    return os.path.join(RESULTS_DIR, f"BENCH_{name}.json")


def record_trajectory(name: str, payload: dict,
                      regress: Optional[dict] = None) -> str:
    """Append one timestamped run record to the suite's trajectory (the
    ONE benchmark writer; created on first use, unreadable/corrupt files
    restart the list). ``regress`` carries this run's lower-is-better
    gate scalars for ``python -m repro.obs.regress``."""
    record = dict(payload, timestamp=time.strftime("%Y-%m-%dT%H:%M:%S"))
    if regress:
        record["regress"] = {k: float(v) for k, v in regress.items()}
    path = trajectory_path(name)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    runs = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                runs = json.load(f)
            if not isinstance(runs, list):
                runs = [runs]
        except (json.JSONDecodeError, OSError):
            runs = []
    runs.append(record)
    with open(path, "w") as f:
        json.dump(runs, f, indent=1, default=float)
    print(f"trajectory appended to {path}")
    return path


def print_table(rows, cols):
    widths = [max(len(str(r.get(c, ""))) for r in rows + [{c: c}])
              for c in cols]
    line = " | ".join(c.ljust(w) for c, w in zip(cols, widths))
    print(line)
    print("-+-".join("-" * w for w in widths))
    for r in rows:
        print(" | ".join(str(r.get(c, "")).ljust(w)
                         for c, w in zip(cols, widths)))
