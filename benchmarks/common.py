"""Shared benchmark utilities: timing, result persistence, dataset prep."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict

import numpy as np

RESULTS_DIR = os.environ.get("REPRO_BENCH_DIR", "results/bench")

# container-scale dataset knobs (full-scale graphs exceed 1-core CPU time
# budgets; degree structure and feature dims are preserved)
QUICK_SCALE = {"flickr": 0.02, "ogbn-arxiv": 0.01, "reddit": 0.004}
FULL_SCALE = {"flickr": 0.2, "ogbn-arxiv": 0.1, "reddit": 0.02}


def timeit(fn: Callable, *, warmup: int = 1, iters: int = 3) -> Dict:
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    a = np.array(ts)
    return {"mean_s": float(a.mean()), "min_s": float(a.min()),
            "std_s": float(a.std()), "iters": iters}


def save_result(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def trajectory_path(name: str) -> str:
    """Per-suite trajectory artifact beside the per-run payload dir,
    governed by the SAME knob (REPRO_BENCH_DIR via RESULTS_DIR):
    default results/bench/ -> results/BENCH_<name>.json."""
    return os.path.join(os.path.dirname(RESULTS_DIR.rstrip("/")) or ".",
                        f"BENCH_{name}.json")


def append_trajectory(record: dict, path: str):
    """Append one run record to a JSON-list trajectory file (created on
    first use; unreadable/corrupt files restart the list)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    runs = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                runs = json.load(f)
            if not isinstance(runs, list):
                runs = [runs]
        except (json.JSONDecodeError, OSError):
            runs = []
    runs.append(record)
    with open(path, "w") as f:
        json.dump(runs, f, indent=1, default=float)
    return path


def print_table(rows, cols):
    widths = [max(len(str(r.get(c, ""))) for r in rows + [{c: c}])
              for c in cols]
    line = " | ".join(c.ljust(w) for c, w in zip(cols, widths))
    print(line)
    print("-+-".join("-" * w for w in widths))
    for r in rows:
        print(" | ".join(str(r.get(c, "")).ljust(w)
                         for c, w in zip(cols, widths)))
