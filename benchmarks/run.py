"""Benchmark harness entry point: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # quick pass
  PYTHONPATH=src python -m benchmarks.run --full     # paper-size sweeps
  PYTHONPATH=src python -m benchmarks.run --only fig8_latency

Roofline/dry-run numbers live in launch/dryrun.py + launch/roofline.py
(they need the 512-device env var and are run as their own processes).
"""
from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import (bench_eq1_loadbalance, bench_fig3_breakdown,
                        bench_fig8_latency, bench_fig10_batch,
                        bench_kernels, bench_obs, bench_pipeline,
                        bench_precompute, bench_program, bench_rpc,
                        bench_serve_multimodel, bench_shard,
                        bench_store, bench_table5_load, bench_table6_ini)

SUITES = {
    "fig8_latency": bench_fig8_latency.run,
    "fig10_batch": bench_fig10_batch.run,
    "fig3_breakdown": bench_fig3_breakdown.run,
    "table5_load": bench_table5_load.run,
    "table6_ini": bench_table6_ini.run,
    "eq1_loadbalance": bench_eq1_loadbalance.run,
    "kernels": bench_kernels.run,
    "serve_multimodel": bench_serve_multimodel.run_suite,
    "store": bench_store.run_suite,
    "program": bench_program.run_suite,
    "shard": bench_shard.run_suite,
    "pipeline": bench_pipeline.run_suite,
    "rpc": bench_rpc.run_suite,
    "obs": bench_obs.run_suite,
    "precompute": bench_precompute.run_suite,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    names = [args.only] if args.only else list(SUITES)
    failed = []
    for name in names:
        print(f"\n=== {name} {'(full)' if args.full else '(quick)'} ===",
              flush=True)
        t0 = time.time()
        try:
            SUITES[name](quick=not args.full)
            print(f"[{name}] done in {time.time()-t0:.1f}s", flush=True)
        except Exception:   # noqa: BLE001 — report all suites
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"\nFAILED suites: {failed}")
        return 1
    print("\nall benchmark suites passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
