"""Quickstart: low-latency mini-batch GNN inference with a Decoupled model.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's Algorithm 2/3 end to end on a synthetic Flickr-scale
graph: PPR important-neighbor identification on the host, fixed-shape
subgraph batches, and the jitted ACK inference program, with the
triple-buffered host/device pipeline hiding preparation latency.
"""
import numpy as np

from repro.core.config import ServingConfig
from repro.core.engine import DecoupledEngine
from repro.gnn.model import GNNConfig
from repro.graphs.synthetic import get_graph

# 1. graph (synthetic stand-in for Flickr: 500-dim features, power-law)
g = get_graph("flickr", scale=0.05, seed=0)
print(f"graph: {g.num_vertices} vertices, {g.num_edges} edges, "
      f"f_in={g.feature_dim}")

# 2. a Decoupled GraphSAGE: depth L=5 with a FIXED receptive field N=128
#    (depth and receptive field are independent — the paper's key idea)
cfg = GNNConfig(kind="sage", n_layers=5, receptive_field=128,
                f_in=g.feature_dim)

# 3. engine: host INI + subgraph build, device = one jitted ACK program
engine = DecoupledEngine(g, cfg, config=ServingConfig(batch_size=64))
print(f"model {cfg.display}; ACK mode = {engine.mode} "
      f"({engine.decision.summary}; {engine.decision.reason})")

# 4. mini-batch inference for 128 target vertices
targets = np.random.default_rng(0).integers(0, g.num_vertices, size=128)
result = engine.infer(targets)

print(f"embeddings: {result.embeddings.shape} "
      f"(finite: {np.isfinite(result.embeddings).all()})")
s = result.stats.summary()
lat = s["latency"]
print(f"latency: {lat['t_wall']*1e3:.1f} ms wall for {len(targets)} targets "
      f"({lat['t_wall']*1e6/len(targets):.0f} us/target)")
print(f"host/device overlap: {s['stages']['overlap']:.0%} of prep hidden "
      f"(t_init {lat['t_init']*1e3:.1f} ms, paper's Fig. 7 scheduling)")
