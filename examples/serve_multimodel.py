"""Serve SEVERAL GNN models from one server under one shared DSE plan —
the paper's "single accelerator configuration, many models" deployment
(§4.5; pushed further by GraphAGILE) as a runnable example.

    python examples/serve_multimodel.py [--requests 300]

Three engines (GCN, GraphSAGE, GAT) register on one graph; the server
recomputes the shared plan over the model set at each registration and
rejects models that don't fit it. Requests route by model name into
per-model micro-batchers that stream into each engine's persistent
pipeline; the report shows per-model tail latency and overlap.
"""
import argparse
import time

import numpy as np

from repro.core.config import ServingConfig
from repro.core.engine import DecoupledEngine
from repro.gnn.model import GNNConfig
from repro.graphs.synthetic import get_graph
from repro.serve.gnn_server import GNNServer

ap = argparse.ArgumentParser()
ap.add_argument("--requests", type=int, default=300)
ap.add_argument("--batch-size", type=int, default=16)
args = ap.parse_args()

g = get_graph("flickr", scale=0.03, seed=0)
kinds = ("gcn", "sage", "gat")

server = GNNServer(max_wait_s=0.02)
for kind in kinds:
    cfg = GNNConfig(kind=kind, n_layers=2, receptive_field=64,
                    f_in=g.feature_dim)
    server.register(kind, graph=g, cfg=cfg,
                    config=ServingConfig(batch_size=args.batch_size))
print(f"registered {list(server.models)} under one plan: "
      f"BF={server.plan.block_f}, c_core={server.plan.c_core}, "
      f"vmem={server.plan.vmem_used >> 10} KiB")
server.start()

# precompile each model's program (a deployment would do this at startup)
for kind in kinds:
    server.engine_for(kind).infer(np.zeros(args.batch_size, np.int64),
                                  overlap=False)

rng = np.random.default_rng(1)
t0 = time.perf_counter()
reqs = [server.submit(int(t), model=str(k))
        for k, t in zip(rng.choice(kinds, args.requests),
                        rng.integers(0, g.num_vertices, args.requests))]
server.drain(reqs, timeout=1200)
wall = time.perf_counter() - t0
server.stop()

rep = server.report()
print(f"\nserved {args.requests} requests across {len(kinds)} models "
      f"in {wall:.2f}s ({args.requests / wall:.0f} req/s)")
for kind in kinds:
    m = rep["models"][kind]
    lat = m["latency"]
    print(f"  {kind:5s} n={lat['n']:4d}  p50 {lat['p50'] * 1e3:7.1f} ms  "
          f"p99 {lat['p99'] * 1e3:7.1f} ms  "
          f"overlap {m['stages']['overlap']:.2f}")
r = reqs[0]
print(f"\nsample: vertex {r.target} via {r.model} -> "
      f"embedding[:4] = {np.round(r.embedding[:4], 3)}")
