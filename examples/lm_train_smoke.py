"""Train a reduced assigned-architecture LM end to end on CPU with the
fault-tolerant loop: checkpoints, kill, resume — the 1000-node story at
smoke scale.

    PYTHONPATH=src python examples/lm_train_smoke.py \
        [--arch deepseek-v2-lite-16b] [--steps 60]
"""
import argparse
import shutil
import tempfile

from repro.ckpt import checkpoint as ckpt
from repro.configs.registry import get_config
from repro.train.loop import TrainJobConfig, train

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="deepseek-v2-lite-16b")
ap.add_argument("--steps", type=int, default=60)
args = ap.parse_args()

cfg = get_config(args.arch, reduced=True)
print(f"arch {cfg.name} (reduced: {cfg.n_layers}L d{cfg.d_model}); "
      f"family={cfg.family}")
ckpt_dir = tempfile.mkdtemp(prefix="repro_lm_")
job = TrainJobConfig(steps=args.steps, ckpt_every=args.steps // 3,
                     ckpt_dir=ckpt_dir, seq_len=64, global_batch=4)

print("phase 1: train until an injected failure ...")
try:
    train(cfg, job, fail_at_step=args.steps // 2)
except RuntimeError as e:
    print(f"  {e}")
print(f"  committed checkpoints: {ckpt.committed_steps(ckpt_dir)}")

print("phase 2: restart — resumes from the latest checkpoint ...")
_, _, hist = train(cfg, job)
print(f"  resumed at step {hist[0]['step']}, "
      f"finished at step {hist[-1]['step']}")
print(f"  loss: start {hist[0]['loss']:.3f} -> end {hist[-1]['loss']:.3f}")
assert hist[-1]["loss"] < hist[0]["loss"] + 0.5
shutil.rmtree(ckpt_dir, ignore_errors=True)
print("done: loss continued across the restart (deterministic pipeline)")
