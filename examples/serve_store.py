"""Serve one GNN with the two-level store subsystem switched on — device
feature store (full-resident) + host neighborhood cache — against Zipf-
skewed traffic, and read the cache/transfer stats off the server report.

    python examples/serve_store.py [--requests 400] [--zipf 1.1]

The engine pins the graph's feature matrix in device memory at start, so
each batch ships an int32 slot map instead of dense [C, N, f] rows; hot
targets' PPR neighborhoods come out of the LRU cache instead of re-running
local push. ``invalidate()`` shows the graph-update hook forcing a
recompute for affected targets.
"""
import argparse
import time

import numpy as np

from repro.core.config import ServingConfig
from repro.core.engine import DecoupledEngine
from repro.gnn.model import GNNConfig
from repro.graphs.synthetic import get_graph, zipf_traffic
from repro.serve.gnn_server import GNNServer
from repro.store import StorePolicy

ap = argparse.ArgumentParser()
ap.add_argument("--requests", type=int, default=400)
ap.add_argument("--batch-size", type=int, default=8)
ap.add_argument("--zipf", type=float, default=1.1)
args = ap.parse_args()

g = get_graph("flickr", scale=0.005, seed=0)
cfg = GNNConfig(kind="gcn", n_layers=2, receptive_field=32,
                f_in=g.feature_dim)
policy = StorePolicy(features="resident", nbr_cache="lru",
                     nbr_capacity=512)
engine = DecoupledEngine(g, cfg, config=ServingConfig(
    batch_size=args.batch_size, store=policy))

server = GNNServer(engine, max_wait_s=0.02)
server.start()
engine.infer(np.zeros(args.batch_size, np.int64), overlap=False)  # warm

# Zipf(a) popularity, hottest = highest degree (hub-heavy traffic)
targets = zipf_traffic(g, args.requests, a=args.zipf, seed=1)
t0 = time.perf_counter()
reqs = [server.submit(int(t)) for t in targets]
server.drain(reqs, timeout=1200)
wall = time.perf_counter() - t0
server.stop()

rep = server.report()["models"]["default"]
lat, store = rep["latency"], rep["store"]
print(f"served {args.requests} Zipf({args.zipf}) requests in {wall:.2f}s "
      f"({args.requests / wall:.0f} req/s)")
print(f"p50={lat['p50'] * 1e3:.1f}ms p99={lat['p99'] * 1e3:.1f}ms "
      f"overlap={rep['stages']['overlap']}")
print(f"nbr-cache hit rate: {store['cache_hit_rate']:.2%}  "
      f"transfer ratio: {store['transfer_ratio']:.3f} "
      f"(bytes shipped: {store['bytes_shipped'] >> 10} KiB)")
print("store:", store["features"])
print("nbr_cache:", store["nbr_cache"])

# graph-update hook: invalidating a hub forces recompute of every cached
# neighborhood that reaches it
hub = int(np.argmax(g.degrees))
dropped = engine.invalidate([hub])
print(f"\ninvalidate(hub={hub}) dropped {dropped} cached neighborhoods")
engine.close()
