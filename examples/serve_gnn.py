"""End-to-end serving driver (the paper's deployment): batched mini-batch
GNN inference requests against a trained Decoupled model, with latency
percentiles — the 'latency per batch' metric of paper §3.1/§5.3.

    PYTHONPATH=src python examples/serve_gnn.py [--requests 512]
"""
import argparse
import time

import numpy as np

from repro.core.config import ServingConfig
from repro.core.engine import DecoupledEngine
from repro.gnn.model import GNNConfig
from repro.gnn.train import train_gnn
from repro.graphs.synthetic import get_graph
from repro.serve.gnn_server import GNNServer

ap = argparse.ArgumentParser()
ap.add_argument("--requests", type=int, default=256)
ap.add_argument("--batch-size", type=int, default=32)
ap.add_argument("--train-steps", type=int, default=60)
args = ap.parse_args()

g = get_graph("flickr", scale=0.03, seed=0)
cfg = GNNConfig(kind="gcn", n_layers=3, receptive_field=64,
                f_in=g.feature_dim, num_classes=7)

# the paper serves PRE-TRAINED models: train one quickly first
print(f"training {cfg.display} for {args.train_steps} steps ...")
out = train_gnn(g, cfg, steps=args.train_steps, batch_size=16, lr=2e-3)
h0, h1 = out["history"][0], out["history"][-1]
print(f"  loss {h0['loss']:.3f} -> {h1['loss']:.3f}, "
      f"acc {h0['acc']:.2f} -> {h1['acc']:.2f}")

engine = DecoupledEngine(g, cfg, params=out["params"],
                         config=ServingConfig(batch_size=args.batch_size))
server = GNNServer(engine, max_wait_s=0.02)
server.start()

print(f"submitting {args.requests} requests ...")
rng = np.random.default_rng(1)
t0 = time.perf_counter()
reqs = [server.submit(int(t))
        for t in rng.integers(0, g.num_vertices, size=args.requests)]
server.drain(reqs, timeout=600)
wall = time.perf_counter() - t0
server.stop()

p = server.stats.percentiles()
print(f"\nserved {p['n']} requests in {wall:.2f}s "
      f"({p['n']/wall:.0f} req/s)")
print(f"request latency: p50 {p['p50']*1e3:.1f} ms, "
      f"p90 {p['p90']*1e3:.1f} ms, p99 {p['p99']*1e3:.1f} ms")
print(f"batch latency mean: {p['batch_mean']*1e3:.1f} ms "
      f"({server.stats.n_batches} batches)")
pred = np.argmax(reqs[0].embedding)
print(f"sample prediction for vertex {reqs[0].target}: class {pred} "
      f"(true {g.labels[reqs[0].target]})")
