"""Train a Decoupled GNN node classifier (produces the pre-trained weights
the paper's accelerator serves), a few hundred steps on CPU.

    PYTHONPATH=src python examples/train_gnn.py [--steps 200]
"""
import argparse

import numpy as np

from repro.gnn.model import GNNConfig
from repro.gnn.train import train_gnn
from repro.graphs.synthetic import get_graph

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--model", default="sage",
                choices=["gcn", "sage", "gin", "gat"])
ap.add_argument("--layers", type=int, default=3)
ap.add_argument("--receptive-field", type=int, default=64)
args = ap.parse_args()

g = get_graph("flickr", scale=0.03, seed=0)
cfg = GNNConfig(kind=args.model, n_layers=args.layers,
                receptive_field=args.receptive_field,
                f_in=g.feature_dim, num_classes=7)
print(f"training {cfg.display} on {g.name} "
      f"({g.num_vertices} vertices) ...")
out = train_gnn(g, cfg, steps=args.steps, batch_size=16, lr=2e-3,
                eval_every=50)
hist = out["history"]
first = np.mean([h["loss"] for h in hist[:20]])
last = np.mean([h["loss"] for h in hist[-20:]])
acc = np.mean([h["acc"] for h in hist[-20:]])
print(f"\nloss {first:.3f} -> {last:.3f}; final train acc {acc:.2f}; "
      f"{out['wall_s']:.1f}s total "
      f"({out['wall_s']/len(hist)*1e3:.0f} ms/step)")
assert last < first, "training did not reduce loss"
