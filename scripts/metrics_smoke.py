"""CI metrics smoke: serve, scrape the live endpoint, validate.

Boots a GNNServer with telemetry on (ephemeral exposition port) over an
``inproc`` graph host (full wire codec, one process — so the cluster
scrape path and graph-host registry both light up), drives enough
traffic to populate every instrumented site, then scrapes the real HTTP
endpoint the way Prometheus would and runs the in-repo exposition
validator over the body. Fails (exit 1 via assert) if the endpoint is
down, the text is malformed, or fewer than ``MIN_SERIES`` series show
up — the "did someone unplug a metric family" canary.

    python scripts/metrics_smoke.py
"""
from __future__ import annotations

import os
import sys
import urllib.request

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

MIN_SERIES = 20


def main() -> int:
    import jax

    from repro.core.config import ServingConfig
    from repro.gnn.model import GNNConfig, init_gnn
    from repro.graphs.synthetic import get_graph, zipf_traffic
    from repro.obs import TelemetryConfig, validate_exposition
    from repro.obs.metrics import series_count
    from repro.serve.gnn_server import GNNServer

    g = get_graph("flickr", scale=0.004, seed=0)
    cfg = GNNConfig(kind="gcn", n_layers=2, receptive_field=16,
                    f_in=g.feature_dim)
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    sc = ServingConfig(batch_size=8, num_threads=2, transport="inproc",
                      telemetry=TelemetryConfig(port=0, window_s=5.0))
    server = GNNServer(config=sc)
    server.register("gcn", graph=g, cfg=cfg, params=params)
    server.start()
    try:
        reqs = [server.submit(int(t), model="gcn")
                for t in zipf_traffic(g, 128, 1.1, 1)]
        server.drain(reqs, timeout=300.0)

        url = server.metrics_url
        assert url, "telemetry port configured but no endpoint mounted"
        with urllib.request.urlopen(url, timeout=10) as resp:
            assert resp.status == 200, f"GET {url} -> {resp.status}"
            ctype = resp.headers.get("Content-Type", "")
            body = resp.read().decode("utf-8")
        assert "version=0.0.4" in ctype, f"content-type: {ctype!r}"

        problems = validate_exposition(body)
        assert not problems, f"exposition invalid: {problems[:5]}"
        n = series_count(server.metrics_wire())
        families = sorted({ln.split()[2] for ln in body.splitlines()
                           if ln.startswith("# TYPE ")})
        print(f"scraped {url}: {n} series across {len(families)} "
              f"families, exposition valid")
        for fam in families:
            print(f"  {fam}")
        assert n >= MIN_SERIES, \
            f"only {n} series exposed (floor {MIN_SERIES})"
    finally:
        server.stop()
    print("metrics smoke: ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
