import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import time

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_config
from repro.configs.base import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import specs_for
from repro.models.transformer import init_params
from repro.train.optim import AdamWConfig, init_opt
from repro.train.step import make_train_step
from repro.distributed.sharding import (activation_rules, batch_spec,
                                        param_pspecs, zero1_pspecs, named)
from repro.models.common import logical_axis_rules

t0 = time.time()
mesh = make_production_mesh()
print(f"mesh {mesh.shape} in {time.time()-t0:.1f}s", flush=True)

for arch in ["deepseek-7b", "deepseek-v3-671b"]:
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    t0 = time.time()
    params_shapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), max_seq=shape.seq_len))
    print(f"{arch} eval_shape {time.time()-t0:.1f}s", flush=True)
    pspecs = param_pspecs(cfg, params_shapes)
    opt_cfg = AdamWConfig(moment_dtype=cfg.dtype.opt_dtype)
    opt_shapes = jax.eval_shape(lambda: init_opt(params_shapes, opt_cfg))
    mspec = zero1_pspecs(pspecs, params_shapes, mesh)
    opt_pspecs = type(opt_shapes)(step=P(), m=mspec, v=mspec)
    bspec = batch_spec(shape.global_batch, mesh)
    batch = specs_for(cfg, shape)
    batch_specs = {k: bspec if hasattr(v, "ndim") and v.ndim >= 2 else P()
                   for k, v in batch.items()}
    rules = activation_rules(cfg, mesh)

    def step_fn(p, o, b):
        with logical_axis_rules(rules):
            return make_train_step(cfg, opt_cfg)(p, o, b)

    t0 = time.time()
    with mesh:
        jf = jax.jit(step_fn,
                     in_shardings=(named(pspecs, mesh), named(opt_pspecs, mesh),
                                   named(batch_specs, mesh)),
                     out_shardings=(named(pspecs, mesh), named(opt_pspecs, mesh),
                                    None))
        lowered = jf.lower(params_shapes, opt_shapes, batch)
        print(f"{arch} lower {time.time()-t0:.1f}s", flush=True)
        t0 = time.time()
        compiled = lowered.compile()
        print(f"{arch} compile {time.time()-t0:.1f}s", flush=True)
        ma = compiled.memory_analysis()
        print(f"{arch} argbytes/dev={ma.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB", flush=True)
        ca = compiled.cost_analysis()
        print(f"{arch} flops={ca.get('flops', 0):.3e}", flush=True)
