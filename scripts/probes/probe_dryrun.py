import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=64"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

print("devices:", len(jax.devices()))
mesh = jax.make_mesh((4, 16), ("data", "model"))

# 1) scan FLOPs accounting: y = x @ w applied L times via scan
L, D = 8, 256
w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
x = jax.ShapeDtypeStruct((32, D), jnp.float32)

def f(w, x):
    def body(h, wl):
        return h @ wl, None
    h, _ = jax.lax.scan(body, x, w)
    return h

lowered = jax.jit(f).lower(w, x)
c = lowered.compile()
ca = c.cost_analysis()
print("cost keys sample:", {k: v for k, v in list(ca.items())[:8]})
analytic = 2 * L * 32 * D * D
print("flops reported:", ca.get("flops"), "analytic:", analytic,
      "ratio:", ca.get("flops", 0) / analytic)
ma = c.memory_analysis()
print("memory_analysis:", ma)

# 2) uneven sharding of dim 20 over 16
def g(a):
    return jax.lax.with_sharding_constraint(
        a, NamedSharding(mesh, P(None, "model"))) * 2.0
a = jax.ShapeDtypeStruct((8, 20), jnp.float32)
try:
    cc = jax.jit(g).lower(a).compile()
    print("uneven OK")
except Exception as e:
    print("uneven FAIL:", e)

# 3) sharded matmul -> collectives in HLO text
def h_fn(x, w):
    y = x @ w
    return jax.lax.with_sharding_constraint(y, NamedSharding(mesh, P("data", None)))
xs = jax.ShapeDtypeStruct((64, 256), jnp.float32)
ws = jax.ShapeDtypeStruct((256, 512), jnp.float32)
jf = jax.jit(h_fn, in_shardings=(NamedSharding(mesh, P("data", "model")),
                                 NamedSharding(mesh, P("model", None))))
low = jf.lower(xs, ws)
txt = low.compile().as_text()
COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
            "collective-permute")
colls = [l.split("=")[1].split("(")[0].strip() for l in txt.splitlines()
         if any(op in l for op in COLL_OPS) and "=" in l]
print("collectives:", colls[:10])
# check while-body collectives visibility
def f2(w, x):
    def body(h, wl):
        h = h @ wl
        return jax.lax.with_sharding_constraint(
            h, NamedSharding(mesh, P("data", None))), None
    h, _ = jax.lax.scan(body, x, w)
    return h
jf2 = jax.jit(f2, in_shardings=(NamedSharding(mesh, P(None, None, "model")),
                                NamedSharding(mesh, P("data", "model"))))
low2 = jf2.lower(jax.ShapeDtypeStruct((L, 256, 256), jnp.float32),
                 jax.ShapeDtypeStruct((64, 256), jnp.float32))
c2 = low2.compile()
txt2 = c2.as_text()
n_coll = sum(1 for l in txt2.splitlines() if "all-reduce" in l and "=" in l)
print("while-body all-reduce lines:", n_coll)
print("has while:", "while(" in txt2 or " while " in txt2)
ca2 = c2.cost_analysis()
print("scan sharded flops:", ca2.get("flops"), "analytic global:", 2*L*64*256*256)
