import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=64"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

mesh = jax.make_mesh((4, 16), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
L = 8

def f2(w, x):
    def body(h, wl):
        h = h @ wl
        h = jax.lax.with_sharding_constraint(h, NamedSharding(mesh, P("data", None)))
        return h, None
    h, _ = jax.lax.scan(body, x, w)
    return h

jf2 = jax.jit(f2, in_shardings=(NamedSharding(mesh, P(None, None, "model")),
                                NamedSharding(mesh, P("data", "model"))))
low2 = jf2.lower(jax.ShapeDtypeStruct((L, 256, 256), jnp.float32),
                 jax.ShapeDtypeStruct((64, 256), jnp.float32))
c2 = low2.compile()
txt2 = c2.as_text()
print(txt2[:4000])
print("......")
for line in txt2.splitlines():
    if any(s in line for s in ("while", "all-", "collective", "dot(", "= dot")):
        print(line.strip()[:220])
