"""Store policy: how an engine deployment caches features and neighborhoods.

The paper's end-to-end latency (Eq. 2) is t_pre + t_load + t_compute.
``StorePolicy`` picks, per deployment, how much of t_pre (PPR local push)
and t_load (host->device feature shipping) is traded for memory:

  features:  "dense"    ship [C, N, f] feature rows every batch (baseline)
             "packed"   cross-target dedup: unique rows + int32 index map
             "resident" device feature store: rows pinned in device memory
                        at engine start; batches ship int32 slot maps plus
                        only the rows that miss the HBM budget partition
             "sharded"  resident table partitioned across ``num_shards``
                        shard tables (one per jax device when available),
                        each under its own budget; batches ship per-shard
                        slot lists + a reorder map, rows gather
                        shard-locally, and ``repin()`` rebalances from
                        observed PPR mass (store/sharded.py)
  nbr_cache: "none"     re-run PPR local push per target every batch
             "lru"      LRU cache of per-target PPR node lists
             "pinned"   LRU plus a never-evicted hot set (top-degree
                        targets by default, or an explicit pin list)

  subgraph_rows: "auto" cache the BUILT per-target adjacency/edge rows
                        (SubgraphRowCache) whenever a neighborhood cache
                        is configured — a hit skips the Build stage's
                        induced-subgraph construction entirely
                 "on" | "off"  force it either way (rows are ~N^2 floats
                        per target — "off" trades Build time for memory)

  repin_every / repin_hit_floor: automatic residency rebalance triggers
    (resident/sharded features only) — the pipeline's completion path
    calls ``engine.repin()`` every K completed batches, or whenever the
    store's resident hit rate since the last repin drops below the floor.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

FEATURE_MODES = ("dense", "packed", "resident", "sharded")
NBR_CACHE_MODES = ("none", "lru", "pinned")
PLACEMENT_MODES = ("hash", "range")
SUBGRAPH_ROW_MODES = ("auto", "on", "off")
REPINNABLE_FEATURES = ("resident", "sharded")


@dataclass(frozen=True)
class StorePolicy:
    """Per-deployment caching configuration (see module docstring)."""
    features: str = "dense"
    hbm_budget_bytes: Optional[int] = None   # resident: None = whole matrix
    # per-vertex residency score (array-like [V], e.g. accumulated PPR
    # mass; None = vertex degree); compare=False keeps the frozen
    # dataclass's ==/hash usable when an ndarray is supplied
    hot_scores: Optional[object] = field(default=None, compare=False)
    # sharded-store knobs (features="sharded" only)
    num_shards: int = 0                      # logical shards (>= 1)
    placement: str = "hash"                  # hash | range (degree bands)
    # per-shard HBM budget: None = whole matrix split across shards, an
    # int applies to every shard, a tuple gives uneven per-shard budgets
    shard_budget_bytes: Optional[object] = field(default=None,
                                                 compare=False)
    nbr_cache: str = "none"
    nbr_capacity: int = 4096                 # LRU entries (excludes pins)
    pinned_targets: Optional[Tuple[int, ...]] = None
    pinned_count: int = 0                    # auto-pin top-degree targets
    # Build-stage subgraph-row cache: "auto" follows nbr_cache (rows are
    # cached whenever neighborhoods are), "on"/"off" force it
    subgraph_rows: str = "auto"
    # explicit entry cap; None = derive from the byte budget below (one
    # entry is ~2N^2 floats + edge arrays — far heavier than a node list,
    # so the default bound is bytes, capped at nbr_capacity entries)
    subgraph_capacity: Optional[int] = None
    subgraph_budget_bytes: int = 256 << 20
    # automatic residency rebalance (resident/sharded features): repin
    # every K completed batches, and/or when the store's resident hit
    # rate since the last repin falls below the floor (0 = off for both)
    repin_every: int = 0
    repin_hit_floor: float = 0.0

    def __post_init__(self):
        if self.features not in FEATURE_MODES:
            raise ValueError(
                f"features={self.features!r}, expected one of {FEATURE_MODES}")
        if self.nbr_cache not in NBR_CACHE_MODES:
            raise ValueError(f"nbr_cache={self.nbr_cache!r}, "
                             f"expected one of {NBR_CACHE_MODES}")
        if self.placement not in PLACEMENT_MODES:
            raise ValueError(f"placement={self.placement!r}, "
                             f"expected one of {PLACEMENT_MODES}")
        if self.nbr_capacity < 1:
            raise ValueError("nbr_capacity must be >= 1")
        if self.pinned_count < 0:
            raise ValueError("pinned_count must be >= 0")
        if (self.pinned_targets is not None or self.pinned_count) \
                and self.nbr_cache != "pinned":
            raise ValueError("pinned_targets/pinned_count require "
                             "nbr_cache='pinned'")
        if self.hbm_budget_bytes is not None \
                and self.features != "resident":
            raise ValueError("hbm_budget_bytes requires features='resident'"
                             " (sharded stores use shard_budget_bytes)")
        if self.hot_scores is not None \
                and self.features not in ("resident", "sharded"):
            raise ValueError("hot_scores require features='resident' "
                             "or 'sharded'")
        if self.features == "sharded":
            if self.num_shards < 1:
                raise ValueError("features='sharded' needs num_shards >= 1")
        elif self.num_shards or self.shard_budget_bytes is not None:
            raise ValueError("num_shards/shard_budget_bytes require "
                             "features='sharded'")
        if self.subgraph_rows not in SUBGRAPH_ROW_MODES:
            raise ValueError(f"subgraph_rows={self.subgraph_rows!r}, "
                             f"expected one of {SUBGRAPH_ROW_MODES}")
        if self.subgraph_capacity is not None \
                and self.subgraph_capacity < 1:
            raise ValueError("subgraph_capacity must be >= 1")
        if self.subgraph_budget_bytes < 1:
            raise ValueError("subgraph_budget_bytes must be >= 1")
        if self.repin_every < 0:
            raise ValueError("repin_every must be >= 0")
        if not 0.0 <= self.repin_hit_floor <= 1.0:
            raise ValueError("repin_hit_floor must be in [0, 1]")
        if (self.repin_every or self.repin_hit_floor) \
                and self.features not in REPINNABLE_FEATURES:
            raise ValueError(
                "repin_every/repin_hit_floor require features in "
                f"{REPINNABLE_FEATURES} (got {self.features!r})")

    @property
    def cache_subgraph_rows(self) -> bool:
        """Resolved Build-cache switch: "auto" mirrors the neighborhood
        cache (hot traffic that re-selects also re-builds)."""
        if self.subgraph_rows == "auto":
            return self.nbr_cache != "none"
        return self.subgraph_rows == "on"

    def describe(self) -> dict:
        if self.pinned_targets is not None:
            pins = len(self.pinned_targets)
        elif self.pinned_count:
            pins = self.pinned_count
        else:
            # the engine resolves "auto" to a concrete top-degree pin set
            # and overwrites this field in store_report()
            pins = "auto" if self.nbr_cache == "pinned" else 0
        d = {"features": self.features,
             "hbm_budget_bytes": self.hbm_budget_bytes,
             "nbr_cache": self.nbr_cache,
             "nbr_capacity": self.nbr_capacity,
             "pinned_count": pins,
             "subgraph_rows": self.cache_subgraph_rows}
        if self.repin_every or self.repin_hit_floor:
            d.update(repin_every=self.repin_every,
                     repin_hit_floor=self.repin_hit_floor)
        if self.features == "sharded":
            b = self.shard_budget_bytes
            d.update(num_shards=self.num_shards, placement=self.placement,
                     shard_budget_bytes=list(b) if b is not None
                     and not isinstance(b, int) else b)
        return d
