"""Store policy: how an engine deployment caches features and neighborhoods.

The paper's end-to-end latency (Eq. 2) is t_pre + t_load + t_compute.
``StorePolicy`` picks, per deployment, how much of t_pre (PPR local push)
and t_load (host->device feature shipping) is traded for memory:

  features:  "dense"    ship [C, N, f] feature rows every batch (baseline)
             "packed"   cross-target dedup: unique rows + int32 index map
             "resident" device feature store: rows pinned in device memory
                        at engine start; batches ship int32 slot maps plus
                        only the rows that miss the HBM budget partition
  nbr_cache: "none"     re-run PPR local push per target every batch
             "lru"      LRU cache of per-target PPR node lists
             "pinned"   LRU plus a never-evicted hot set (top-degree
                        targets by default, or an explicit pin list)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

FEATURE_MODES = ("dense", "packed", "resident")
NBR_CACHE_MODES = ("none", "lru", "pinned")


@dataclass(frozen=True)
class StorePolicy:
    """Per-deployment caching configuration (see module docstring)."""
    features: str = "dense"
    hbm_budget_bytes: Optional[int] = None   # resident: None = whole matrix
    # per-vertex residency score (array-like [V], e.g. accumulated PPR
    # mass; None = vertex degree); compare=False keeps the frozen
    # dataclass's ==/hash usable when an ndarray is supplied
    hot_scores: Optional[object] = field(default=None, compare=False)
    nbr_cache: str = "none"
    nbr_capacity: int = 4096                 # LRU entries (excludes pins)
    pinned_targets: Optional[Tuple[int, ...]] = None
    pinned_count: int = 0                    # auto-pin top-degree targets

    def __post_init__(self):
        if self.features not in FEATURE_MODES:
            raise ValueError(
                f"features={self.features!r}, expected one of {FEATURE_MODES}")
        if self.nbr_cache not in NBR_CACHE_MODES:
            raise ValueError(f"nbr_cache={self.nbr_cache!r}, "
                             f"expected one of {NBR_CACHE_MODES}")
        if self.nbr_capacity < 1:
            raise ValueError("nbr_capacity must be >= 1")
        if self.pinned_count < 0:
            raise ValueError("pinned_count must be >= 0")
        if (self.pinned_targets is not None or self.pinned_count) \
                and self.nbr_cache != "pinned":
            raise ValueError("pinned_targets/pinned_count require "
                             "nbr_cache='pinned'")
        if (self.hbm_budget_bytes is not None
                or self.hot_scores is not None) \
                and self.features != "resident":
            raise ValueError("hbm_budget_bytes/hot_scores require "
                             "features='resident'")

    def describe(self) -> dict:
        if self.pinned_targets is not None:
            pins = len(self.pinned_targets)
        elif self.pinned_count:
            pins = self.pinned_count
        else:
            # the engine resolves "auto" to a concrete top-degree pin set
            # and overwrites this field in store_report()
            pins = "auto" if self.nbr_cache == "pinned" else 0
        return {"features": self.features,
                "hbm_budget_bytes": self.hbm_budget_bytes,
                "nbr_cache": self.nbr_cache,
                "nbr_capacity": self.nbr_capacity,
                "pinned_count": pins}
