"""Store policy: how an engine deployment caches features and neighborhoods.

The paper's end-to-end latency (Eq. 2) is t_pre + t_load + t_compute.
``StorePolicy`` picks, per deployment, how much of t_pre (PPR local push)
and t_load (host->device feature shipping) is traded for memory:

  features:  "dense"    ship [C, N, f] feature rows every batch (baseline)
             "packed"   cross-target dedup: unique rows + int32 index map
             "resident" device feature store: rows pinned in device memory
                        at engine start; batches ship int32 slot maps plus
                        only the rows that miss the HBM budget partition
             "sharded"  resident table partitioned across ``num_shards``
                        shard tables (one per jax device when available),
                        each under its own budget; batches ship per-shard
                        slot lists + a reorder map, rows gather
                        shard-locally, and ``repin()`` rebalances from
                        observed PPR mass (store/sharded.py)
  nbr_cache: "none"     re-run PPR local push per target every batch
             "lru"      LRU cache of per-target PPR node lists
             "pinned"   LRU plus a never-evicted hot set (top-degree
                        targets by default, or an explicit pin list)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

FEATURE_MODES = ("dense", "packed", "resident", "sharded")
NBR_CACHE_MODES = ("none", "lru", "pinned")
PLACEMENT_MODES = ("hash", "range")


@dataclass(frozen=True)
class StorePolicy:
    """Per-deployment caching configuration (see module docstring)."""
    features: str = "dense"
    hbm_budget_bytes: Optional[int] = None   # resident: None = whole matrix
    # per-vertex residency score (array-like [V], e.g. accumulated PPR
    # mass; None = vertex degree); compare=False keeps the frozen
    # dataclass's ==/hash usable when an ndarray is supplied
    hot_scores: Optional[object] = field(default=None, compare=False)
    # sharded-store knobs (features="sharded" only)
    num_shards: int = 0                      # logical shards (>= 1)
    placement: str = "hash"                  # hash | range (degree bands)
    # per-shard HBM budget: None = whole matrix split across shards, an
    # int applies to every shard, a tuple gives uneven per-shard budgets
    shard_budget_bytes: Optional[object] = field(default=None,
                                                 compare=False)
    nbr_cache: str = "none"
    nbr_capacity: int = 4096                 # LRU entries (excludes pins)
    pinned_targets: Optional[Tuple[int, ...]] = None
    pinned_count: int = 0                    # auto-pin top-degree targets

    def __post_init__(self):
        if self.features not in FEATURE_MODES:
            raise ValueError(
                f"features={self.features!r}, expected one of {FEATURE_MODES}")
        if self.nbr_cache not in NBR_CACHE_MODES:
            raise ValueError(f"nbr_cache={self.nbr_cache!r}, "
                             f"expected one of {NBR_CACHE_MODES}")
        if self.placement not in PLACEMENT_MODES:
            raise ValueError(f"placement={self.placement!r}, "
                             f"expected one of {PLACEMENT_MODES}")
        if self.nbr_capacity < 1:
            raise ValueError("nbr_capacity must be >= 1")
        if self.pinned_count < 0:
            raise ValueError("pinned_count must be >= 0")
        if (self.pinned_targets is not None or self.pinned_count) \
                and self.nbr_cache != "pinned":
            raise ValueError("pinned_targets/pinned_count require "
                             "nbr_cache='pinned'")
        if self.hbm_budget_bytes is not None \
                and self.features != "resident":
            raise ValueError("hbm_budget_bytes requires features='resident'"
                             " (sharded stores use shard_budget_bytes)")
        if self.hot_scores is not None \
                and self.features not in ("resident", "sharded"):
            raise ValueError("hot_scores require features='resident' "
                             "or 'sharded'")
        if self.features == "sharded":
            if self.num_shards < 1:
                raise ValueError("features='sharded' needs num_shards >= 1")
        elif self.num_shards or self.shard_budget_bytes is not None:
            raise ValueError("num_shards/shard_budget_bytes require "
                             "features='sharded'")

    def describe(self) -> dict:
        if self.pinned_targets is not None:
            pins = len(self.pinned_targets)
        elif self.pinned_count:
            pins = self.pinned_count
        else:
            # the engine resolves "auto" to a concrete top-degree pin set
            # and overwrites this field in store_report()
            pins = "auto" if self.nbr_cache == "pinned" else 0
        d = {"features": self.features,
             "hbm_budget_bytes": self.hbm_budget_bytes,
             "nbr_cache": self.nbr_cache,
             "nbr_capacity": self.nbr_capacity,
             "pinned_count": pins}
        if self.features == "sharded":
            b = self.shard_budget_bytes
            d.update(num_shards=self.num_shards, placement=self.placement,
                     shard_budget_bytes=list(b) if b is not None
                     and not isinstance(b, int) else b)
        return d
