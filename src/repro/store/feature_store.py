"""Device feature store: keep feature rows resident in device memory so a
batch ships int32 index maps instead of dense [C, N, f] tensors.

Three strategies share one interface (``host_payload`` on the host side of
the pipeline, ``device_feats`` on the device side), so the engine's
prepare/run_device stay strategy-agnostic:

  * ``DenseFeatureShipper``  — the baseline: every batch carries its own
    feature rows (the paper's t_load paid in full).
  * ``PackedFeatureShipper`` — cross-target dedup (the pre-existing
    ``packed_features`` path as a store strategy): unique rows once per
    batch plus an index map.
  * ``DeviceFeatureStore``   — rows pinned in device HBM once at engine
    start. When the matrix exceeds ``budget_bytes`` only the hottest rows
    (by degree, or a caller-supplied score such as accumulated PPR mass)
    are resident; cold rows fall back to a host partition and ship as a
    small per-batch miss block appended behind the resident table.

All strategies emit feature rows already padded to the engine's MXU
feature width (``f_pad``), so padding is decided exactly once.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.csr import CSRGraph
from repro.store.nbr_cache import as_vertex_ids


def pad_feature_dim(feats, f_pad: int):
    """THE one feature-padding implementation: zero-pad the trailing dim
    to f_pad (MXU alignment — exact, because the matching layer0 weight
    rows are zero). numpy or jax arrays, any leading shape; no-op when
    already at f_pad. Every padding site (engine and store strategies)
    routes through here."""
    pad = f_pad - feats.shape[-1]
    if pad == 0:
        return feats
    if pad < 0:
        raise ValueError(f"feature dim {feats.shape[-1]} exceeds "
                         f"f_pad={f_pad}")
    widths = [(0, 0)] * (feats.ndim - 1) + [(0, pad)]
    xp = jnp if isinstance(feats, jax.Array) else np
    return xp.pad(feats, widths)


class DenseFeatureShipper:
    """Baseline: ship the dense [C, N, f_pad] block every batch."""

    name = "dense"
    needs_host_feats = True
    payload_keys = ("feats",)

    def __init__(self, graph: CSRGraph, f_pad: int):
        self.graph, self.f_pad = graph, f_pad

    def host_payload(self, node_lists: List[np.ndarray], n: int,
                     feats: Optional[np.ndarray]
                     ) -> Tuple[Dict[str, np.ndarray], Optional[float]]:
        return {"feats": pad_feature_dim(feats, self.f_pad)}, None

    def device_feats(self, payload: Dict) -> jax.Array:
        return jnp.asarray(payload["feats"])

    def report(self) -> dict:
        return {"strategy": self.name}


class PackedFeatureShipper:
    """Cross-target dedup: unique rows [U, f_pad] + int32 index map [C, N].

    PPR favors hubs, so the same vertices recur across a batch's subgraphs;
    each unique row crosses the link once. ``ratio`` (packed/dense bytes)
    is surfaced per batch as the dedup ratio."""

    name = "packed"
    needs_host_feats = False
    payload_keys = ("uniq_feats", "feat_idx")

    def __init__(self, graph: CSRGraph, f_pad: int):
        self.graph, self.f_pad = graph, f_pad

    def host_payload(self, node_lists, n, feats=None):
        from repro.core.subgraph import packed_features
        uniq, idx, _ = packed_features(node_lists, self.graph, n)
        # ship at f_in — the device pads AFTER the gather (run_device's
        # pad_feature_dim), so the link never carries pad zeros. The
        # ratio denominator uses f_pad because that is what the dense
        # strategy ships, keeping dedup_ratio consistent with the
        # scheduler's transfer_ratio under impl="pallas"
        ratio = (uniq.nbytes + idx.nbytes) / \
            (idx.shape[0] * idx.shape[1] * self.f_pad * 4)
        return {"uniq_feats": uniq, "feat_idx": idx}, ratio

    def device_feats(self, payload):
        return jnp.take(jnp.asarray(payload["uniq_feats"]),
                        jnp.asarray(payload["feat_idx"]), axis=0)

    def report(self) -> dict:
        return {"strategy": self.name}


@dataclass(frozen=True)
class ResidencySnapshot:
    """One immutable residency generation of the single-device store:
    which vertices are resident (1-based slot, -1 = host partition) and
    the device table built from that assignment. The generation rides in
    the batch payload so a ``repin()`` landing between a batch's host
    prep and its device gather cannot mismap slots."""
    gen: int
    slot_of: np.ndarray           # [V] int64, 1-based; -1 = host
    table: jax.Array              # [R + 1, f_pad]; row 0 = zero pad
    num_resident: int


class DeviceFeatureStore:
    """Feature rows resident in device memory; batches ship slot maps.

    Layout: one device table [R + 1, f_pad]; slot 0 is the zero pad row
    (masked subgraph slots), slots 1..R are resident vertices. A batch's
    payload is a [C, N] int32 slot map plus a [M, f_in] miss block of
    host-partition rows (padded to f_pad on the device — the link never
    carries pad zeros), addressed as slots R+1..R+M for that batch only.

    ``budget_bytes=None`` pins the whole matrix (full-resident). Otherwise
    the top rows under the budget by ``hot_scores`` (default: degree — the
    PPR-mass proxy that needs no traffic history) are resident and the rest
    stay host-side. Every lookup then accumulates rank-weighted PPR mass
    per row (node lists arrive PPR-rank-ordered, so 1/(1+rank) is the
    online estimate of the paper's PPR score) and ``repin()`` re-derives
    the resident set from that observed mass — the same hotness feedback
    the sharded store has, for single-device deployments. Residency lives
    in immutable generational snapshots (the generation rides in the
    payload, refcounted per in-flight batch), so repins never corrupt
    batches already in the pipeline.
    """

    name = "resident"
    needs_host_feats = False
    payload_keys = ("feat_slots", "miss_feats", "store_gen")

    def __init__(self, graph: CSRGraph, f_pad: int, *,
                 budget_bytes: Optional[int] = None,
                 hot_scores: Optional[np.ndarray] = None):
        self.graph, self.f_pad = graph, f_pad
        v = graph.num_vertices
        row_bytes = f_pad * 4
        if budget_bytes is None or budget_bytes >= (v + 1) * row_bytes:
            self.cap_rows = v                     # full residency
        else:
            self.cap_rows = min(v, max(0, budget_bytes // row_bytes - 1))
        score = np.asarray(graph.degrees if hot_scores is None
                           else hot_scores, np.float64)
        if len(score) != v:
            raise ValueError("hot_scores must have one entry per vertex")
        self._lock = threading.Lock()
        self._snapshots: Dict[int, ResidencySnapshot] = {}
        self._gen_refs: Dict[int, int] = {}
        self._gen = 0
        self._mass = np.zeros(v, np.float64)      # rank-weighted PPR mass
        self._install(self._top_rows(score))
        self.lookups = 0          # vertex slots resolved (excl. padding)
        self.resident_lookups = 0  # served from the device table
        self.miss_rows_shipped = 0  # host-partition rows shipped
        self.repins = 0

    def _top_rows(self, score: np.ndarray) -> np.ndarray:
        """Sorted ids of the ``cap_rows`` highest-scored vertices."""
        v, k = self.graph.num_vertices, self.cap_rows
        if k >= v:
            return np.arange(v, dtype=np.int64)
        return np.sort(np.argpartition(score, -k)[-k:]) if k \
            else np.empty(0, np.int64)

    def _install(self, resident_ids: np.ndarray) -> ResidencySnapshot:
        """Build the table + slot map for ``resident_ids`` and make it
        the current residency (new generation)."""
        v = self.graph.num_vertices
        slot_of = np.full(v, -1, np.int64)
        slot_of[resident_ids] = np.arange(1, len(resident_ids) + 1)
        table = np.zeros((len(resident_ids) + 1, self.f_pad), np.float32)
        if len(resident_ids):
            table[1:] = pad_feature_dim(
                self.graph.features[resident_ids], self.f_pad)
        with self._lock:
            self._gen += 1
            snap = ResidencySnapshot(self._gen, slot_of,
                                     jax.device_put(table),
                                     int(len(resident_ids)))
            self._snapshots[snap.gen] = snap
            self._current = snap
            for g in [g for g in self._snapshots
                      if g != snap.gen and not self._gen_refs.get(g)]:
                del self._snapshots[g]
        return snap

    # back-compat spellings: residency state of the CURRENT generation
    @property
    def slot_of(self) -> np.ndarray:
        return self._current.slot_of

    @property
    def table(self) -> jax.Array:
        return self._current.table

    @property
    def num_resident(self) -> int:
        return self._current.num_resident

    @property
    def device_bytes(self) -> int:
        return int(self._current.table.nbytes)

    @property
    def resident_fraction(self) -> float:
        return self.num_resident / max(1, self.graph.num_vertices)

    def host_payload(self, node_lists, n, feats=None):
        # one snapshot per batch, pinned until the gather: the payload
        # holds a generation reference that device_feats releases — a
        # payload that is never gathered keeps its generation's table
        # alive, so don't accumulate abandoned payloads across repins
        with self._lock:
            snap = self._current
            self._gen_refs[snap.gen] = self._gen_refs.get(snap.gen, 0) + 1
        c = len(node_lists)
        ids = np.full((c, n), -1, np.int64)
        for i, nl in enumerate(node_lists):
            k = min(len(nl), n)
            ids[i, :k] = nl[:k]
        valid = ids >= 0
        slots = np.zeros((c, n), np.int64)
        slots[valid] = snap.slot_of[ids[valid]]
        missing = valid & (slots < 0)
        miss_ids = np.unique(ids[missing])
        if len(miss_ids):
            slots[missing] = snap.num_resident + 1 + \
                np.searchsorted(miss_ids, ids[missing])
            # the miss block ships at f_in and is padded on the DEVICE
            # (device_feats): the resident table carries the MXU pad
            # columns already, so shipping them per batch would charge
            # the link — and bytes_shipped — for resident-table layout
            # instead of just the miss rows themselves
            miss_feats = self.graph.features[miss_ids]
        else:
            miss_feats = np.zeros((0, self.graph.feature_dim), np.float32)
        # rank-weighted PPR-mass accumulation (node lists are ordered by
        # descending PPR score): the O(C*N) reduction runs OUTSIDE the
        # lock, only the O(unique) merge holds it
        w = (1.0 / (1.0 + np.arange(n, dtype=np.float64)))[None, :]
        uids, uinv = np.unique(ids[valid], return_inverse=True)
        contrib = np.bincount(uinv,
                              weights=np.broadcast_to(w, ids.shape)[valid])
        with self._lock:
            self._mass[uids] += contrib
            self.lookups += int(valid.sum())
            self.resident_lookups += int(valid.sum() - missing.sum())
            self.miss_rows_shipped += int(len(miss_ids))
        return {"feat_slots": slots.astype(np.int32),
                "miss_feats": miss_feats,
                "store_gen": np.asarray(snap.gen, np.int32)}, None

    def device_feats(self, payload):
        gen = int(payload.get("store_gen", 0))
        with self._lock:
            snap = self._snapshots.get(gen, self._current)
        try:
            slots = jnp.asarray(payload["feat_slots"])
            miss = payload["miss_feats"]
            # two gathers + select, NOT concatenate: concatenating would
            # copy the whole resident table per batch (O(R * f_pad) device
            # traffic and ~2x the HBM budget transiently — the budget
            # exists because the table barely fits)
            res = jnp.take(snap.table,
                           jnp.clip(slots, 0, snap.num_resident), axis=0)
            if miss.shape[0] == 0:
                return res
            mi = jnp.clip(slots - snap.num_resident - 1, 0,
                          miss.shape[0] - 1)
            m = jnp.take(pad_feature_dim(jnp.asarray(miss), self.f_pad),
                         mi, axis=0)
            return jnp.where((slots > snap.num_resident)[..., None], m,
                             res)
        finally:
            with self._lock:
                r = self._gen_refs.get(gen, 0)
                if r > 1:
                    self._gen_refs[gen] = r - 1
                elif r:
                    self._gen_refs.pop(gen, None)
                    if gen != self._current.gen:
                        self._snapshots.pop(gen, None)

    # -- online rebalancing ---------------------------------------------------
    def repin(self, decay: float = 0.0) -> dict:
        """Re-derive the resident set from the accumulated PPR mass: the
        hottest ``cap_rows`` rows by observed mass (degree as tiebreak
        for never-seen rows) become resident. In-flight batches keep
        their residency snapshot (the payload carries its generation), so
        serving never pauses. ``decay`` scales the retained mass
        afterwards (0 keeps it all)."""
        with self._lock:
            mass = self._mass.copy()
            old = self._current
        key = mass + 1e-12 * self.graph.degrees.astype(np.float64)
        new_ids = self._top_rows(key)
        snap = self._install(new_ids)
        was = old.slot_of >= 0
        now = snap.slot_of >= 0
        promoted = int((~was & now).sum())
        demoted = int((was & ~now).sum())
        with self._lock:
            self.repins += 1
            if decay:
                self._mass *= (1.0 - decay)
        return {"promoted": promoted, "demoted": demoted,
                "resident_rows": snap.num_resident,
                "mass_covered": round(float(
                    mass[new_ids].sum() / mass.sum()), 4)
                if mass.sum() > 0 else 1.0}

    def refresh_features(self, vertices) -> int:
        """Re-upload the resident rows of ``vertices`` from the (updated)
        host feature matrix — the feature half of the graph-update hook.
        Host-partition vertices need nothing: their rows ship fresh from
        ``graph.features`` on every miss. Returns rows re-uploaded."""
        ids = as_vertex_ids(vertices)
        with self._lock:  # table swap is read-modify-write: without the
            # lock, concurrent invalidate() calls lose each other's
            # re-uploads (readers are safe — jax arrays are immutable)
            snap = self._current
            slots = snap.slot_of[ids]
            res = slots > 0
            if not res.any():
                return 0
            rows = pad_feature_dim(self.graph.features[ids[res]],
                                   self.f_pad)
            new = ResidencySnapshot(
                snap.gen, snap.slot_of,
                snap.table.at[jnp.asarray(slots[res])].set(
                    jnp.asarray(rows)),
                snap.num_resident)
            self._snapshots[snap.gen] = new
            self._current = new
        return int(res.sum())

    def report(self) -> dict:
        with self._lock:
            lk, res, miss = (self.lookups, self.resident_lookups,
                             self.miss_rows_shipped)
            repins = self.repins
        return {"strategy": self.name,
                "resident_rows": self.num_resident,
                "resident_fraction": round(self.resident_fraction, 4),
                "device_bytes": self.device_bytes,
                "lookups": lk,
                "resident_hit_rate": round(res / lk, 4) if lk else 0.0,
                "miss_rows_shipped": miss,
                "repins": repins}


def build_feature_source(graph: CSRGraph, policy, f_pad: int,
                         hot_scores: Optional[np.ndarray] = None):
    """Strategy factory keyed on ``StorePolicy.features``. ``hot_scores``
    defaults to the policy's own (e.g. accumulated PPR mass supplied at
    deployment time); vertex degree when neither is given."""
    if policy.features == "dense":
        return DenseFeatureShipper(graph, f_pad)
    if policy.features == "packed":
        return PackedFeatureShipper(graph, f_pad)
    if hot_scores is None and policy.hot_scores is not None:
        hot_scores = np.asarray(policy.hot_scores, np.float64)
    if policy.features == "resident":
        return DeviceFeatureStore(graph, f_pad,
                                  budget_bytes=policy.hbm_budget_bytes,
                                  hot_scores=hot_scores)
    if policy.features == "sharded":
        from repro.store.sharded import ShardedFeatureStore
        return ShardedFeatureStore(graph, f_pad,
                                   num_shards=policy.num_shards,
                                   placement=policy.placement,
                                   budget_bytes=policy.shard_budget_bytes,
                                   hot_scores=hot_scores)
    raise ValueError(f"unknown feature strategy {policy.features!r}")
