"""Sharded device feature store: the resident table partitioned across N
logical shards with cross-shard gather and online PPR-mass rebalancing.

``DeviceFeatureStore`` (store/feature_store.py) keeps inference index-only
while the feature matrix fits ONE device's HBM budget; past that, every
cold row re-pays the paper's t_load as a per-batch miss block. HP-GNN and
GraphAGILE scale past one accelerator by partitioning vertex data across
memory banks/devices — this module is that step for the TPU substrate:

  * the resident table is split into ``num_shards`` shard tables, each
    placed on its own jax device when the host has that many (simulated
    shards — all tables on the default device — otherwise), each under
    its OWN ``budget_bytes``;
  * placement is ``hash`` (vertex id mod shards — uniform, no stats
    needed) or ``range`` (degree-rank bands — shard 0 holds the hottest
    band, matching HP-GNN's degree-ordered partitioning);
  * a batch ships, per shard, the int32 shard-local slot list of the
    unique rows it needs there; each shard gathers its rows LOCALLY and
    the blocks are concatenated + reordered on the target shard via one
    [C, N] int32 reorder map. Rows resident on no shard fall back to a
    host miss partition exactly like the single-device store (shipped at
    f_in — the link never carries pad zeros);
  * every lookup accumulates rank-weighted PPR mass per row (node lists
    arrive PPR-rank-ordered, so 1/(1+rank) is the online estimate of the
    paper's PPR score); ``repin()`` rebuilds the residency from that
    observed mass — promoting hot cold-rows, demoting dead resident
    rows, and rebalancing skewed shards — without restarting the engine.

Placements are immutable snapshots keyed by a generation counter that
rides inside the batch payload, so a ``repin()`` landing between a
batch's host prep and its device gather cannot mismap slots.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import shard_devices
from repro.graphs.csr import CSRGraph
from repro.store.nbr_cache import as_vertex_ids
from repro.store.policy import PLACEMENT_MODES as PLACEMENTS

BudgetSpec = Union[None, int, Sequence[int]]


@dataclass(frozen=True)
class ShardPlacement:
    """One immutable residency snapshot: which shard (if any) holds each
    vertex row, at which shard-local slot, and the shard tables built
    from that assignment. ``gen`` keys the snapshot in the payload."""
    gen: int
    shard_of: np.ndarray          # [V] int32, -1 = host partition
    slot_of: np.ndarray           # [V] int32 shard-local slot, -1 = host
    tables: Tuple[jax.Array, ...]  # per shard [R_s, f_pad] device-resident

    @property
    def resident_per_shard(self) -> Tuple[int, ...]:
        return tuple(int(t.shape[0]) for t in self.tables)

    @property
    def num_resident(self) -> int:
        return sum(self.resident_per_shard)


def _normalize_budgets(budget: BudgetSpec, num_shards: int,
                       total_rows: int, row_bytes: int) -> List[int]:
    """Per-shard row capacities. ``None`` = the whole matrix split evenly
    (full residency across the union of shards); an int applies to every
    shard; a sequence gives per-shard budgets (uneven shards)."""
    if budget is None:
        base = total_rows // num_shards
        extra = total_rows - base * num_shards
        return [base + (1 if s < extra else 0) for s in range(num_shards)]
    if isinstance(budget, (int, np.integer)):
        budgets = [int(budget)] * num_shards
    else:
        budgets = [int(b) for b in budget]
        if len(budgets) != num_shards:
            raise ValueError(f"{len(budgets)} shard budgets for "
                             f"{num_shards} shards")
    return [max(0, b // row_bytes) for b in budgets]


class ShardedFeatureStore:
    """Feature rows partitioned across shard-resident tables; batches ship
    per-shard slot lists + one reorder map (+ the host-fallback miss
    block). Implements the engine's feature-source interface."""

    name = "sharded"
    needs_host_feats = False

    def __init__(self, graph: CSRGraph, f_pad: int, *,
                 num_shards: int = 2, placement: str = "hash",
                 budget_bytes: BudgetSpec = None,
                 hot_scores: Optional[np.ndarray] = None):
        if num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        if placement not in PLACEMENTS:
            raise ValueError(f"placement={placement!r}, expected one of "
                             f"{PLACEMENTS}")
        self.graph, self.f_pad = graph, f_pad
        self.num_shards = num_shards
        self.placement = placement
        v = graph.num_vertices
        self.row_bytes = f_pad * 4
        self.capacities = _normalize_budgets(budget_bytes, num_shards, v,
                                             self.row_bytes)
        score = np.asarray(graph.degrees if hot_scores is None
                           else hot_scores, np.float64)
        if len(score) != v:
            raise ValueError("hot_scores must have one entry per vertex")
        self.devices = shard_devices(num_shards)
        self.target_device = self.devices[0]
        self.simulated = len(set(self.devices)) < num_shards
        self._lock = threading.Lock()
        # online hotness: rank-weighted appearance mass per row (the
        # ROADMAP's PPR-mass feedback — node lists are PPR-rank-ordered)
        self._mass = np.zeros(v, np.float64)
        self._pad_row = jax.device_put(
            jnp.zeros((1, f_pad), jnp.float32), self.target_device)
        self._placements: Dict[int, ShardPlacement] = {}
        # generation refcounts: host_payload takes a reference on its
        # snapshot, device_feats releases it — a placement is retired
        # only when it is no longer current AND no in-flight batch still
        # points at it, so arbitrarily many repin() calls can land while
        # batches sit in the pipeline
        self._gen_refs: Dict[int, int] = {}
        self._gen = 0
        self._install(self._initial_assignment(score))
        # cumulative counters (under _lock)
        self.lookups = 0
        self.resident_lookups = 0
        self.miss_rows_shipped = 0
        self.cross_shard_rows = 0     # rows gathered off the target shard
        self.shard_lookups = np.zeros(num_shards, np.int64)
        self.repins = 0

    # payload keys are an instance attribute: they enumerate the shards
    @property
    def payload_keys(self) -> Tuple[str, ...]:
        return tuple(f"shard{s}_slots" for s in range(self.num_shards)) \
            + ("reorder", "miss_feats", "shard_gen")

    # -- placement construction ---------------------------------------------
    def _initial_assignment(self, score: np.ndarray) -> np.ndarray:
        """[V] int32 shard assignment (-1 = host) from the static policy.

        hash:  home shard = v mod num_shards; within a home bucket the
               top rows by ``score`` stay under that shard's capacity.
        range: vertices in descending-score order are cut into contiguous
               bands, one per shard, band s sized to capacity_s (shard 0
               holds the hottest band).
        """
        v = self.graph.num_vertices
        assign = np.full(v, -1, np.int32)
        if self.placement == "hash":
            home = (np.arange(v) % self.num_shards).astype(np.int32)
            for s in range(self.num_shards):
                mine = np.flatnonzero(home == s)
                k = min(len(mine), self.capacities[s])
                if k:
                    top = mine[np.argpartition(score[mine], -k)[-k:]]
                    assign[top] = s
        else:                                     # degree-range bands
            order = np.argsort(-score, kind="stable")
            lo = 0
            for s in range(self.num_shards):
                hi = min(v, lo + self.capacities[s])
                assign[order[lo:hi]] = s
                lo = hi
        return assign

    def _install(self, assign: np.ndarray) -> ShardPlacement:
        """Build shard tables + slot maps for ``assign`` and make it the
        current placement (new generation)."""
        v = self.graph.num_vertices
        slot_of = np.full(v, -1, np.int32)
        tables = []
        for s in range(self.num_shards):
            ids = np.flatnonzero(assign == s)
            slot_of[ids] = np.arange(len(ids), dtype=np.int32)
            rows = np.zeros((len(ids), self.f_pad), np.float32)
            if len(ids):
                rows[:, :self.graph.feature_dim] = self.graph.features[ids]
            tables.append(jax.device_put(rows, self.devices[s]))
        with self._lock:
            self._gen += 1
            pl = ShardPlacement(self._gen, assign.astype(np.int32),
                                slot_of, tuple(tables))
            self._placements[pl.gen] = pl
            self._current = pl
            # retire snapshots nothing references anymore
            for g in [g for g in self._placements
                      if g != pl.gen and not self._gen_refs.get(g)]:
                del self._placements[g]
        return pl

    # -- feature-source interface -------------------------------------------
    def host_payload(self, node_lists, n, feats=None):
        with self._lock:                       # one snapshot per batch,
            pl = self._current                 # pinned until the gather
            self._gen_refs[pl.gen] = self._gen_refs.get(pl.gen, 0) + 1
        c = len(node_lists)
        ids = np.full((c, n), -1, np.int64)
        for i, nl in enumerate(node_lists):
            k = min(len(nl), n)
            ids[i, :k] = nl[:k]
        valid = ids >= 0
        flat = ids[valid]
        shard = pl.shard_of[flat]
        slot = pl.slot_of[flat]
        # reorder map into [pad_row | shard blocks ... | miss block]
        pos = np.zeros(len(flat), np.int64)
        payload: Dict[str, np.ndarray] = {}
        offset = 1                             # row 0 = zero pad row
        per_shard = np.zeros(self.num_shards, np.int64)
        for s in range(self.num_shards):
            sel = shard == s
            uniq, inv = np.unique(slot[sel], return_inverse=True)
            payload[f"shard{s}_slots"] = uniq.astype(np.int32)
            pos[sel] = offset + inv
            offset += len(uniq)
            per_shard[s] = int(sel.sum())
        miss_sel = shard < 0
        miss_ids, miss_inv = np.unique(flat[miss_sel], return_inverse=True)
        pos[miss_sel] = offset + miss_inv
        # host-fallback miss block ships at f_in: the shard tables carry
        # the MXU pad columns, the link must not (see PackedFeatureShipper)
        payload["miss_feats"] = self.graph.features[miss_ids] if \
            len(miss_ids) else np.zeros((0, self.graph.feature_dim),
                                        np.float32)
        reorder = np.zeros((c, n), np.int32)
        reorder[valid] = pos
        payload["reorder"] = reorder
        payload["shard_gen"] = np.asarray(pl.gen, np.int32)
        # rank-weighted PPR-mass accumulation: node lists are ordered by
        # descending PPR score, so 1/(1+rank) tracks each row's share.
        # The O(C*N) reduction runs OUTSIDE the lock (unique rows +
        # bincount); only the O(unique) merge holds it, so concurrent
        # prepare threads don't serialize on the scatter-add
        w = (1.0 / (1.0 + np.arange(n, dtype=np.float64)))[None, :]
        uids, uinv = np.unique(flat, return_inverse=True)
        contrib = np.bincount(uinv,
                              weights=np.broadcast_to(w, ids.shape)[valid])
        with self._lock:
            self._mass[uids] += contrib
            self.lookups += int(valid.sum())
            self.resident_lookups += int(valid.sum() - miss_sel.sum())
            self.miss_rows_shipped += int(len(miss_ids))
            self.shard_lookups += per_shard
            self.cross_shard_rows += int(sum(
                len(payload[f"shard{s}_slots"])
                for s in range(1, self.num_shards)))
        return payload, None

    def device_feats(self, payload):
        gen = int(payload["shard_gen"])
        with self._lock:
            pl = self._placements[gen]
        try:
            blocks = [self._pad_row]
            for s in range(self.num_shards):
                slots = payload[f"shard{s}_slots"]
                if slots.shape[0] == 0:
                    continue
                # shard-local gather: slot list crosses to shard s (int32
                # — index-only), the gathered rows cross back to the
                # target. On simulated shards (same device) both hops are
                # skipped — no device_put round-trips per batch
                sl = jnp.asarray(slots)
                if self.devices[s] is not self.target_device:
                    sl = jax.device_put(sl, self.devices[s])
                blk = jnp.take(pl.tables[s], sl, axis=0)
                if self.devices[s] is not self.target_device:
                    blk = jax.device_put(blk, self.target_device)
                blocks.append(blk)
            miss = payload["miss_feats"]
            if miss.shape[0]:
                # default device == devices[0] == the target shard, so
                # the padded miss block lands there without an explicit
                # transfer
                m = jnp.asarray(miss)
                pad = self.f_pad - m.shape[-1]
                if pad:
                    m = jnp.pad(m, ((0, 0), (0, pad)))
                blocks.append(m)
            gathered = jnp.concatenate(blocks, axis=0) \
                if len(blocks) > 1 else self._pad_row
            return jnp.take(gathered, jnp.asarray(payload["reorder"]),
                            axis=0)
        finally:
            with self._lock:
                r = self._gen_refs.get(gen, 0)
                if r > 1:
                    self._gen_refs[gen] = r - 1
                else:
                    self._gen_refs.pop(gen, None)
                    if gen != self._current.gen:
                        self._placements.pop(gen, None)

    # -- per-batch shard metrics (pure function of one payload) --------------
    def shard_metrics_for(self, payload) -> List[int]:
        """Host->device bytes this payload ships to each shard: the shard's
        slot list, plus (on the target shard) the reorder map and the miss
        block. Pure — safe from concurrent prepare threads."""
        out = [int(payload[f"shard{s}_slots"].nbytes)
               for s in range(self.num_shards)]
        out[0] += int(payload["reorder"].nbytes) \
            + int(payload["miss_feats"].nbytes)
        return out

    # -- online rebalancing ---------------------------------------------------
    def repin(self, decay: float = 0.0) -> dict:
        """Re-derive residency from the accumulated PPR mass: the globally
        hottest rows (by observed mass, degree as tiebreak for never-seen
        rows) fill the shard capacities. Rows keep their current shard
        when it still has room (minimizing table churn); the rest go to
        the least-loaded shard. Returns a movement/balance report;
        ``decay`` scales the retained mass afterwards (0 keeps it all)."""
        with self._lock:
            mass = self._mass.copy()
            old = self._current
        # degree epsilon-tiebreak: rows never observed rank by degree
        deg = self.graph.degrees.astype(np.float64)
        key = mass + 1e-12 * deg
        total_cap = sum(self.capacities)
        v = self.graph.num_vertices
        k = min(v, total_cap)
        hot = np.argsort(-key, kind="stable")[:k] if k else \
            np.empty(0, np.int64)
        assign = np.full(v, -1, np.int32)
        free = np.array(self.capacities, np.int64)
        # pass 1: sticky — hot rows stay on their current shard
        cur = old.shard_of[hot]
        for s in range(self.num_shards):
            keep = hot[(cur == s)][:self.capacities[s]]
            assign[keep] = s
            free[s] -= len(keep)
        # pass 2: promote the remaining hot rows across the free slots,
        # vectorized stride-scheduling fill (equivalent to repeatedly
        # picking the least-loaded shard, without the per-row Python
        # loop): shard s's k-th free slot sits at fractional position
        # (k + 1) / free_s, and filling slots in that order interleaves
        # shards proportionally to their free capacity
        pending = hot[assign[hot] < 0]
        slot_shard = np.repeat(np.arange(self.num_shards), np.maximum(
            free, 0))
        slot_pos = np.concatenate(
            [(np.arange(f) + 1.0) / f for f in free if f > 0]) \
            if (free > 0).any() else np.empty(0)
        order = np.argsort(slot_pos, kind="stable")
        take = min(len(pending), len(slot_shard))
        assign[pending[:take]] = slot_shard[order[:take]]
        promoted = int(((old.shard_of < 0) & (assign >= 0)).sum())
        demoted = int(((old.shard_of >= 0) & (assign < 0)).sum())
        moved = int(((old.shard_of >= 0) & (assign >= 0)
                     & (old.shard_of != assign)).sum())
        bal_before = self._balance(old, mass)
        pl = self._install(assign)
        bal_after = self._balance(pl, mass)
        with self._lock:
            self.repins += 1
            if decay:
                self._mass *= (1.0 - decay)
        return {"promoted": promoted, "demoted": demoted, "moved": moved,
                "resident_per_shard": pl.resident_per_shard,
                "mass_balance_before": bal_before,
                "mass_balance_after": bal_after}

    def _balance(self, pl: ShardPlacement, mass: np.ndarray) -> float:
        """max/mean of per-shard resident mass (1.0 = perfectly even)."""
        per = np.zeros(self.num_shards)
        res = pl.shard_of >= 0
        np.add.at(per, pl.shard_of[res], mass[res])
        mean = per.mean()
        return round(float(per.max() / mean), 4) if mean > 0 else 1.0

    # -- graph-update hook ----------------------------------------------------
    def refresh_features(self, vertices) -> int:
        """Re-upload the shard-resident rows of ``vertices`` from the
        (updated) host feature matrix. Host-partition rows need nothing —
        they ship fresh on every miss. Returns rows re-uploaded."""
        ids = as_vertex_ids(vertices)
        with self._lock:
            pl = self._current
            refreshed = 0
            tables = list(pl.tables)
            for s in range(self.num_shards):
                mine = ids[pl.shard_of[ids] == s]
                if not len(mine):
                    continue
                rows = np.zeros((len(mine), self.f_pad), np.float32)
                rows[:, :self.graph.feature_dim] = self.graph.features[mine]
                tables[s] = tables[s].at[
                    jnp.asarray(pl.slot_of[mine])].set(jnp.asarray(rows))
                refreshed += len(mine)
            if refreshed:
                new = ShardPlacement(pl.gen, pl.shard_of, pl.slot_of,
                                     tuple(tables))
                self._placements[pl.gen] = new
                self._current = new
        return refreshed

    # -- introspection --------------------------------------------------------
    @property
    def num_resident(self) -> int:
        return self._current.num_resident

    @property
    def resident_fraction(self) -> float:
        return self.num_resident / max(1, self.graph.num_vertices)

    @property
    def device_bytes(self) -> int:
        return sum(int(t.nbytes) for t in self._current.tables)

    def report(self) -> dict:
        with self._lock:
            pl = self._current
            lk, res, miss = (self.lookups, self.resident_lookups,
                             self.miss_rows_shipped)
            cross, repins = self.cross_shard_rows, self.repins
            per_lookups = self.shard_lookups.tolist()
            mass = self._mass.copy()
        per_rows = pl.resident_per_shard
        return {"strategy": self.name,
                "num_shards": self.num_shards,
                "placement": self.placement,
                "simulated": self.simulated,
                "resident_rows": sum(per_rows),
                "resident_fraction": round(self.resident_fraction, 4),
                "device_bytes": sum(int(t.nbytes) for t in pl.tables),
                "shard_rows": list(per_rows),
                "shard_bytes": [int(t.nbytes) for t in pl.tables],
                "shard_lookups": per_lookups,
                "shard_hit_share": [round(x / lk, 4) for x in per_lookups]
                if lk else [0.0] * self.num_shards,
                "mass_balance": self._balance(pl, mass),
                "lookups": lk,
                "resident_hit_rate": round(res / lk, 4) if lk else 0.0,
                "miss_rows_shipped": miss,
                "cross_shard_rows": cross,
                "repins": repins}
