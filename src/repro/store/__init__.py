"""Two-level caching subsystem: device feature store + host neighborhood
cache (turns per-batch "recompute + reship everything" into "look up +
ship indices" — see policy.py for the knobs)."""
from repro.store.feature_store import (DenseFeatureShipper,
                                       DeviceFeatureStore,
                                       PackedFeatureShipper,
                                       build_feature_source)
from repro.store.nbr_cache import (FrontierCache, NeighborhoodCache,
                                   SubgraphRowCache, nbr_key)
from repro.store.policy import StorePolicy
from repro.store.sharded import ShardedFeatureStore

__all__ = ["StorePolicy", "NeighborhoodCache", "SubgraphRowCache",
           "FrontierCache", "nbr_key",
           "DeviceFeatureStore", "PackedFeatureShipper",
           "DenseFeatureShipper", "ShardedFeatureStore",
           "build_feature_source"]
