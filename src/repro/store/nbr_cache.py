"""Host neighborhood cache: per-target PPR node lists, LRU + pinned hot set.

INI (PPR local push) is the dominant host cost per target (paper t_pre,
Eq. 2). Under skewed traffic the same targets recur, and their PPR
neighborhoods are deterministic in ``(target, N, alpha, eps)`` — so the
push result is cached under exactly that key. Entries for targets in the
pinned hot set never evict; everything else is LRU over ``capacity``
entries. ``invalidate(vertices)`` drops every cached neighborhood whose
push FRONTIER (the full touched set, cached alongside the truncated
top-N selection) contains an updated vertex — a graph update at v
changes the PPR of any target whose push reached v, even when v fell
below that target's top-N cutoff — forcing recompute on next lookup.

Thread-safe: the engine's prepare runs on the scheduler's host pool, so
several batches may probe the cache concurrently. Two concurrent misses on
the same target may both compute (benign stampede); last put wins. A PPR
computation in flight across an ``invalidate()`` must NOT insert its
(possibly pre-update) result: callers snapshot ``generation`` before
computing and pass it to ``put()``, which drops the insert when any
invalidation happened in between.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Hashable, Iterable, Optional, Tuple

import numpy as np

Key = Tuple[int, int, float, float]       # (target, N, alpha, eps)


def nbr_key(target: int, n: int, alpha: float, eps: float) -> Key:
    return (int(target), int(n), float(alpha), float(eps))


def as_vertex_ids(vertices) -> np.ndarray:
    """Coerce a scalar, iterable, or array of vertex ids to unique sorted
    int64 — the shared normalization for both invalidation levels
    (neighborhood cache and device feature store)."""
    if not isinstance(vertices, np.ndarray):
        vertices = list(vertices) if np.iterable(vertices) else [vertices]
    return np.unique(np.asarray(vertices, dtype=np.int64))


class NeighborhoodCache:
    """LRU + pinned-hot-set cache of per-target PPR node lists."""

    def __init__(self, capacity: int = 4096,
                 pinned_targets: Optional[Iterable[int]] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._pin_ids = frozenset(
            int(t) for t in (() if pinned_targets is None
                             else pinned_targets))
        self._pinned: dict = {}               # never evicted
        self._lru: "OrderedDict[Hashable, np.ndarray]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0                # entries dropped, not calls
        self._gen = 0                         # bumped by invalidate/clear

    # -- core ----------------------------------------------------------------
    def get(self, key: Key) -> Optional[np.ndarray]:
        with self._lock:
            ent = self._pinned.get(key)
            if ent is None:
                ent = self._lru.get(key)
                if ent is not None:
                    self._lru.move_to_end(key)
            if ent is None:
                self.misses += 1
                return None
            self.hits += 1
            return ent[0]

    def put(self, key: Key, node_list: np.ndarray,
            generation: Optional[int] = None,
            frontier: Optional[np.ndarray] = None):
        """Insert a computed neighborhood. Pass the ``generation`` read
        BEFORE the computation started: if an invalidate() ran in between,
        the result may reflect the pre-update graph and is dropped (the
        next lookup recomputes). ``frontier`` is the push's full touched
        set (``select_important(with_frontier=True)``): with it,
        invalidation is EXACT; without it, invalidation falls back to
        scanning the truncated top-N list (approximate — updates at
        below-cutoff touched vertices go undetected)."""
        nl = np.array(node_list)              # copy: freezing an aliased
        nl.flags.writeable = False            # array would make the
        # caller's own node list read-only as a side effect
        if frontier is not None:
            frontier = np.array(frontier)
            frontier.flags.writeable = False
        ent = (nl, frontier)
        with self._lock:
            if generation is not None and generation != self._gen:
                return
            if key[0] in self._pin_ids:
                self._pinned[key] = ent
                return
            self._lru[key] = ent
            self._lru.move_to_end(key)
            while len(self._lru) > self.capacity:
                self._lru.popitem(last=False)
                self.evictions += 1

    def invalidate(self, vertices) -> int:
        """Drop every cached neighborhood whose push FRONTIER contains any
        of ``vertices`` (pinned entries included). Returns the number of
        entries dropped.

        Entries stored with their full touched set (the engine's miss
        path caches it) are invalidated EXACTLY: an update at a vertex
        the push reached — even one below the top-N cutoff — drops the
        entry, because it can shift the target's scores enough to change
        its true top-N. Entries without a frontier (direct put() callers)
        fall back to scanning the truncated selection, the pre-frontier
        approximation."""
        vs = as_vertex_ids(vertices)
        # the O(entries * frontier) membership scan runs OUTSIDE the lock
        # so concurrent serving-path get/put calls don't stall behind a
        # graph update; the generation bump (taken first) keeps any
        # in-flight pre-update computation from landing afterwards
        with self._lock:
            self._gen += 1
            snapshot = [(store, list(store.items()))
                        for store in (self._pinned, self._lru)]
        stale = [(store, k, ent) for store, items in snapshot
                 for k, ent in items
                 if np.isin(ent[1] if ent[1] is not None else ent[0], vs,
                            assume_unique=False).any()]
        dropped = 0
        with self._lock:
            for store, k, ent in stale:
                # identity check: a fresh post-update recompute may have
                # replaced the entry while we scanned — keep that one
                if store.get(k) is ent:
                    del store[k]
                    dropped += 1
            self.invalidations += dropped
        return dropped

    def clear(self):
        with self._lock:
            self._gen += 1
            self._pinned.clear()
            self._lru.clear()

    @property
    def generation(self) -> int:
        """Invalidation epoch — snapshot before a miss's PPR computation
        and hand to put()."""
        with self._lock:
            return self._gen

    # -- introspection -------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._pinned) + len(self._lru)

    def __contains__(self, key: Key) -> bool:
        with self._lock:
            return key in self._pinned or key in self._lru

    @property
    def num_pinned_targets(self) -> int:
        """Size of the configured evict-exempt target set (not the number
        of pinned entries currently cached — see stats())."""
        return len(self._pin_ids)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        with self._lock:
            return {"entries": len(self._pinned) + len(self._lru),
                    "pinned_entries": len(self._pinned),
                    "capacity": self.capacity,
                    "hits": self.hits, "misses": self.misses,
                    "hit_rate": round(self.hit_rate, 4),
                    "evictions": self.evictions,
                    "invalidations": self.invalidations}
