"""Host-side frontier-keyed caches: PPR neighborhoods + built subgraph rows.

INI (PPR local push) is the dominant host cost per target (paper t_pre,
Eq. 2), and induced-subgraph construction is the next (the Build stage of
the BatchPlan pipeline). Under skewed traffic the same targets recur, and
both artifacts are deterministic in ``(target, N, alpha, eps)`` — so both
cache under exactly that key:

  * ``NeighborhoodCache``  — per-target PPR node lists (Select stage).
  * ``SubgraphRowCache``   — the built per-target adjacency/edge rows
    (``core.subgraph.SubgraphRows``, Build stage): a hit skips induced-
    subgraph construction entirely, keyed alongside the neighborhood
    entry with the SAME generation/frontier-exact invalidation.

Entries for targets in the pinned hot set never evict; everything else is
LRU over ``capacity`` entries. ``invalidate(vertices)`` drops every cached
entry whose push FRONTIER (the full touched set, cached alongside the
value) contains an updated vertex — a graph update at v changes the PPR of
any target whose push reached v, even when v fell below that target's
top-N cutoff — forcing recompute on next lookup.

Thread-safe: the engine's stages run on the scheduler's stage workers, so
several batches may probe a cache concurrently. Two concurrent misses on
the same target may both compute (benign stampede); last put wins. A
computation in flight across an ``invalidate()`` must NOT insert its
(possibly pre-update) result: callers snapshot ``generation`` before
computing and pass it to ``put()``, which drops the insert when any
invalidation happened in between.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Hashable, Iterable, Optional, Tuple

import numpy as np

Key = Tuple[int, int, float, float]       # (target, N, alpha, eps)


def nbr_key(target: int, n: int, alpha: float, eps: float) -> Key:
    return (int(target), int(n), float(alpha), float(eps))


def as_vertex_ids(vertices) -> np.ndarray:
    """Coerce a scalar, iterable, or array of vertex ids to unique sorted
    int64 — the shared normalization for both invalidation levels
    (neighborhood cache and device feature store)."""
    if not isinstance(vertices, np.ndarray):
        vertices = list(vertices) if np.iterable(vertices) else [vertices]
    return np.unique(np.asarray(vertices, dtype=np.int64))


class FrontierCache:
    """LRU + pinned-hot-set cache of per-target artifacts, each entry
    carrying its push's full touched frontier for exact invalidation.
    Subclasses pick the value type (``_freeze`` normalizes on insert and
    ``_footprint`` names the array invalidation scans when an entry has
    no frontier)."""

    def __init__(self, capacity: int = 4096,
                 pinned_targets: Optional[Iterable[int]] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._pin_ids = frozenset(
            int(t) for t in (() if pinned_targets is None
                             else pinned_targets))
        self._pinned: dict = {}               # never evicted
        self._lru: "OrderedDict[Hashable, tuple]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0                # entries dropped, not calls
        self._gen = 0                         # bumped by invalidate/clear

    # -- value hooks ---------------------------------------------------------
    def _freeze(self, value: Any) -> Any:
        """Normalize a value on insert (subclasses may copy/read-only it)."""
        return value

    def _footprint(self, value: Any) -> Optional[np.ndarray]:
        """Vertex ids invalidation scans when an entry has NO frontier
        (the pre-frontier approximation); None = always drop."""
        return None

    # -- core ----------------------------------------------------------------
    def get(self, key: Key) -> Optional[Any]:
        ent = self.get_entry(key)
        return None if ent is None else ent[0]

    def get_entry(self, key: Key) -> Optional[Tuple[Any, np.ndarray]]:
        """Like ``get`` but returns the full ``(value, frontier)`` entry —
        the Select stage hands a hit's frontier to the Build stage so a
        row-cache insert after a neighborhood hit stays frontier-exact."""
        with self._lock:
            ent = self._pinned.get(key)
            if ent is None:
                ent = self._lru.get(key)
                if ent is not None:
                    self._lru.move_to_end(key)
            if ent is None:
                self.misses += 1
                return None
            self.hits += 1
            return ent

    def put(self, key: Key, value: Any,
            generation: Optional[int] = None,
            frontier: Optional[np.ndarray] = None):
        """Insert a computed artifact. Pass the ``generation`` read BEFORE
        the computation started: if an invalidate() ran in between, the
        result may reflect the pre-update graph and is dropped (the next
        lookup recomputes). ``frontier`` is the push's full touched set
        (``select_important(with_frontier=True)``): with it, invalidation
        is EXACT; without it, invalidation falls back to scanning the
        value's footprint (approximate — updates at below-cutoff touched
        vertices go undetected)."""
        value = self._freeze(value)
        if frontier is not None:
            frontier = np.array(frontier)
            frontier.flags.writeable = False
        ent = (value, frontier)
        with self._lock:
            if generation is not None and generation != self._gen:
                return
            if key[0] in self._pin_ids:
                self._pinned[key] = ent
                return
            self._lru[key] = ent
            self._lru.move_to_end(key)
            while len(self._lru) > self.capacity:
                self._lru.popitem(last=False)
                self.evictions += 1

    def invalidate(self, vertices) -> int:
        """Drop every cached entry whose push FRONTIER contains any of
        ``vertices`` (pinned entries included). Returns the number of
        entries dropped.

        Entries stored with their full touched set are invalidated
        EXACTLY: an update at a vertex the push reached — even one below
        the top-N cutoff — drops the entry, because it can shift the
        target's scores enough to change its true top-N. Entries without
        a frontier (direct put() callers) fall back to scanning the
        value's footprint, the pre-frontier approximation."""
        vs = as_vertex_ids(vertices)

        def touched(ent) -> bool:
            scan = ent[1] if ent[1] is not None else self._footprint(ent[0])
            if scan is None:
                return True
            return bool(np.isin(scan, vs, assume_unique=False).any())

        # the O(entries * frontier) membership scan runs OUTSIDE the lock
        # so concurrent serving-path get/put calls don't stall behind a
        # graph update; the generation bump (taken first) keeps any
        # in-flight pre-update computation from landing afterwards
        with self._lock:
            self._gen += 1
            snapshot = [(store, list(store.items()))
                        for store in (self._pinned, self._lru)]
        stale = [(store, k, ent) for store, items in snapshot
                 for k, ent in items if touched(ent)]
        dropped = 0
        with self._lock:
            for store, k, ent in stale:
                # identity check: a fresh post-update recompute may have
                # replaced the entry while we scanned — keep that one
                if store.get(k) is ent:
                    del store[k]
                    dropped += 1
            self.invalidations += dropped
        return dropped

    def clear(self):
        with self._lock:
            self._gen += 1
            self._pinned.clear()
            self._lru.clear()

    @property
    def generation(self) -> int:
        """Invalidation epoch — snapshot before a miss's computation and
        hand to put()."""
        with self._lock:
            return self._gen

    # -- introspection -------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._pinned) + len(self._lru)

    def __contains__(self, key: Key) -> bool:
        with self._lock:
            return key in self._pinned or key in self._lru

    @property
    def num_pinned_targets(self) -> int:
        """Size of the configured evict-exempt target set (not the number
        of pinned entries currently cached — see stats())."""
        return len(self._pin_ids)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        with self._lock:
            return {"entries": len(self._pinned) + len(self._lru),
                    "pinned_entries": len(self._pinned),
                    "capacity": self.capacity,
                    "hits": self.hits, "misses": self.misses,
                    "hit_rate": round(self.hit_rate, 4),
                    "evictions": self.evictions,
                    "invalidations": self.invalidations}


class NeighborhoodCache(FrontierCache):
    """LRU + pinned-hot-set cache of per-target PPR node lists."""

    def _freeze(self, node_list: np.ndarray) -> np.ndarray:
        nl = np.array(node_list)              # copy: freezing an aliased
        nl.flags.writeable = False            # array would make the
        return nl                             # caller's list read-only

    def _footprint(self, node_list: np.ndarray) -> np.ndarray:
        # pre-frontier approximation: scan the truncated top-N selection
        return node_list


class SubgraphRowCache(FrontierCache):
    """LRU cache of built per-target subgraph rows (SubgraphRows): a hit
    skips the Build stage's induced-subgraph construction. Keyed by the
    same ``nbr_key`` as the neighborhood cache — the node list is
    deterministic in the key, so a neighborhood hit (or deterministic
    recompute) always corresponds to these rows — and invalidated by the
    same push frontier (the built rows only read vertices the push
    touched)."""

    def _freeze(self, rows):
        return rows.freeze()

    def _footprint(self, rows) -> Optional[np.ndarray]:
        return None      # no node list stored: drop conservatively
