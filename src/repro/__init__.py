"""Reproduction of 'Low-latency Mini-batch GNN Inference on CPU-FPGA
Heterogeneous Platform' grown into a JAX serving system."""

__version__ = "0.1.0"
