"""train_step / loss: the function lowered by the dry-run and the trainer."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import train_logits
from repro.train.optim import AdamWConfig, OptState, apply_updates
from repro.train.xent import softmax_xent

AUX_WEIGHT = 0.01
MTP_WEIGHT = 0.3


def loss_fn(cfg: ModelConfig, params, batch, remat=True):
    logits, extras = train_logits(cfg, params, batch, remat=remat)
    loss, _ = softmax_xent(logits, batch["labels"],
                           batch.get("loss_mask"))
    total = loss + AUX_WEIGHT * extras.get("aux_loss", 0.0)
    if "mtp_logits" in extras:
        # MTP predicts token t+2: shift labels by one more position
        mtp_labels = jnp.roll(batch["labels"], -1, axis=1)
        mtp_loss, _ = softmax_xent(extras["mtp_logits"], mtp_labels)
        total = total + MTP_WEIGHT * mtp_loss
    return total, {"xent": loss, "aux": extras.get("aux_loss", 0.0)}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, remat=True):
    def train_step(params, opt_state: OptState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, remat), has_aux=True)(params)
        params, opt_state, opt_metrics = apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step
