"""Cross-entropy over (possibly vocab-sharded) logits.

Reductions over the vocab dim are plain jnp ops; under pjit with logits
sharded ('vocab' -> 'model') GSPMD lowers the max/logsumexp to all-reduces
over the model axis, so no full-vocab gather is ever materialized.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent(logits, labels, mask=None):
    """logits [B,S,V] (any float dtype), labels [B,S] int32.
    Returns (mean loss fp32, per-token loss [B,S])."""
    lg = logits.astype(jnp.float32)
    m = jnp.max(lg, axis=-1, keepdims=True)
    shifted = lg - jax.lax.stop_gradient(m)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    label_logit = jnp.take_along_axis(lg, labels[..., None],
                                      axis=-1)[..., 0]
    per_tok = lse - label_logit
    if mask is None:
        mask = jnp.ones_like(per_tok)
    mask = mask.astype(jnp.float32)
    loss = jnp.sum(per_tok * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, per_tok
