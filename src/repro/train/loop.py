"""Fault-tolerant training loop.

Production behaviours exercised even at CPU smoke scale:
  * periodic atomic checkpoints + resume-from-latest (restart safety),
  * failure injection hook (simulated preemption) used by tests to prove
    loss-curve continuity across a kill/restore,
  * straggler-tolerant prefetching data pipeline,
  * metrics log (loss/grad-norm/step-time) appended as jsonl.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.configs.base import ModelConfig
from repro.data.pipeline import TokenPipelineConfig, token_pipeline
from repro.models.transformer import init_params
from repro.train.optim import AdamWConfig, init_opt
from repro.train.step import make_train_step


@dataclass
class TrainJobConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_path: Optional[str] = None
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    keep_ckpts: int = 3


def train(cfg: ModelConfig, job: TrainJobConfig,
          opt_cfg: Optional[AdamWConfig] = None,
          fail_at_step: Optional[int] = None,
          step_fn: Optional[Callable] = None):
    """Runs (or resumes) training; returns (params, opt_state, history).

    ``fail_at_step`` raises RuntimeError after the checkpoint at that step
    — the test harness uses it to simulate preemption, then calls train()
    again and checks the loss curve continues where it left off.
    """
    opt_cfg = opt_cfg or AdamWConfig(lr=1e-3,
                                     moment_dtype=cfg.dtype.opt_dtype)
    key = jax.random.PRNGKey(job.seed)
    params = init_params(cfg, key, max_seq=job.seq_len)
    opt_state = init_opt(params, opt_cfg)
    start_step = 0
    if ckpt.committed_steps(job.ckpt_dir):
        (params, opt_state), start_step, _ = ckpt.restore(
            job.ckpt_dir, (params, opt_state))

    step = jax.jit(step_fn or make_train_step(cfg, opt_cfg, remat=True))
    pipe_cfg = TokenPipelineConfig(vocab_size=cfg.vocab_size,
                                   seq_len=job.seq_len,
                                   global_batch=job.global_batch,
                                   seed=job.seed)
    pipe = token_pipeline(pipe_cfg)
    # fast-forward the deterministic pipeline to the resume point
    for _ in range(start_step):
        next(pipe)

    history = []
    try:
        for s in range(start_step, job.steps):
            batch = next(pipe)
            if cfg.family == "audio":
                rng = np.random.default_rng(s)
                batch["frames"] = rng.standard_normal(
                    (job.global_batch, cfg.encoder.n_frames, cfg.d_model)
                ).astype(np.float32)
            if cfg.family == "vlm":
                rng = np.random.default_rng(s)
                batch["patch_embeds"] = rng.standard_normal(
                    (job.global_batch, cfg.vision.n_patches, cfg.d_model)
                ).astype(np.float32)
            t0 = time.perf_counter()
            params, opt_state, metrics = step(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            rec = {"step": s + 1, "loss": loss,
                   "grad_norm": float(metrics["grad_norm"]),
                   "step_time_s": dt}
            history.append(rec)
            if job.log_path:
                with open(job.log_path, "a") as f:
                    f.write(json.dumps(rec) + "\n")
            if (s + 1) % job.ckpt_every == 0 or (s + 1) == job.steps:
                ckpt.save(job.ckpt_dir, s + 1, (params, opt_state),
                          extra={"loss": loss})
                ckpt.prune(job.ckpt_dir, keep=job.keep_ckpts)
            if fail_at_step is not None and (s + 1) >= fail_at_step:
                raise RuntimeError(f"injected failure at step {s + 1}")
    finally:
        pipe.close()
    return params, opt_state, history
