"""AdamW implemented from scratch (no optax dependency).

Moment dtype follows the config's DTypePolicy so >=100B archs can keep
moments in bf16 (with fp32 master update math) to fit HBM. ZeRO-1 sharding
of the moments is applied by the launcher via output shardings — the
optimizer itself is sharding-agnostic pure function.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init_opt(params, cfg: AdamWConfig) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros_like(p, dtype=dt)  # noqa: E731
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params))


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(params, grads, state: OptState, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mhat = m32 / bc1
        vhat = v32 / bc2
        step_dir = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:           # decoupled weight decay on matrices only
            step_dir = step_dir + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - cfg.lr * step_dir
        return new_p.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm}
