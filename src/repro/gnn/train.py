"""Decoupled-GNN training (node classification on subgraph batches).

The paper assumes pre-trained weights (inference-only accelerator); this
module produces them: shaDow-style training where each target's loss is
computed from its decoupled receptive field — the training analogue of
Algorithm 2, sharing the exact inference code path (gnn_forward).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.subgraph import build_batch
from repro.gnn.model import GNNConfig, gnn_forward, init_gnn
from repro.graphs.csr import CSRGraph
from repro.train.optim import AdamWConfig, apply_updates, init_opt


def make_gnn_train_step(cfg: GNNConfig, opt_cfg: AdamWConfig):
    assert cfg.num_classes, "training needs num_classes > 0"

    def loss_fn(params, batch, labels):
        logits, _ = gnn_forward(cfg, params, batch, mode="dense")
        lp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(lp, labels[:, None], axis=-1)[:, 0]
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(
            jnp.float32))
        return nll.mean(), acc

    @jax.jit
    def step(params, opt_state, batch, labels):
        (loss, acc), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, labels)
        params, opt_state, om = apply_updates(params, grads, opt_state,
                                              opt_cfg)
        return params, opt_state, {"loss": loss, "acc": acc, **om}

    return step


def train_gnn(g: CSRGraph, cfg: GNNConfig, *, steps: int = 200,
              batch_size: int = 32, lr: float = 1e-3, seed: int = 0,
              eval_every: int = 50, log=print) -> Dict:
    rng = np.random.default_rng(seed)
    params = init_gnn(cfg, jax.random.PRNGKey(seed))
    opt_cfg = AdamWConfig(lr=lr, weight_decay=0.0)
    opt_state = init_opt(params, opt_cfg)
    step = make_gnn_train_step(cfg, opt_cfg)
    history: List[dict] = []
    t0 = time.perf_counter()
    for s in range(steps):
        targets = rng.integers(0, g.num_vertices, size=batch_size)
        sb = build_batch(g, targets, cfg.receptive_field, num_threads=4,
                         alpha=cfg.ppr_alpha, eps=cfg.ppr_eps)
        batch = dict(feats=sb.feats, adj=sb.adj, adj_mean=sb.adj_mean,
                     mask=sb.mask)
        labels = jnp.asarray(g.labels[targets.astype(np.int64)])
        params, opt_state, m = step(params, opt_state, batch, labels)
        history.append({k: float(v) for k, v in m.items()})
        if eval_every and (s + 1) % eval_every == 0:
            recent = history[-eval_every:]
            log(f"  step {s+1}: loss "
                f"{np.mean([h['loss'] for h in recent]):.4f} acc "
                f"{np.mean([h['acc'] for h in recent]):.3f}")
    return {"params": params, "history": history,
            "wall_s": time.perf_counter() - t0}
