"""GNN layer operators on padded subgraph batches (the paper's §4.1 kernels).

Every layer is expressed in BOTH ACK execution modes:
  * dense mode   — aggregation as a [N,N] @ [N,f] matmul (TPU systolic/MXU
    path; the densified expression of the paper's Systolic Mode),
  * sg mode      — edge-list scatter-gather with ``segment_sum`` (the
    faithful Scatter-Gather Mode; also the reference for the Pallas SG
    kernel).

Shapes: feats h [C, N, f]; adj/adj_mean [C, N, N] (row = destination);
mask [C, N]; edges (src, dst, w) [C, E]. All ops are batched over C targets
(= the paper's N_pe parallel PEs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_keys

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# aggregation primitives (FA kernel, both modes)


def agg_dense(adj, h):
    """Feature aggregation as dense matmul: [C,N,N] @ [C,N,f]."""
    return jnp.einsum("cij,cjf->cif", adj, h,
                      preferred_element_type=jnp.float32).astype(h.dtype)


def agg_sg(src, dst, w, h, n):
    """Scatter-gather aggregation (Algorithm 4).

    Scatter: per edge, update = w * h[src]  (vector multiplier units)
    Gather:  segment-sum updates at dst     (accumulator units)
    """
    C, E = src.shape

    def one(src_c, dst_c, w_c, h_c):
        upd = h_c[src_c] * w_c[:, None]                 # Scatter
        return jax.ops.segment_sum(upd, dst_c, num_segments=n)  # Gather

    return jax.vmap(one)(src, dst, w, h)


# ---------------------------------------------------------------------------
# layer inits


def init_gcn_layer(key, f_in, f_out, dtype=jnp.float32):
    return {"w": dense_init(key, (f_in, f_out), dtype=dtype),
            "b": jnp.zeros((f_out,), dtype)}


def init_sage_layer(key, f_in, f_out, dtype=jnp.float32):
    ks = split_keys(key, 2)
    return {"w_self": dense_init(ks[0], (f_in, f_out), dtype=dtype),
            "w_neigh": dense_init(ks[1], (f_in, f_out), dtype=dtype),
            "b": jnp.zeros((f_out,), dtype)}


def init_gin_layer(key, f_in, f_out, dtype=jnp.float32):
    ks = split_keys(key, 2)
    return {"w1": dense_init(ks[0], (f_in, f_out), dtype=dtype),
            "b1": jnp.zeros((f_out,), dtype),
            "w2": dense_init(ks[1], (f_out, f_out), dtype=dtype),
            "b2": jnp.zeros((f_out,), dtype),
            "eps": jnp.zeros((), dtype)}


def init_appnp_layer(key, f_in, f_out, alpha=0.15, dtype=jnp.float32):
    """APPNP: layer0 is the prediction MLP; inner layers are
    propagation-ONLY — one teleport scalar, no transform weights. The
    inner Residual reads the ``h0`` register (the post-layer0 prediction,
    the APPNP teleport anchor) with into_gain = 1 - alpha, so each step
    is exactly h' = (1-a) A_hat h + (1 + teleport) h0 — the APPNP power
    iteration when 1 + teleport = alpha. ``teleport`` stays learnable;
    ``w``/``b`` ride along so the stacked inner params give lax.scan its
    length (the propagation ops never read them)."""
    return {"w": dense_init(key, (f_in, f_out), dtype=dtype),
            "b": jnp.zeros((f_out,), dtype),
            "teleport": jnp.asarray(alpha - 1.0, dtype)}


def init_sgc_layer(key, f_in, f_out, dtype=jnp.float32):
    """SGC: ONE weight matrix total. Layer0 applies it (transform-first —
    S^K (X W) == (S^K X) W by associativity, so this is the exact SGC
    logits map); inner layers are propagation-only, their ``w`` rides
    along unused so the stacked params give lax.scan its length."""
    return {"w": dense_init(key, (f_in, f_out), dtype=dtype)}


def init_gat_layer(key, f_in, f_out, n_heads, dtype=jnp.float32):
    assert f_out % n_heads == 0
    ks = split_keys(key, 3)
    fh = f_out // n_heads
    return {"w": dense_init(ks[0], (f_in, f_out), dtype=dtype),
            "a_src": dense_init(ks[1], (n_heads, fh), in_axis=-1,
                                dtype=dtype),
            "a_dst": dense_init(ks[2], (n_heads, fh), in_axis=-1,
                                dtype=dtype),
            "b": jnp.zeros((f_out,), dtype)}


# ---------------------------------------------------------------------------
# layer applies. Each takes (params, h, batch, mode) -> h'


def _ft(h, w, b):
    """Feature Transformation kernel (dense/systolic mode matmul)."""
    return jnp.einsum("cnf,fg->cng", h, w,
                      preferred_element_type=jnp.float32).astype(h.dtype) + b


def gcn_layer(p, h, batch, mode="dense", act=jax.nn.relu):
    if mode == "dense":
        z = agg_dense(batch["adj"], h)
    else:
        z = agg_sg(batch["edge_src"], batch["edge_dst"], batch["edge_w"], h,
                   h.shape[1])
        # self-loop term (normalized) is part of adj in dense mode; edges
        # exclude it, so add explicitly
        z = z + h * batch["self_w"][..., None]
    return act(_ft(z, p["w"], p["b"])) * batch["mask"][..., None]


def sage_layer(p, h, batch, mode="dense", act=jax.nn.relu):
    if mode == "dense":
        z = agg_dense(batch["adj_mean"], h)
    else:
        z = agg_sg(batch["edge_src"], batch["edge_dst"],
                   batch["edge_w_mean"], h, h.shape[1])
    out = _ft(h, p["w_self"], p["b"]) + _ft(z, p["w_neigh"],
                                            jnp.zeros((), h.dtype))
    return act(out) * batch["mask"][..., None]


def gin_layer(p, h, batch, mode="dense", act=jax.nn.relu):
    if mode == "dense":
        adj_bin = jnp.sign(batch["adj_mean"])
        z = agg_dense(adj_bin, h)
    else:
        ones = jnp.ones_like(batch["edge_w"])
        z = agg_sg(batch["edge_src"], batch["edge_dst"],
                   ones * (batch["edge_w"] != 0), h, h.shape[1])
    z = (1.0 + p["eps"]) * h + z
    hidden = act(_ft(z, p["w1"], p["b1"]))
    return act(_ft(hidden, p["w2"], p["b2"])) * batch["mask"][..., None]


def gat_layer(p, h, batch, mode="dense", act=jax.nn.elu,
              negative_slope=0.2):
    """Attention kernel (paper §4.1): e_ij from (h_i, h_j, W_att, a), then
    masked softmax over incoming edges, then weighted aggregation. Dense
    mode computes the full [N,N] score matrix (MXU-friendly at small N —
    exactly the decoupling payoff); sg mode is edge-parallel."""
    C, N, _ = h.shape
    nh, fh = p["a_src"].shape
    z = _ft(h, p["w"], jnp.zeros((), h.dtype)).reshape(C, N, nh, fh)
    s_src = jnp.einsum("cnhf,hf->cnh", z, p["a_src"])   # source term
    s_dst = jnp.einsum("cnhf,hf->cnh", z, p["a_dst"])   # destination term
    if mode == "dense":
        # scores[c,h,i,j] for edge j->i (i = dst), structure incl. self loop
        e = s_dst.transpose(0, 2, 1)[:, :, :, None] \
            + s_src.transpose(0, 2, 1)[:, :, None, :]
        e = jax.nn.leaky_relu(e, negative_slope)
        struct = (jnp.sign(batch["adj_mean"])
                  + jnp.eye(N, dtype=h.dtype)) * batch["mask"][:, None, :]
        emask = struct[:, None, :, :] > 0
        e = jnp.where(emask, e, NEG_INF)
        attn = jax.nn.softmax(e, axis=-1)
        attn = jnp.where(emask, attn, 0.0)
        out = jnp.einsum("chij,cjhf->cihf", attn, z)
    else:
        src, dst = batch["edge_src"], batch["edge_dst"]
        valid = (batch["edge_w"] != 0).astype(h.dtype)

        def one(src_c, dst_c, val_c, z_c, ss_c, sd_c):
            # self-loop handled by appending implicit (i, i) edges
            iota = jnp.arange(N, dtype=src_c.dtype)
            s_all = jnp.concatenate([src_c, iota])
            d_all = jnp.concatenate([dst_c, iota])
            v_all = jnp.concatenate([val_c, jnp.ones(N, h.dtype)])
            e = jax.nn.leaky_relu(sd_c[d_all] + ss_c[s_all], negative_slope)
            e = jnp.where(v_all[:, None] > 0, e, NEG_INF)
            m = jax.ops.segment_max(e, d_all, num_segments=N)
            ex = jnp.exp(e - m[d_all]) * v_all[:, None]
            den = jax.ops.segment_sum(ex, d_all, num_segments=N)
            alpha = ex / jnp.maximum(den[d_all], 1e-20)
            upd = alpha[:, :, None] * z_c[s_all]
            return jax.ops.segment_sum(upd, d_all, num_segments=N)

        out = jax.vmap(one)(src, dst, valid, z, s_src, s_dst)
    out = out.reshape(C, N, nh * fh) + p["b"]
    return act(out) * batch["mask"][..., None]


LAYER_INITS = {"gcn": init_gcn_layer, "sage": init_sage_layer,
               "gin": init_gin_layer}
LAYER_APPLY = {"gcn": gcn_layer, "sage": sage_layer, "gin": gin_layer,
               "gat": gat_layer}


# ---------------------------------------------------------------------------
# readout


def readout(h, mask, kind="max"):
    """h [C,N,f] -> [C,f]. Paper: element-wise Max over the receptive field
    (executed by ACK in scatter-gather mode)."""
    if kind == "target":
        return h[:, 0, :]
    if kind == "mean":
        s = jnp.sum(h * mask[..., None], axis=1)
        return s / jnp.maximum(jnp.sum(mask, axis=1), 1.0)[..., None]
    neg = jnp.where(mask[..., None] > 0, h, NEG_INF)
    return jnp.max(neg, axis=1)
