"""Decoupled GNN model assembly (paper §2.3 "Specification of Decoupled
model"): (1) L layers, (2) receptive-field size N, (3) the PPR sampling
algorithm (core.ini), (4) aggregate(), (5) hidden dims f_l, (6) update()
weights — plus the Readout().

Hidden dims follow the paper's evaluation: f_l = 256 for all layers, so the
L-1 inner layers are homogeneous and run under one ``lax.scan`` over stacked
weights (bounded HLO at L=16). The first layer maps f_in -> f_hidden.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.program import layer_init_for, lower_and_specialize
from repro.models.common import dense_init, split_keys


@dataclass(frozen=True)
class GNNConfig:
    kind: str                    # gcn | sage | gin | gat
    n_layers: int = 3            # L
    receptive_field: int = 128   # N
    f_in: int = 500
    f_hidden: int = 256          # paper: 256 for every layer
    n_heads: int = 4             # gat only (f_hidden % n_heads == 0)
    num_classes: int = 0         # 0 = emit embeddings only
    readout: str = "max"
    ppr_alpha: float = 0.15
    ppr_eps: float = 1e-4
    name: str = ""

    @property
    def display(self) -> str:
        return self.name or f"{self.kind}-L{self.n_layers}-N{self.receptive_field}"


def _init_layer(cfg: GNNConfig, key, f_in, f_out):
    # per-layer params come from the same registry as the lowering, so a
    # runtime-registered kind is constructible with no edits here
    return layer_init_for(cfg.kind)(cfg, key, f_in, f_out)


def init_gnn(cfg: GNNConfig, key):
    ks = split_keys(key, 4)
    p = {"layer0": _init_layer(cfg, ks[0], cfg.f_in, cfg.f_hidden)}
    if cfg.n_layers > 1:
        p["layers"] = jax.vmap(
            lambda k: _init_layer(cfg, k, cfg.f_hidden, cfg.f_hidden)
        )(jax.random.split(ks[1], cfg.n_layers - 1))
    if cfg.num_classes:
        p["cls_w"] = dense_init(ks[2], (cfg.f_hidden, cfg.num_classes))
        p["cls_b"] = jnp.zeros((cfg.num_classes,))
    return p


def gnn_forward(cfg: GNNConfig, params, batch, mode: str = "dense",
                impl: str = "xla"):
    """batch: device dict (see SubgraphBatch.device_arrays + derived keys).
    Returns (embeddings [C, f_hidden or num_classes], final h [C,N,f]).

    Thin wrapper over the AckProgram pipeline: lowers ``cfg`` through the
    model registry, forces every mux'd op to ``mode``, and executes. For
    per-op (auto/mixed) mode dispatch use ``core.program`` directly — the
    engine does."""
    from repro.core.program import execute
    prog, _ = lower_and_specialize(cfg, force=mode)
    return execute(prog, params, batch, impl=impl)


# the paper's evaluated sweep (§5.2): 3 models x L in {3,5,8,16} x
# N in {64,128,256}, hidden 256
PAPER_MODELS = ("gcn", "sage", "gat")
PAPER_LAYERS = (3, 5, 8, 16)
PAPER_N = (64, 128, 256)


def paper_model_grid(f_in: int = 500, num_classes: int = 0):
    for kind in PAPER_MODELS:
        for L in PAPER_LAYERS:
            for N in PAPER_N:
                yield GNNConfig(kind=kind, n_layers=L, receptive_field=N,
                                f_in=f_in, num_classes=num_classes)
