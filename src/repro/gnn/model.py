"""Decoupled GNN model assembly (paper §2.3 "Specification of Decoupled
model"): (1) L layers, (2) receptive-field size N, (3) the PPR sampling
algorithm (core.ini), (4) aggregate(), (5) hidden dims f_l, (6) update()
weights — plus the Readout().

Hidden dims follow the paper's evaluation: f_l = 256 for all layers, so the
L-1 inner layers are homogeneous and run under one ``lax.scan`` over stacked
weights (bounded HLO at L=16). The first layer maps f_in -> f_hidden.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.gnn.layers import (LAYER_APPLY, LAYER_INITS, gat_layer,
                              init_gat_layer, readout)
from repro.models.common import dense_init, split_keys


@dataclass(frozen=True)
class GNNConfig:
    kind: str                    # gcn | sage | gin | gat
    n_layers: int = 3            # L
    receptive_field: int = 128   # N
    f_in: int = 500
    f_hidden: int = 256          # paper: 256 for every layer
    n_heads: int = 4             # gat only (f_hidden % n_heads == 0)
    num_classes: int = 0         # 0 = emit embeddings only
    readout: str = "max"
    ppr_alpha: float = 0.15
    ppr_eps: float = 1e-4
    name: str = ""

    @property
    def display(self) -> str:
        return self.name or f"{self.kind}-L{self.n_layers}-N{self.receptive_field}"


def _init_layer(cfg: GNNConfig, key, f_in, f_out):
    if cfg.kind == "gat":
        return init_gat_layer(key, f_in, f_out, cfg.n_heads)
    return LAYER_INITS[cfg.kind](key, f_in, f_out)


def init_gnn(cfg: GNNConfig, key):
    ks = split_keys(key, 4)
    p = {"layer0": _init_layer(cfg, ks[0], cfg.f_in, cfg.f_hidden)}
    if cfg.n_layers > 1:
        p["layers"] = jax.vmap(
            lambda k: _init_layer(cfg, k, cfg.f_hidden, cfg.f_hidden)
        )(jax.random.split(ks[1], cfg.n_layers - 1))
    if cfg.num_classes:
        p["cls_w"] = dense_init(ks[2], (cfg.f_hidden, cfg.num_classes))
        p["cls_b"] = jnp.zeros((cfg.num_classes,))
    return p


def _apply_layer(cfg: GNNConfig, p, h, batch, mode):
    if cfg.kind == "gat":
        return gat_layer(p, h, batch, mode)
    return LAYER_APPLY[cfg.kind](p, h, batch, mode)


def gnn_forward(cfg: GNNConfig, params, batch, mode: str = "dense",
                layer_fn=None):
    """batch: device dict (see SubgraphBatch.device_arrays + derived keys).
    Returns (embeddings [C, f_hidden or num_classes], final h [C,N,f]).

    ``layer_fn`` optionally overrides the inner-layer apply (the engine
    injects the Pallas ACK kernels here; default is the pure-jnp path)."""
    apply = layer_fn or (lambda p, h: _apply_layer(cfg, p, h, batch, mode))
    h = apply(params["layer0"], batch["feats"])
    if cfg.n_layers > 1:
        def body(hh, lp):
            return apply(lp, hh), None
        h, _ = jax.lax.scan(body, h, params["layers"])
    emb = readout(h, batch["mask"], cfg.readout)
    if cfg.num_classes:
        emb = emb @ params["cls_w"] + params["cls_b"]
    return emb, h


def sg_extras(batch_np, adj, edge_src, edge_dst):
    """Derived arrays the sg mode needs beyond SubgraphBatch.device_arrays:
    per-vertex self-loop weights and row-mean edge weights."""
    import numpy as np
    C, N, _ = adj.shape
    self_w = adj[:, np.arange(N), np.arange(N)]
    # mean-normalized edge weights for SAGE: 1/indeg(dst)
    indeg = np.zeros((C, N), np.float32)
    valid = batch_np.edge_w != 0
    for c in range(C):
        np.add.at(indeg[c], edge_dst[c][valid[c]], 1.0)
    ew_mean = np.where(valid,
                       1.0 / np.maximum(indeg[np.arange(C)[:, None],
                                              edge_dst], 1.0),
                       0.0).astype(np.float32)
    return self_w.astype(np.float32), ew_mean


# the paper's evaluated sweep (§5.2): 3 models x L in {3,5,8,16} x
# N in {64,128,256}, hidden 256
PAPER_MODELS = ("gcn", "sage", "gat")
PAPER_LAYERS = (3, 5, 8, 16)
PAPER_N = (64, 128, 256)


def paper_model_grid(f_in: int = 500, num_classes: int = 0):
    for kind in PAPER_MODELS:
        for L in PAPER_LAYERS:
            for N in PAPER_N:
                yield GNNConfig(kind=kind, n_layers=L, receptive_field=N,
                                f_in=f_in, num_classes=num_classes)
