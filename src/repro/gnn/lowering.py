"""Builtin model lowerings: GNN kind -> ACK instruction stream.

Each lowering maps one GNN variant onto the typed op vocabulary in
``core.program`` (the paper's kernel taxonomy). The registry entry also
carries the per-layer parameter initializer, so a kind registered here —
or at runtime by a user — is immediately constructible (``init_gnn``),
servable (``DecoupledEngine``/``GNNServer``) and admissible (DSE plan
checks), with no engine/model/dse edits.

The lowering table (layer template; layer0 and inner layers share it,
differing only in feature widths):

  gcn   Aggregate[gcn]    -> Transform[w]            (relu)
  sage  Aggregate[mean]   -> Transform[w_neigh + w_self]  (relu)
  gin   Aggregate[binary] -> Residual[(1+eps) h]
                          -> Transform[w1] -> Transform[w2]   (relu, relu)
  gat   Transform[w] (none) -> AttentionScore -> AttentionSoftmax (elu)
  appnp layer0: Transform[w] (relu)   — the prediction MLP
        inner:  Aggregate[gcn] -> Residual[(1+teleport) h0, gain 1-a]
        (propagation-only inner template: NO Transform — h' =
        (1-a) A_hat h + (1+teleport) h0, the exact APPNP power step)
  sgc   layer0: Transform[w] (none)   — the single linear map
        inner:  Aggregate[gcn]        — pure propagation, K = L-1 steps
        (h_L = S^(L-1) (X W) == (S^(L-1) X) W: the SGC S^K X W recurrence
        with the transform hoisted in front by associativity)

Tail: Readout[cfg.readout] and, when ``cfg.num_classes`` is set, Classify.
"""
from __future__ import annotations

from typing import Tuple

from repro.core.program import (AckOp, AckProgram, Aggregate,
                                AttentionScore, AttentionSoftmax, Classify,
                                Readout, Residual, Transform,
                                register_lowering)
from repro.gnn.layers import (init_appnp_layer, init_gat_layer,
                              init_gcn_layer, init_gin_layer,
                              init_sage_layer, init_sgc_layer)


def _tail(cfg) -> Tuple[AckOp, ...]:
    tail: Tuple[AckOp, ...] = (Readout(kind=cfg.readout),)
    if cfg.num_classes:
        tail += (Classify(),)
    return tail


def _program(cfg, layer_ops: Tuple[AckOp, ...]) -> AckProgram:
    return AckProgram(kind=cfg.kind, layer0=layer_ops, inner=layer_ops,
                      tail=_tail(cfg), n_layers=cfg.n_layers)


@register_lowering("gcn",
                   layer_init=lambda cfg, key, fi, fo:
                   init_gcn_layer(key, fi, fo))
def lower_gcn(cfg) -> AckProgram:
    return _program(cfg, (
        Aggregate(norm="gcn"),
        Transform(w="w", b="b", act="relu"),
    ))


@register_lowering("sage",
                   layer_init=lambda cfg, key, fi, fo:
                   init_sage_layer(key, fi, fo))
def lower_sage(cfg) -> AckProgram:
    return _program(cfg, (
        Aggregate(norm="mean"),
        Transform(w="w_neigh", w_self="w_self", b="b", act="relu"),
    ))


@register_lowering("gin",
                   layer_init=lambda cfg, key, fi, fo:
                   init_gin_layer(key, fi, fo))
def lower_gin(cfg) -> AckProgram:
    return _program(cfg, (
        Aggregate(norm="binary"),
        Residual(src="h_in", into="z", eps_param="eps"),
        Transform(w="w1", b="b1", act="relu", src="z", out="h2",
                  masked=False),
        Transform(w="w2", b="b2", act="relu", src="h2", out="h"),
    ))


@register_lowering("appnp",
                   layer_init=lambda cfg, key, fi, fo:
                   init_appnp_layer(key, fi, fo, cfg.ppr_alpha))
def lower_appnp(cfg) -> AckProgram:
    """Predict-then-propagate: layer0 is the MLP, every inner layer is a
    PROPAGATION-ONLY template (Aggregate + teleport Residual, no
    Transform) — the op-vocabulary stress case: a layer section with no
    weight matmul, whose mux'd Aggregate still gets its own dense/sg
    decision. The Residual teleports to the ``h0`` register (the
    post-layer0 prediction) with into_gain = 1 - alpha: h' =
    (1-a) A_hat h + (1+teleport) h0, the exact APPNP power step at the
    initializer's 1 + teleport = alpha."""
    return AckProgram(kind=cfg.kind, layer0=(
        Transform(w="w", b="b", act="relu", src="h", out="h"),
    ), inner=(
        Aggregate(norm="gcn", src="h", out="h"),
        Residual(src="h0", into="h", eps_param="teleport",
                 into_gain=1.0 - cfg.ppr_alpha),
    ), tail=_tail(cfg), n_layers=cfg.n_layers)


@register_lowering("sgc",
                   layer_init=lambda cfg, key, fi, fo:
                   init_sgc_layer(key, fi, fo))
def lower_sgc(cfg) -> AckProgram:
    """Simplified GCN (SGC): K propagation steps and ONE linear map —
    logits = S^K X W, no nonlinearity between steps. Lowered
    transform-first (layer0 applies W, every inner layer is a pure
    Aggregate[gcn] propagation): h_L = S^(L-1) (X W), which equals the
    canonical (S^(L-1) X) W by matmul associativity — so an L-layer sgc
    program runs K = L-1 SGC propagation steps exactly, and the inner
    Aggregate still gets its own dense/sg mux (a second propagation-only
    template next to APPNP, with no Residual at all)."""
    return AckProgram(kind=cfg.kind, layer0=(
        Transform(w="w", b=None, act="none", src="h", out="h"),
    ), inner=(
        Aggregate(norm="gcn", src="h", out="h"),
    ), tail=_tail(cfg), n_layers=cfg.n_layers)


@register_lowering("gat",
                   layer_init=lambda cfg, key, fi, fo:
                   init_gat_layer(key, fi, fo, cfg.n_heads))
def lower_gat(cfg) -> AckProgram:
    return _program(cfg, (
        Transform(w="w", b=None, act="none", src="h", out="z",
                  masked=False),
        AttentionScore(n_heads=cfg.n_heads),
        AttentionSoftmax(b="b", act="elu", n_heads=cfg.n_heads),
    ))
