"""Precompute artifact persistence — the embedding table via repro.ckpt.

The artifact is one committed checkpoint step holding the [V, f_out]
embedding matrix, stamped with fingerprints of everything the rows are a
pure function of: the graph's CSR arrays + features, the model
signature, and the parameter values. Loading validates every stamp
against the live deployment — a mutated graph or different weights must
fail loudly with a rebuild instruction, never serve stale embeddings.
"""
from __future__ import annotations

import hashlib

import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.precompute.propagate import PrecomputeError


class PrecomputeArtifactError(PrecomputeError):
    """Artifact does not match the live graph/model deployment."""


def _sha(*arrays) -> str:
    h = hashlib.sha256()
    for a in arrays:
        a = np.ascontiguousarray(a)
        h.update(str((a.dtype.str, a.shape)).encode())
        h.update(a.tobytes())
    return h.hexdigest()[:16]


def graph_fingerprint(graph) -> str:
    return _sha(graph.indptr, graph.indices, graph.features)


def params_fingerprint(params) -> str:
    import jax
    leaves, _ = jax.tree_util.tree_flatten(params)
    return _sha(*[np.asarray(x) for x in leaves])


def model_signature(cfg) -> dict:
    return {"kind": cfg.kind, "n_layers": cfg.n_layers,
            "f_in": cfg.f_in, "f_hidden": cfg.f_hidden,
            "num_classes": cfg.num_classes, "readout": cfg.readout,
            "ppr_alpha": cfg.ppr_alpha}


def save_artifact(out_dir: str, embeddings: np.ndarray, graph, cfg,
                  params, generation: int = 0) -> str:
    """Write the embedding matrix + stamps as one committed ckpt step;
    returns the artifact directory."""
    extra = {"schema": 1,
             "graph_fingerprint": graph_fingerprint(graph),
             "params_fingerprint": params_fingerprint(params),
             "model": model_signature(cfg),
             "generation": int(generation),
             "num_vertices": int(embeddings.shape[0]),
             "f_out": int(embeddings.shape[1])}
    ckpt.save(out_dir, 0, {"embeddings": np.asarray(embeddings,
                                                    np.float32)},
              extra=extra)
    return out_dir


def load_artifact(path: str, graph, cfg, params) -> np.ndarray:
    """Load + validate an artifact against the live deployment. Raises
    ``PrecomputeArtifactError`` naming the first mismatched stamp."""
    tree, _, extra = ckpt.restore(
        path, {"embeddings": np.zeros((0, 0), np.float32)})
    remedy = (f"rebuild it with `python -m repro.precompute.build "
              f"--out {path}` (plus the deployment's --dataset/--kind "
              f"flags) or drop PrecomputeConfig(artifact=...) to build "
              f"at engine construction")
    checks = [
        ("graph_fingerprint", graph_fingerprint(graph),
         "the graph (CSR structure or features) has changed since the "
         "artifact was built — its rows would silently serve wrong "
         "embeddings"),
        ("model", model_signature(cfg),
         "the model configuration differs from the one the artifact was "
         "built for"),
        ("params_fingerprint", params_fingerprint(params),
         "the model parameters differ from the ones the artifact was "
         "built with (seed / checkpoint mismatch)"),
    ]
    for key, live, why in checks:
        if extra.get(key) != live:
            raise PrecomputeArtifactError(
                f"stale precompute artifact at {path!r}: {key} mismatch "
                f"(artifact {extra.get(key)!r} vs live {live!r}). "
                f"{why}; {remedy}.")
    emb = np.asarray(tree["embeddings"], np.float32)
    if emb.shape[0] != graph.num_vertices:
        raise PrecomputeArtifactError(
            f"stale precompute artifact at {path!r}: {emb.shape[0]} rows "
            f"vs {graph.num_vertices} live vertices; {remedy}.")
    return emb
