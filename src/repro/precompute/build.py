"""Offline artifact builder CLI:

  PYTHONPATH=src python -m repro.precompute.build \\
      --dataset flickr --scale 0.01 --kind sgc --out /tmp/sgc_tier

Builds the full-graph layer-major embedding table for one (dataset,
model) deployment and persists it via repro.ckpt, stamped with the
graph/model/params fingerprints ``load_artifact`` validates against.
An engine loads it with ``PrecomputeConfig(artifact=<out>)`` — the
deployment must use the SAME graph (dataset/scale/seed) and the same
model seed, or loading fails with the actionable mismatch error.
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.core.program import lower, specialize
from repro.gnn.model import GNNConfig, init_gnn
from repro.graphs.synthetic import get_graph
from repro.precompute.artifact import save_artifact
from repro.precompute.propagate import layer_major_embeddings


def build(graph, cfg: GNNConfig, params, out: str,
          chunk_size: int = 2048) -> dict:
    """Programmatic entry: build + persist, returns a summary dict."""
    prog, _ = specialize(lower(cfg), n=cfg.receptive_field,
                         f_in=cfg.f_in, f_hidden=cfg.f_hidden)
    emb = layer_major_embeddings(graph, prog, params,
                                 chunk_size=chunk_size)
    save_artifact(out, emb, graph, cfg, params)
    return {"out": out, "num_vertices": int(emb.shape[0]),
            "f_out": int(emb.shape[1]),
            "bytes": int(emb.nbytes), "kind": cfg.kind,
            "n_layers": cfg.n_layers}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="build the offline precompute embedding artifact")
    ap.add_argument("--dataset", default="flickr",
                    help="synthetic dataset name (flickr/reddit/...)")
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--graph-seed", type=int, default=0)
    ap.add_argument("--kind", default="sgc",
                    help="model kind (must lower to a precomputable "
                         "program, e.g. sgc/appnp/gcn)")
    ap.add_argument("--layers", type=int, default=3)
    ap.add_argument("--hidden", type=int, default=256)
    ap.add_argument("--classes", type=int, default=0)
    ap.add_argument("--rf", type=int, default=128,
                    help="receptive field of the serving deployment")
    ap.add_argument("--seed", type=int, default=0,
                    help="model param seed — must match the serving "
                         "ServingConfig(seed=...)")
    ap.add_argument("--chunk-size", type=int, default=2048)
    ap.add_argument("--out", required=True)
    a = ap.parse_args(argv)
    g = get_graph(a.dataset, scale=a.scale, seed=a.graph_seed)
    cfg = GNNConfig(kind=a.kind, n_layers=a.layers,
                    receptive_field=a.rf, f_in=g.feature_dim,
                    f_hidden=a.hidden, num_classes=a.classes,
                    readout="target")
    params = init_gnn(cfg, jax.random.PRNGKey(a.seed))
    info = build(g, cfg, params, a.out, chunk_size=a.chunk_size)
    info["avg_degree"] = round(float(np.mean(g.degrees)), 2)
    print(json.dumps(info))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
