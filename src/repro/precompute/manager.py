"""PrecomputeManager + TierStage — the hybrid router's moving parts.

The manager owns the deployment's EmbeddingTier: it builds (or loads)
the offline table at engine construction, demotes the dependency ball of
every graph update (wired into ``DecoupledEngine.invalidate``, which the
graph's update listener machinery already calls), and re-promotes
demoted vertices from a background refresh pool in ``chunk_size``
batches — each refresh chunk runs the SAME subset-mode layer-major
propagation as the full build, so a refreshed row is bitwise what a
fresh offline build would store.

``TierStage`` is the router: stage 0 of the host pipeline. All-fresh
batches short-circuit the pipeline entirely (Select/Build/Pack pass the
plan through untouched; ``run_device`` returns the gathered rows).
Mixed batches are SPLIT: the stale targets ride the online PPR pipeline
(padded to the fixed batch size, so the one compiled program still
serves), and ``run_device`` rejoins tier rows with online rows on the
ticket via the plan's ``online_index`` map.
"""
from __future__ import annotations

import itertools
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from contextlib import nullcontext
from types import SimpleNamespace
from typing import Dict, Optional

import numpy as np

from repro.core.batchplan import BatchPlan, PlanStage
from repro.core.program import Classify, Transform
from repro.precompute.propagate import (agg_hops, check_precomputable,
                                        dependency_closure,
                                        layer_major_embeddings)
from repro.precompute.tier import EmbeddingTier
from repro.store.nbr_cache import as_vertex_ids


def output_dim(prog, cfg) -> int:
    """Embedding width the program emits per vertex (readout='target')."""
    f = cfg.f_in
    for _, op in prog.ops:
        if isinstance(op, Transform):
            f = cfg.f_hidden
        elif isinstance(op, Classify):
            f = cfg.num_classes
    return f


class PrecomputeManager:
    """Owns the tier, the refresh backlog, and the refresh worker pool
    for one deployment (engine holds exactly one, or None)."""

    def __init__(self, engine, pconf, params):
        self.engine = engine
        self.pconf = pconf
        self.params = params              # UNPADDED model params
        self.prog = engine.program
        check_precomputable(self.prog)
        self.hops = agg_hops(self.prog)
        graph = engine.graph
        self.tier = EmbeddingTier(
            graph.num_vertices, output_dim(self.prog, engine.cfg),
            budget_bytes=pconf.budget_bytes,
            degrees=np.asarray(graph.degrees))
        self.builds = 0
        self.refresh_chunks = 0
        self.refresh_errors = 0
        self._backlog: Dict[int, None] = {}     # ordered pending set
        self._lock = threading.Lock()
        self._futures: list = []
        self._closed = False
        self._pool = ThreadPoolExecutor(
            max_workers=pconf.refresh_workers,
            thread_name_prefix="refresh")
        if pconf.artifact:
            from repro.precompute.artifact import load_artifact
            emb = load_artifact(pconf.artifact, graph, engine.cfg, params)
            ids = self.tier.resident_ids
            self.tier.install(ids, emb[ids])
        else:
            ids = self.tier.resident_ids
            rows = layer_major_embeddings(
                graph, self.prog, params, chunk_size=pconf.chunk_size,
                out_ids=None if len(ids) == graph.num_vertices else ids)
            self.tier.install(ids, rows)
            self.builds = 1

    # -- serving -------------------------------------------------------------
    def lookup(self, targets):
        return self.tier.lookup(targets)

    # -- invalidation / refresh ----------------------------------------------
    def on_invalidate(self, vertices) -> int:
        """Demote the dependency ball of the touched vertices (every
        vertex whose embedding reads any of them within the program's
        aggregate radius) and enqueue them for refresh. Runs on the
        graph-update caller's thread, AFTER the CSR swap — the ball is
        computed on the post-update graph, whose edges are exactly the
        ones the demoted embeddings now depend on."""
        ids = as_vertex_ids(vertices)
        if not len(ids):
            return 0
        g = self.engine.graph
        snap = SimpleNamespace(indptr=g.indptr, indices=g.indices)
        ball = dependency_closure(snap, ids, self.hops)
        demoted = self.tier.demote(ball)
        if len(demoted):
            with self._lock:
                for v in demoted.tolist():
                    self._backlog[v] = None
            if self.pconf.auto_refresh:
                self._kick()
        return len(demoted)

    def _kick(self):
        with self._lock:
            if self._closed:
                return
            self._futures = [f for f in self._futures if not f.done()]
            if len(self._futures) < self.pconf.refresh_workers:
                self._futures.append(
                    self._pool.submit(self._refresh_loop))

    def _refresh_loop(self):
        """Pop ≤ chunk_size vertices off the backlog and recompute their
        rows via subset layer-major propagation; repeat until drained.
        Promotion is epoch-guarded: a demote landing mid-chunk wins (its
        re-enqueued entry recomputes against the newer graph)."""
        while not self._closed:
            with self._lock:
                take = list(itertools.islice(
                    self._backlog, self.pconf.chunk_size))
                for v in take:
                    del self._backlog[v]
            if not take:
                return
            ids = np.asarray(take, np.int64)
            epochs = self.tier.epoch_of(ids)
            tr = self.engine.tracer
            tm = getattr(self.engine, "telemetry", None)
            cm = tr.root_span("refresh.chunk", cat="precompute",
                              n_vertices=len(ids)) \
                if tr is not None else nullcontext()
            t0 = time.perf_counter()
            try:
                with cm:
                    rows = layer_major_embeddings(
                        self.engine.graph, self.prog, self.params,
                        chunk_size=self.pconf.chunk_size, out_ids=ids)
                self.tier.promote(ids, rows, epochs)
                with self._lock:
                    self.refresh_chunks += 1
                if tm is not None:
                    tm.whist("repro_refresh_chunk_seconds",
                             help="tier refresh chunk wall time"
                             ).record(time.perf_counter() - t0)
            except Exception:       # a failed chunk must not kill the
                with self._lock:    # worker; its vertices stay demoted
                    self.refresh_errors += 1    # (served online) until
                if self._closed:                # the next demote re-adds
                    return                      # them

    def drain(self, timeout: Optional[float] = 60.0):
        """Process the refresh backlog to completion (tests, maintenance
        windows, orderly shutdown): the caller thread helps drain, then
        waits out any in-flight worker chunks."""
        self._refresh_loop()
        with self._lock:
            futs = list(self._futures)
        for f in futs:
            f.result(timeout)
        self._refresh_loop()        # entries re-added by racing demotes

    # -- reporting -----------------------------------------------------------
    def report(self) -> dict:
        s = self.tier.stats()
        total = s["hits"] + s["misses"]
        with self._lock:
            backlog = len(self._backlog)
            chunks, errors = self.refresh_chunks, self.refresh_errors
        return {"enabled": True, **s,
                "hit_rate": s["hits"] / total if total else 0.0,
                "refresh_backlog": backlog, "refresh_chunks": chunks,
                "refresh_errors": errors, "builds": self.builds}

    def close(self):
        self._closed = True
        self._pool.shutdown(wait=True, cancel_futures=True)


class TierStage(PlanStage):
    """Stage 0 of the hybrid host pipeline: look every target up in the
    tier, short-circuit all-fresh batches, split mixed ones."""

    name = "tier"

    def __init__(self, engine):
        self.engine = engine

    def run(self, plan) -> BatchPlan:
        if not isinstance(plan, BatchPlan):   # pipeline entry: raw targets
            plan = BatchPlan(targets=np.asarray(plan))
        eng = self.engine
        tr = eng.tracer
        cm = tr.span("tier.lookup", cat="precompute") \
            if tr is not None else nullcontext()
        with cm:
            rows, fresh = eng.precompute.lookup(plan.targets)
            if tr is not None:
                tr.annotate(tier_fresh=int(fresh.sum()),
                            n_targets=len(fresh))
        plan.tier_rows = rows
        plan.tier_fresh = fresh
        if fresh.all():
            # fast path: row gather IS the answer — Select/Build/Pack
            # pass the plan through untouched, run_device returns rows
            plan.tier_done = True
            return plan
        if fresh.any():
            # split: only the stale targets ride the online pipeline,
            # padded to the fixed batch size (one compiled program);
            # run_device rejoins on online_index
            stale = plan.targets[~fresh]
            plan.online_index = np.zeros(len(fresh), np.int64)
            plan.online_index[~fresh] = np.arange(len(stale))
            plan.orig_targets = plan.targets
            plan.targets = np.concatenate(
                [stale, np.repeat(stale[-1:], len(fresh) - len(stale))])
        return plan
