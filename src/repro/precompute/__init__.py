"""Offline layer-major precompute tier + hybrid serving (docs/PRECOMPUTE.md).

Decoupled models make propagation a pure function of the graph: S^K X
can be computed ONCE, layer-major, over the full graph — then serving a
precomputed vertex is a row lookup, no PPR push, no subgraph build.
This package holds the offline propagation engine (propagate), the
freshness-tracked embedding table (tier), the hybrid router + refresh
workers (manager), artifact persistence (artifact, build), and the
``ServingConfig(precompute=...)`` knobs (config).
"""
from repro.precompute.artifact import (PrecomputeArtifactError,
                                       load_artifact, save_artifact)
from repro.precompute.config import PrecomputeConfig
from repro.precompute.manager import PrecomputeManager, TierStage
from repro.precompute.propagate import (PrecomputeError, agg_hops,
                                        check_precomputable,
                                        dependency_closure,
                                        layer_major_embeddings)
from repro.precompute.tier import EmbeddingTier

__all__ = ["PrecomputeConfig", "PrecomputeError",
           "PrecomputeArtifactError", "EmbeddingTier",
           "PrecomputeManager", "TierStage", "layer_major_embeddings",
           "dependency_closure", "check_precomputable", "agg_hops",
           "save_artifact", "load_artifact"]
