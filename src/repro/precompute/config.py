"""PrecomputeConfig — the offline-tier knobs on ``ServingConfig``.

``ServingConfig(precompute=PrecomputeConfig(...))`` turns the hybrid
serving tier on for a deployment: the engine builds (or loads) the
full-graph layer-major embedding table at construction and serves
tier-fresh targets from it, falling back to the online PPR pipeline for
cold / recently-updated vertices (see repro.precompute)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class PrecomputeConfig:
    """Offline embedding-tier configuration.

    models:          model kinds the tier applies to (None = any kind
                     whose lowered program is precomputable — pure
                     Aggregate/Residual/Transform layers + Readout[target])
    chunk_size:      destination vertices per offline propagation chunk —
                     bounds working memory at one hop x chunk and sets the
                     refresh granularity
    refresh_workers: background threads re-promoting demoted vertices
    budget_bytes:    embedding-table byte cap; None = whole graph
                     resident. Over-budget vertices (lowest degree first)
                     stay permanently cold and serve online.
    artifact:        path of a ``repro.precompute.build`` artifact to load
                     instead of building at engine construction (validated
                     against the live graph/model — see artifact.py)
    auto_refresh:    schedule refresh chunks as soon as vertices demote;
                     False = accumulate backlog until ``drain()`` (tests /
                     controlled maintenance windows)
    """
    models: Optional[Tuple[str, ...]] = None
    chunk_size: int = 2048
    refresh_workers: int = 1
    budget_bytes: Optional[int] = None
    artifact: Optional[str] = None
    auto_refresh: bool = True

    def __post_init__(self):
        if self.models is not None and not isinstance(self.models, tuple):
            object.__setattr__(self, "models", tuple(self.models))
        if self.chunk_size < 1:
            raise ValueError(f"chunk_size={self.chunk_size}, expected >= 1")
        if self.refresh_workers < 1:
            raise ValueError(
                f"refresh_workers={self.refresh_workers}, expected >= 1")
        if self.budget_bytes is not None and self.budget_bytes < 0:
            raise ValueError(
                f"budget_bytes={self.budget_bytes}, expected >= 0 or None")

    def describe(self) -> dict:
        return {"models": list(self.models) if self.models else None,
                "chunk_size": self.chunk_size,
                "refresh_workers": self.refresh_workers,
                "budget_bytes": self.budget_bytes,
                "artifact": self.artifact,
                "auto_refresh": self.auto_refresh}
