"""Layer-major offline propagation — full-graph embeddings, one layer at
a time (VoVAllen/DGL ``inference()`` pattern).

The online path evaluates the whole L-layer program on each target's
induced subgraph. Offline we exploit the converse decomposition: compute
layer ``l``'s output for EVERY vertex before touching layer ``l+1``, so
working memory is bounded by one [V, f] register per live value plus a
one-hop × ``chunk_size`` aggregation working set — never L hops of
neighborhood fan-out. The op streams executed are the SAME lowered
``AckProgram`` sections the online engine jits (Aggregate through the
scatter-gather ACK kernel ``agg_sg``, Transform through ``_ft``,
Residual against the ``h0`` teleport anchor), so a precomputed row
matches what the online path would produce for a full-coverage subgraph.

``out_ids`` turns the same code path into the refresh primitive: the
dependency closure (one inbound hop per executed Aggregate) is computed,
propagation runs on the induced sub-CSR with GLOBAL degree
normalization, and only the requested rows come back — bitwise what a
full rebuild would store for them, because it IS the full rebuild
restricted to the rows' dependency cone.
"""
from __future__ import annotations

import functools
from types import SimpleNamespace
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.program import (ACTS, AckProgram, Aggregate, Classify,
                                Readout, Residual, Transform)
from repro.gnn.layers import _ft, agg_sg
from repro.graphs.csr import _gather_ranges, subgraph_edges


class PrecomputeError(ValueError):
    """The lowered program cannot be served from the offline tier."""


def check_precomputable(prog: AckProgram) -> None:
    """Raise PrecomputeError unless every executed layer op is pure
    propagation (Aggregate/Residual/Transform) and the readout is the
    target row — the regime where one stored row per vertex IS the
    online answer."""
    for site, op in prog.ops:
        if site.startswith("tail"):
            if isinstance(op, Readout) and op.kind != "target":
                raise PrecomputeError(
                    f"{prog.kind!r} is not precomputable: Readout"
                    f"[{op.kind}] reduces over the induced SUBGRAPH, so "
                    "the answer is not one row per vertex. Only "
                    "readout='target' models can serve from the offline "
                    "tier; route this model through the online path "
                    "(drop it from PrecomputeConfig.models).")
        elif not isinstance(op, (Aggregate, Residual, Transform)):
            raise PrecomputeError(
                f"{prog.kind!r} is not precomputable: {site} executes "
                f"{op.describe()}, but offline layer-major propagation "
                "supports pure Aggregate/Residual/Transform layers "
                "(attention softmax support depends on the induced "
                "subgraph). Route this model through the online path "
                "(drop it from PrecomputeConfig.models).")


def agg_hops(prog: AckProgram) -> int:
    """Graph hops one output row depends on = executed Aggregate count
    (the inner section runs n_layers - 1 times)."""
    hops = sum(isinstance(op, Aggregate) for op in prog.layer0)
    if prog.n_layers > 1:
        hops += (prog.n_layers - 1) * sum(isinstance(op, Aggregate)
                                          for op in prog.inner)
    return hops


def dependency_closure(graph, out_ids: np.ndarray,
                       hops: int) -> np.ndarray:
    """Sorted unique vertex set whose layer-0 inputs determine the final
    embeddings of ``out_ids``: out_ids plus ``hops`` inbound neighbor
    expansions (the graph is symmetrized, so out-edges are in-edges)."""
    indptr, indices = graph.indptr, graph.indices
    ball = np.unique(np.asarray(out_ids, np.int64))
    cur = ball
    for _ in range(hops):
        if not len(cur):
            break
        starts, ends = indptr[cur], indptr[cur + 1]
        total = int((ends - starts).sum())
        if not total:
            break
        if len(cur) < 4096:
            nbrs = np.concatenate([indices[s:e]
                                   for s, e in zip(starts, ends)])
        else:
            nbrs = _gather_ranges(indices, starts, ends, total)
        new = np.setdiff1d(np.unique(nbrs).astype(np.int64), ball,
                           assume_unique=True)
        if not len(new):
            break
        ball = np.union1d(ball, new)
        cur = new
    return ball


# -- jitted chunk kernels (one compile per shape tuple, cached) ----------


@functools.lru_cache(maxsize=64)
def _agg_chunk_fn(nseg: int):
    @jax.jit
    def f(src, dst, w, h):
        # the scatter-gather ACK kernel, C=1: gather h[src] rows from the
        # FULL layer register, scatter-sum into the chunk's nseg slots
        return agg_sg(src[None], dst[None], w[None], h[None], nseg)[0]
    return f


@functools.lru_cache(maxsize=64)
def _transform_chunk_fn(act: str, with_self: bool):
    if with_self:
        @jax.jit
        def f(h_src, h_in, w, w_self, b):
            out = _ft(h_in[None], w_self, b) \
                + _ft(h_src[None], w, jnp.zeros((), h_src.dtype))
            return ACTS[act](out)[0]
        return f

    @jax.jit
    def f(h_src, w, b):
        return ACTS[act](_ft(h_src[None], w, b))[0]
    return f


class _LocalCSR:
    """The induced sub-CSR over the compute set, with edge weights under
    GLOBAL-graph normalization (what a full-coverage online subgraph
    computes: induced degree == global degree) and per-chunk edge slices
    padded to one uniform cap so every chunk hits the same compiled
    kernel."""

    def __init__(self, snap, ids: np.ndarray, chunk_size: int):
        self.ids = ids
        self.n = n = len(ids)
        self.chunk = min(chunk_size, n)
        deg = np.diff(snap.indptr)[ids].astype(np.float64)
        src, dst = subgraph_edges(snap, ids)
        order = np.argsort(dst, kind="stable")   # group edges by dst chunk
        self.src = src[order].astype(np.int32)
        dst = dst[order].astype(np.int64)
        self.dst = dst
        # chunk boundaries over local dst ids
        self.starts = list(range(0, n, self.chunk))
        self.e_ranges = [(int(np.searchsorted(dst, c0)),
                          int(np.searchsorted(dst, c0 + self.chunk)))
                         for c0 in self.starts]
        cap = max((e1 - e0 for e0, e1 in self.e_ranges), default=0)
        self.e_cap = max(1, cap + (-cap) % 128)
        # global-degree normalization (float64 math, cast to float32 —
        # the same dtypes build_subgraph_rows uses)
        d_hat = deg + 1.0                        # self loop counts as 1
        inv_sqrt = 1.0 / np.sqrt(d_hat)
        ds, dd = self.src.astype(np.int64), dst
        self._w = {
            "gcn": (inv_sqrt[dd] * inv_sqrt[ds]).astype(np.float32),
            "mean": (1.0 / np.maximum(deg, 1.0))[dd].astype(np.float32),
            "binary": np.ones(len(ds), np.float32),
        }
        self.self_w = (inv_sqrt * inv_sqrt).astype(np.float32)

    def aggregate(self, norm: str, H) -> jnp.ndarray:
        """One Aggregate op over the full register H [n, f], chunked over
        destination vertices; returns the new [n, f] register."""
        w_all = self._w[norm]
        fn = _agg_chunk_fn(self.chunk)
        out = []
        for c0, (e0, e1) in zip(self.starts, self.e_ranges):
            e = e1 - e0
            src = np.zeros(self.e_cap, np.int32)
            rel = np.zeros(self.e_cap, np.int32)
            w = np.zeros(self.e_cap, np.float32)
            src[:e] = self.src[e0:e1]
            rel[:e] = (self.dst[e0:e1] - c0).astype(np.int32)
            w[:e] = w_all[e0:e1]
            out.append(fn(src, rel, w, H)[:min(self.chunk,
                                               self.n - c0)])
        z = jnp.concatenate(out, axis=0) if len(out) > 1 else out[0]
        if norm == "gcn":
            # self-loop term: dense mode bakes it into adj, the edge list
            # excludes it (same convention as the online sg kernel)
            z = z + H * jnp.asarray(self.self_w)[:, None]
        return z

    def transform(self, op: Transform, p, H_src, H_in) -> jnp.ndarray:
        """One Transform op, chunked over vertices (bounds the MXU
        working set at chunk x max(f_in, f_out))."""
        b = p[op.b] if op.b else jnp.zeros((), H_src.dtype)
        fn = _transform_chunk_fn(op.act, op.w_self is not None)
        out = []
        for c0 in self.starts:
            c1 = min(c0 + self.chunk, self.n)
            if op.w_self:
                out.append(fn(H_src[c0:c1], H_in[c0:c1], p[op.w],
                              p[op.w_self], b))
            else:
                out.append(fn(H_src[c0:c1], p[op.w], b))
        return jnp.concatenate(out, axis=0) if len(out) > 1 else out[0]


def _apply_section(local: _LocalCSR, ops, p, H, H0):
    """Run one program section over the full-width registers — the
    offline mirror of program._compile_section (no mask: every row is a
    real vertex)."""
    regs = {"h": H, "h_in": H, "h0": H if H0 is None else H0}
    for op in ops:
        if isinstance(op, Aggregate):
            regs[op.out] = local.aggregate(op.norm, regs[op.src])
        elif isinstance(op, Residual):
            scale = (1.0 + p[op.eps_param]) if op.eps_param else 1.0
            regs[op.into] = scale * regs[op.src] \
                + op.into_gain * regs[op.into]
        elif isinstance(op, Transform):
            regs[op.out] = local.transform(op, p, regs[op.src],
                                           regs["h_in"])
        else:                 # pragma: no cover — check_precomputable
            raise PrecomputeError(f"unsupported op {op!r}")
    return regs["h"]


def layer_major_embeddings(graph, prog: AckProgram, params, *,
                           chunk_size: int = 2048,
                           out_ids: Optional[np.ndarray] = None
                           ) -> np.ndarray:
    """Offline embeddings for ``out_ids`` (default: every vertex).

    Layer-major schedule: layer0 for all compute-set vertices, then the
    inner section n_layers - 1 times, then the tail — each Aggregate /
    Transform chunked over ``chunk_size`` destination vertices.
    ``params`` must be the UNPADDED model params (the engine's pallas
    feature padding is an online-batch concern). Returns float32
    [len(out_ids), f_out].
    """
    check_precomputable(prog)
    # snapshot the CSR arrays: apply_edge_updates swaps whole arrays, so
    # holding these references pins one coherent graph version
    snap = SimpleNamespace(indptr=graph.indptr, indices=graph.indices)
    num_v = len(snap.indptr) - 1
    if out_ids is None:
        ids = np.arange(num_v, dtype=np.int64)
        out_local = slice(None)
    else:
        out_ids = np.asarray(out_ids, np.int64)
        ids = dependency_closure(snap, out_ids, agg_hops(prog))
        out_local = np.searchsorted(ids, out_ids)
    local = _LocalCSR(snap, ids, chunk_size)
    feats = graph.features[ids]
    H = jnp.asarray(feats, jnp.float32)
    H = _apply_section(local, prog.layer0, params["layer0"], H, None)
    if prog.n_layers > 1:
        H0 = H                # scan-entry prediction, teleport anchor
        for i in range(prog.n_layers - 1):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            H = _apply_section(local, prog.inner, lp, H, H0)
    emb = H
    for op in prog.tail:
        if isinstance(op, Readout):
            pass              # kind == "target": the row IS the readout
        elif isinstance(op, Classify):
            emb = emb @ params[op.w] + params[op.b]
        else:                 # pragma: no cover — lower() validates tails
            raise PrecomputeError(f"unsupported tail op {op!r}")
    return np.asarray(emb, np.float32)[out_local]
