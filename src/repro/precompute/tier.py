"""EmbeddingTier — the precomputed-embedding table with freshness.

A compact [R, f_out] float32 table over the RESIDENT vertex set (whole
graph, or the top-degree prefix that fits ``budget_bytes``), plus:

  slot_of [V]   int32 vertex -> row (-1 = non-resident, permanently cold)
  fresh   [R]   per-vertex freshness bit — a lookup serves from the table
                only while set; a graph update clears it (demotion) and
                the vertex serves online until a refresh re-promotes it
  epoch   [R]   generation stamp taken at demote time; a refresh only
                re-promotes a vertex whose epoch is unchanged, so an
                update racing a refresh chunk always wins (the refreshed
                row was computed against the pre-update graph)

All methods are thread-safe: lookups run on scheduler stage threads,
demotions on the graph-update caller, promotions on refresh workers.
"""
from __future__ import annotations

import threading
from typing import Optional, Tuple

import numpy as np


class EmbeddingTier:
    def __init__(self, num_vertices: int, f_out: int,
                 budget_bytes: Optional[int] = None,
                 degrees: Optional[np.ndarray] = None):
        row_bytes = f_out * 4
        if budget_bytes is not None \
                and budget_bytes < num_vertices * row_bytes:
            cap = max(0, budget_bytes // row_bytes)
            if cap and degrees is not None:
                # the budget goes to the top-degree vertices — the ones
                # Zipf traffic hits and the ones whose online fallback
                # (hub neighborhoods) is most expensive
                resident = np.sort(
                    np.argpartition(degrees, -cap)[-cap:])
            else:
                resident = np.arange(cap, dtype=np.int64)
        else:
            resident = np.arange(num_vertices, dtype=np.int64)
        self.num_vertices = num_vertices
        self.f_out = f_out
        self.resident_ids = resident.astype(np.int64)
        self.slot_of = np.full(num_vertices, -1, np.int32)
        self.slot_of[self.resident_ids] = np.arange(len(resident),
                                                    dtype=np.int32)
        self.table = np.zeros((len(resident), f_out), np.float32)
        self.fresh = np.zeros(len(resident), bool)
        self.epoch = np.zeros(len(resident), np.int64)
        self.generation = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.demotions = 0
        self.promotions = 0

    @property
    def capacity(self) -> int:
        return len(self.resident_ids)

    @property
    def nbytes(self) -> int:
        return int(self.table.nbytes)

    def install(self, ids: np.ndarray, rows: np.ndarray) -> int:
        """Unconditionally load rows (initial build / artifact load) and
        mark them fresh at the current generation."""
        with self._lock:
            slots = self.slot_of[np.asarray(ids, np.int64)]
            ok = slots >= 0
            self.table[slots[ok]] = rows[ok]
            self.fresh[slots[ok]] = True
            self.epoch[slots[ok]] = self.generation
            return int(ok.sum())

    def lookup(self, targets: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray]:
        """(rows [C, f_out], fresh_mask [C]) — rows are zero where the
        mask is False (those targets take the online path)."""
        targets = np.asarray(targets, np.int64)
        with self._lock:
            slots = self.slot_of[targets]
            resident = slots >= 0
            fresh = np.zeros(len(targets), bool)
            fresh[resident] = self.fresh[slots[resident]]
            rows = np.zeros((len(targets), self.f_out), np.float32)
            rows[fresh] = self.table[slots[fresh]]
            nf = int(fresh.sum())
            self.hits += nf
            self.misses += len(targets) - nf
        return rows, fresh

    def demote(self, vertices: np.ndarray) -> np.ndarray:
        """Clear freshness for the resident subset of ``vertices`` and
        stamp them with a new generation; returns the resident ids (the
        refresh backlog — already-stale vertices are included, their
        pending refresh must recompute against the newer graph)."""
        vertices = np.asarray(vertices, np.int64)
        with self._lock:
            slots = self.slot_of[vertices]
            ok = slots >= 0
            slots = slots[ok]
            self.generation += 1
            self.demotions += int(self.fresh[slots].sum())
            self.fresh[slots] = False
            self.epoch[slots] = self.generation
            return vertices[ok]

    def epoch_of(self, ids: np.ndarray) -> np.ndarray:
        with self._lock:
            return self.epoch[self.slot_of[np.asarray(ids, np.int64)]] \
                .copy()

    def promote(self, ids: np.ndarray, rows: np.ndarray,
                epochs: np.ndarray) -> int:
        """Install refreshed rows for vertices whose epoch is still
        ``epochs`` (captured when the refresh chunk was popped); a demote
        that landed mid-refresh bumps the epoch and the stale row is
        dropped (its re-enqueued backlog entry recomputes it)."""
        ids = np.asarray(ids, np.int64)
        with self._lock:
            slots = self.slot_of[ids]
            ok = (slots >= 0) & (self.epoch[np.maximum(slots, 0)]
                                 == epochs)
            self.table[slots[ok]] = rows[ok]
            self.fresh[slots[ok]] = True
            n = int(ok.sum())
            self.promotions += n
            return n

    def stats(self) -> dict:
        with self._lock:
            return {"resident": self.capacity,
                    "fresh": int(self.fresh.sum()),
                    "hits": self.hits, "misses": self.misses,
                    "demotions": self.demotions,
                    "promotions": self.promotions,
                    "tier_bytes": self.nbytes,
                    "generation": self.generation}
