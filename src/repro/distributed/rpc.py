"""RPC transport for multi-host graph serving (paper §4.4 at host scale).

The paper hides the CPU->FPGA hop with task scheduling; the same move
works across HOSTS: graph-owning processes run the irregular Select/Build
stages next to their partition's caches, the device host runs Pack +
device execution, and the scheduler's stage stations hide the hop under
neighboring batches (DGL's distributed RPC layer is the exemplar shape).

Three layers, smallest first:

* ``Transport`` — one request/response channel speaking wire.py frames.
  ``InProcTransport`` is the hermetic loopback: it encodes AND decodes
  both legs, so every tier-1 byte crosses the real codec while results
  stay bitwise-checkable in one process. ``SocketTransport`` is TCP with
  u-length framing via the wire header, a small connection pool (so a
  multi-worker remote stage keeps several requests in flight), and
  typed timeout/failure errors.
* ``HostPool`` — routes calls across a pool of graph hosts (round-robin
  or partition-affine), enforces the per-call timeout, retries failures
  on the next host up to ``retries`` times, and quarantines dead hosts
  for ``cooldown_s`` so one crash degrades capacity instead of wedging
  the pipeline.
* ``RemoteSelectBuildStage`` — the scheduler-facing spelling: one
  ``PlanStage`` that ships a batch's targets to a graph host and grafts
  the returned node lists / SubgraphRows / cache counters back onto the
  BatchPlan. A transport failure raises out of the stage, which the
  scheduler already isolates to THAT ticket (failure -> ticket error,
  pipeline keeps flowing).
"""
from __future__ import annotations

import itertools
import socket
import threading
import time
from dataclasses import dataclass
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.batchplan import BatchPlan, PlanStage
from repro.distributed import wire


class TransportError(ConnectionError):
    """The transport failed to deliver the call (dead peer, broken
    connection, unreachable endpoint). Retryable on another host."""


class RPCTimeout(TransportError):
    """The peer did not answer within the per-call timeout."""


class RemoteCallError(RuntimeError):
    """The peer received the call and raised while executing it. NOT
    retried: the failure is deterministic application state, not the
    link."""


@dataclass
class CallMeta:
    """Per-call accounting a transport hands back with the result."""
    bytes_out: int = 0
    bytes_in: int = 0
    remote_s: float = 0.0     # peer-reported handler wall time
    wire_s: float = 0.0       # encode+decode time on THIS side
    retries: int = 0          # filled by HostPool
    timeouts: int = 0
    endpoint: str = ""


class Transport:
    """One request/response channel. ``call`` returns (result, CallMeta)
    or raises TransportError / RPCTimeout / RemoteCallError."""

    endpoint = "?"

    def call(self, method: str, payload: Any,
             timeout: Optional[float] = None
             ) -> Tuple[Any, CallMeta]:
        raise NotImplementedError

    def close(self):
        pass


def _raise_remote(resp: dict, endpoint: str):
    if not resp.get("ok"):
        raise RemoteCallError(
            f"graph host {endpoint} failed "
            f"{resp.get('method', '?')!r}: "
            f"[{resp.get('error_type', 'Error')}] "
            f"{resp.get('error', 'unknown error')}")


class InProcTransport(Transport):
    """Loopback transport: dispatches to a service object in-process but
    runs the FULL wire codec on both legs of both directions — request
    encode->decode before the handler, response encode->decode after —
    so tier-1 stays hermetic while every payload byte is proven to
    survive the wire bitwise."""

    endpoint = "inproc"

    def __init__(self, service, owns_service: bool = False):
        self.service = service
        self._owns = owns_service

    def call(self, method, payload, timeout=None):
        t0 = time.perf_counter()
        req = wire.encode({"method": method, "payload": payload})
        request = wire.decode(req)
        t_wire = time.perf_counter() - t0
        resp_obj = self.service.handle(request)
        t1 = time.perf_counter()
        resp_frame = wire.encode(resp_obj)
        resp = wire.decode(resp_frame)
        t_wire += time.perf_counter() - t1
        _raise_remote(resp, self.endpoint)
        return resp["result"], CallMeta(
            bytes_out=len(req), bytes_in=len(resp_frame),
            remote_s=float(resp.get("remote_s", 0.0)), wire_s=t_wire,
            endpoint=self.endpoint)

    def close(self):
        if self._owns and hasattr(self.service, "close"):
            self.service.close()


def _recv_frame(sock: socket.socket) -> bytes:
    """Read exactly one wire frame: 14-byte header, then the declared
    remainder."""
    header = _recv_exact(sock, 14)
    total = wire.frame_length(header)
    return header + _recv_exact(sock, total - len(header))


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(min(1 << 20, n - got))
        if not chunk:
            raise ConnectionError(
                f"peer closed mid-frame ({got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


class SocketTransport(Transport):
    """TCP transport to one graph host ("host:port"). Keeps a small pool
    of idle connections so several stage workers can have calls in
    flight concurrently (that concurrency is what hides the hop under
    pipelined traffic); dials lazily and drops a connection on any
    failure rather than reusing a possibly-desynced stream."""

    def __init__(self, endpoint: str, *, connect_timeout: float = 5.0,
                 max_idle_conns: int = 8):
        host, _, port = endpoint.rpartition(":")
        if not host or not port.isdigit():
            raise ValueError(
                f"endpoint {endpoint!r} is not 'host:port'")
        self.endpoint = endpoint
        self._addr = (host, int(port))
        self._connect_timeout = connect_timeout
        self._max_idle = max_idle_conns
        self._idle: List[socket.socket] = []
        self._lock = threading.Lock()
        self._closed = False

    def _checkout(self) -> socket.socket:
        with self._lock:
            if self._closed:
                raise TransportError(
                    f"transport to {self.endpoint} is closed")
            if self._idle:
                return self._idle.pop()
        try:
            s = socket.create_connection(
                self._addr, timeout=self._connect_timeout)
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            return s
        except OSError as e:
            raise TransportError(
                f"cannot connect to graph host {self.endpoint}: {e}"
            ) from e

    def _checkin(self, s: socket.socket):
        with self._lock:
            if not self._closed and len(self._idle) < self._max_idle:
                self._idle.append(s)
                return
        s.close()

    def call(self, method, payload, timeout=None):
        t0 = time.perf_counter()
        req = wire.encode({"method": method, "payload": payload})
        t_wire = time.perf_counter() - t0
        s = self._checkout()
        try:
            s.settimeout(timeout)
            s.sendall(req)
            resp_frame = _recv_frame(s)
        except socket.timeout as e:
            s.close()
            raise RPCTimeout(
                f"graph host {self.endpoint} did not answer "
                f"{method!r} within {timeout}s") from e
        except (OSError, ConnectionError, wire.WireFormatError) as e:
            s.close()
            raise TransportError(
                f"call {method!r} to graph host {self.endpoint} "
                f"failed: {e}") from e
        self._checkin(s)
        t1 = time.perf_counter()
        resp = wire.decode(resp_frame)
        t_wire += time.perf_counter() - t1
        _raise_remote(resp, self.endpoint)
        return resp["result"], CallMeta(
            bytes_out=len(req), bytes_in=len(resp_frame),
            remote_s=float(resp.get("remote_s", 0.0)), wire_s=t_wire,
            endpoint=self.endpoint)

    def close(self):
        with self._lock:
            self._closed = True
            idle, self._idle = self._idle, []
        for s in idle:
            s.close()


class GraphHostServer:
    """Threaded frame server around a service object: one accept loop,
    one thread per connection, each request dispatched to
    ``service.handle(request) -> response``. ``"shutdown"`` is handled
    by the server itself (acknowledge, then stop accepting)."""

    def __init__(self, service, host: str = "127.0.0.1", port: int = 0):
        self.service = service
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(32)
        self.host, self.port = self._listener.getsockname()[:2]
        self._stop = threading.Event()
        self._conns: List[socket.socket] = []
        self._lock = threading.Lock()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="graph-host-accept", daemon=True)
        self._accept_thread.start()

    @property
    def endpoint(self) -> str:
        return f"{self.host}:{self.port}"

    def _accept_loop(self):
        while not self._stop.is_set():
            try:
                conn, _ = self._listener.accept()
            except OSError:
                break
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._lock:
                self._conns.append(conn)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def _serve_conn(self, conn: socket.socket):
        try:
            while not self._stop.is_set():
                try:
                    frame = _recv_frame(conn)
                except (ConnectionError, OSError):
                    return
                try:
                    request = wire.decode(frame)
                except wire.WireError as e:
                    conn.sendall(wire.encode(
                        {"ok": False, "error": str(e),
                         "error_type": type(e).__name__}))
                    continue
                if request.get("method") == "shutdown":
                    conn.sendall(wire.encode({"ok": True, "result": None,
                                              "remote_s": 0.0}))
                    threading.Thread(target=self.close,
                                     daemon=True).start()
                    return
                conn.sendall(wire.encode(self.service.handle(request)))
        finally:
            conn.close()

    def close(self):
        self._stop.set()
        try:
            self._listener.close()
        except OSError:
            pass
        with self._lock:
            conns, self._conns = self._conns, []
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            c.close()
        if hasattr(self.service, "close"):
            self.service.close()

    def wait(self):
        """Block until the server is shut down (CLI main loop)."""
        while not self._stop.wait(0.2):
            pass


@dataclass
class PoolCallMeta(CallMeta):
    """CallMeta plus the routing outcome across the pool."""
    wall_s: float = 0.0


class HostPool:
    """Route calls across a pool of graph hosts with timeout, bounded
    retry, and dead-host quarantine.

    routing="round_robin" spreads batches evenly; "affine" pins a call's
    ``affinity`` key (e.g. the batch's first target id) to a fixed host,
    so a partition-affine deployment keeps each host's caches hot for
    its own vertex range. A host that times out or drops the connection
    is marked down for ``cooldown_s`` and skipped while alternatives are
    healthy; the call retries on the next host up to ``retries`` times
    before the error reaches the ticket."""

    def __init__(self, transports: Sequence[Transport], *,
                 timeout: Optional[float] = 30.0, retries: int = 2,
                 routing: str = "round_robin", cooldown_s: float = 5.0,
                 on_quarantine=None):
        if not transports:
            raise ValueError("HostPool needs at least one transport")
        if routing not in ("round_robin", "affine"):
            raise ValueError(f"routing={routing!r}, expected "
                             "'round_robin' or 'affine'")
        self.transports = list(transports)
        self.timeout = timeout
        self.retries = int(retries)
        self.routing = routing
        self.cooldown_s = cooldown_s
        # fired once per quarantine EPISODE with the endpoint string
        # (telemetry hook: the engine routes it into the event ring);
        # re-marks while already down stay silent
        self.on_quarantine = on_quarantine
        self._rr = itertools.count()
        self._lock = threading.Lock()
        self._down_until = [0.0] * len(self.transports)

    def __len__(self) -> int:
        return len(self.transports)

    @property
    def endpoints(self) -> List[str]:
        return [t.endpoint for t in self.transports]

    def _mark_down(self, i: int):
        now = time.monotonic()
        with self._lock:
            fresh = self._down_until[i] <= now
            self._down_until[i] = now + self.cooldown_s
        if fresh and self.on_quarantine is not None:
            try:
                self.on_quarantine(self.transports[i].endpoint)
            except Exception:    # a telemetry hook must never break
                pass             # routing

    def _mark_up(self, i: int):
        with self._lock:
            self._down_until[i] = 0.0

    def _candidates(self, affinity: Optional[int]) -> List[int]:
        n = len(self.transports)
        if self.routing == "affine" and affinity is not None:
            start = int(affinity) % n
        else:
            start = next(self._rr) % n
        order = [(start + k) % n for k in range(n)]
        now = time.monotonic()
        with self._lock:
            healthy = [i for i in order if self._down_until[i] <= now]
        return healthy or order      # all down: try anyway

    def call(self, method: str, payload: Any,
             affinity: Optional[int] = None) -> Tuple[Any, PoolCallMeta]:
        t_start = time.perf_counter()
        attempts = self.retries + 1
        candidates = self._candidates(affinity)
        errors: List[str] = []
        timeouts = 0
        for attempt in range(attempts):
            i = candidates[attempt % len(candidates)]
            tr = self.transports[i]
            try:
                result, meta = tr.call(method, payload,
                                       timeout=self.timeout)
            except RPCTimeout as e:
                timeouts += 1
                errors.append(str(e))
                self._mark_down(i)
                last: TransportError = e
            except TransportError as e:
                errors.append(str(e))
                self._mark_down(i)
                last = e
            else:
                self._mark_up(i)
                return result, PoolCallMeta(
                    bytes_out=meta.bytes_out, bytes_in=meta.bytes_in,
                    remote_s=meta.remote_s, wire_s=meta.wire_s,
                    retries=attempt, timeouts=timeouts,
                    endpoint=meta.endpoint,
                    wall_s=time.perf_counter() - t_start)
        raise type(last)(
            f"{method!r} failed after {attempts} attempt(s) across "
            f"{min(attempts, len(candidates))} host(s): "
            + " | ".join(errors))

    def broadcast(self, method: str, payload: Any) -> List[Any]:
        """Best-effort call on EVERY host (cache invalidation, report):
        per-host failures are returned as None, never raised — a dead
        host cannot hold stale state anyway."""
        out = []
        for i, tr in enumerate(self.transports):
            try:
                result, _ = tr.call(method, payload, timeout=self.timeout)
                self._mark_up(i)
                out.append(result)
            except (TransportError, RemoteCallError):
                self._mark_down(i)
                out.append(None)
        return out

    def report(self) -> List[dict]:
        now = time.monotonic()
        with self._lock:
            down = [u > now for u in self._down_until]
        return [{"endpoint": t.endpoint, "healthy": not d}
                for t, d in zip(self.transports, down)]

    def close(self):
        for t in self.transports:
            t.close()


class RemoteSelectBuildStage(PlanStage):
    """Select+Build as ONE remote station: ship the batch's targets to a
    graph host, graft the returned node lists / SubgraphRows / counters
    back onto the BatchPlan, and hand it to the local Pack stage. The
    station runs ``workers`` concurrent calls so the hop overlaps with
    itself under pipelined traffic (triple buffering across the wire).

    Failures raise out of ``run``; the scheduler's stage-step already
    converts that into a per-ticket error, so a dead graph host fails
    the in-flight tickets and the pool's quarantine reroutes the rest —
    degrade, not wedge."""

    name = "select_build"

    def __init__(self, engine, pool: HostPool, workers: int = 4):
        self.engine = engine
        self.pool = pool
        self.workers = max(1, int(workers))

    def run(self, plan) -> BatchPlan:
        if not isinstance(plan, BatchPlan):
            plan = BatchPlan(targets=np.asarray(plan))
        if plan.tier_done:       # all targets served from the embedding
            return plan          # tier — skip the remote hop entirely
        eng = self.engine
        cfg = eng.cfg
        payload = {
            "targets": np.asarray(plan.targets, dtype=np.int64),
            "n": int(cfg.receptive_field),
            "alpha": float(cfg.ppr_alpha),
            "eps": float(cfg.ppr_eps),
            "e_pad": int(eng.e_pad),
        }
        tracer = getattr(eng, "tracer", None)
        if tracer is not None:
            # the scheduler opened this ticket's stage span on THIS
            # thread; its ids ride the wire meta so the graph host's
            # spans come back parented under it (cross-host stitching)
            ids = tracer.current_ids()
            if ids is not None:
                payload["trace"] = {"trace_id": ids[0], "parent": ids[1]}
        affinity = int(plan.targets[0]) if len(plan.targets) else 0
        t0 = time.perf_counter()
        try:
            result, meta = self.pool.call("select_build", payload,
                                          affinity=affinity)
        except TransportError as e:
            eng.scheduler.note_rpc_metrics(
                calls=1, errors=1, retries=self.pool.retries,
                timeouts=1 if isinstance(e, RPCTimeout) else 0,
                wall=time.perf_counter() - t0)
            raise
        plan.node_lists = wire.node_lists_from_wire(result["node_lists"])
        plan.rows = wire.rows_from_wire(result["rows"])
        plan.nbr_hits = int(result["nbr_hits"])
        plan.nbr_misses = int(result["nbr_misses"])
        plan.build_hits = int(result["build_hits"])
        plan.build_misses = int(result["build_misses"])
        eng.scheduler.note_rpc_metrics(
            calls=1, bytes_out=meta.bytes_out, bytes_in=meta.bytes_in,
            retries=meta.retries, timeouts=meta.timeouts,
            wall=time.perf_counter() - t0, remote=meta.remote_s,
            wire=meta.wire_s)
        if tracer is not None and "trace" in payload:
            tracer.annotate(endpoint=meta.endpoint,
                            bytes_out=meta.bytes_out,
                            bytes_in=meta.bytes_in,
                            retries=meta.retries,
                            remote_s=round(meta.remote_s, 6))
            spans = result.get("spans")
            if spans:
                tracer.ingest_remote(spans, meta.endpoint)
        return plan


def estimate_clock_offsets(pool: HostPool, pings: int = 5) -> dict:
    """Ping-based clock sync per graph host: for each transport, send
    ``pings`` pings, and from the round trip with the SMALLEST rtt (the
    one least contaminated by queueing) estimate

        offset = remote_clock - (t_send + rtt / 2)

    i.e. the remote wall clock minus the local one under the symmetric-
    link assumption. ``tracer.ingest_remote`` subtracts the offset from
    remote span timestamps to map them onto the client timeline; the
    residual error is bounded by the link's asymmetry (at most rtt/2).
    Hosts that fail to answer or predate the ``clock`` ping field are
    skipped — their spans stitch unshifted."""
    from repro.obs.trace import now
    out = {}
    for tr in pool.transports:
        best = None
        for _ in range(max(1, pings)):
            t_send = now()
            try:
                result, _ = tr.call("ping", None, timeout=pool.timeout)
            except (TransportError, RemoteCallError):
                break
            rtt = now() - t_send
            clock = result.get("clock") if isinstance(result, dict) \
                else None
            if clock is None:        # pre-observability peer
                break
            if best is None or rtt < best[0]:
                best = (rtt, float(clock) - (t_send + rtt / 2.0))
        if best is not None:
            out[tr.endpoint] = {"offset_s": best[1], "rtt_s": best[0]}
    return out


def build_host_pool(config, graph=None) -> HostPool:
    """Resolve a ServingConfig's transport section into a HostPool.

    transport="inproc" spins up a private GraphHostService over the
    loopback transport (hermetic: full codec, one process);
    transport="socket" dials ``config.endpoints``."""
    if config.transport == "inproc":
        if graph is None:
            raise ValueError("transport='inproc' needs the graph")
        from repro.distributed.graph_host import GraphHostService
        pol = config.store
        svc = GraphHostService(
            graph, num_threads=config.num_threads,
            nbr_cache_mode=pol.nbr_cache if pol.nbr_cache != "none"
            else "lru",
            nbr_capacity=pol.nbr_capacity,
            cache_rows=True,
            telemetry=getattr(config, "telemetry", None))
        transports: List[Transport] = [
            InProcTransport(svc, owns_service=True)]
    elif config.transport == "socket":
        transports = [SocketTransport(ep) for ep in config.endpoints]
    else:
        raise ValueError(
            f"transport={config.transport!r} has no host pool "
            "(transport='local' runs Select/Build in-process)")
    return HostPool(transports, timeout=config.rpc_timeout_s,
                    retries=config.rpc_retries, routing=config.routing)


__all__ = ["Transport", "InProcTransport", "SocketTransport",
           "GraphHostServer", "HostPool", "RemoteSelectBuildStage",
           "TransportError", "RPCTimeout", "RemoteCallError",
           "CallMeta", "PoolCallMeta", "build_host_pool",
           "estimate_clock_offsets"]
