"""Graph host: the process that owns a graph partition and its caches.

The device host keeps the compiled ACK program and the feature store;
the graph host keeps the CSR graph, the neighborhood cache, and the
subgraph-row cache, and answers ``select_build`` calls by running the
SAME ``SelectStage``/``BuildStage`` objects the in-process pipeline uses
(core.batchplan) — so the remote path is the staged path by
construction, and bitwise-identical to it.

One service can answer for several registered models at once: stages are
cached per (receptive field, alpha, eps, e_pad) signature while the two
frontier caches are shared across them (entries key by that signature
already — ``nbr_key``).

Run standalone:

    python -m repro.distributed.graph_host --dataset flickr \
        --scale 0.01 --seed 0 --port 0

prints ``GRAPH_HOST_LISTENING <host> <port>`` once ready (parents parse
this to discover an ephemeral port) and serves until a ``shutdown`` RPC
or SIGTERM.
"""
from __future__ import annotations

import itertools
import os
import threading
import time
from types import SimpleNamespace
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.batchplan import BatchPlan, BuildStage, SelectStage
from repro.distributed import wire
from repro.obs.trace import SpanAllocator, now, span_dict
from repro.store.nbr_cache import NeighborhoodCache, SubgraphRowCache


class _StagePair:
    """Select+Build stations for one model signature, duck-typing the
    slice of DecoupledEngine the stages read."""

    def __init__(self, service: "GraphHostService", n: int, alpha: float,
                 eps: float, e_pad: int):
        eng = SimpleNamespace(
            graph=service.graph,
            cfg=SimpleNamespace(receptive_field=n, ppr_alpha=alpha,
                                ppr_eps=eps),
            num_threads=service.num_threads,
            nbr_cache=service.nbr_cache,
            sg_cache=service.sg_cache,
            e_pad=e_pad,
            tracer=None)   # stages read eng.tracer; remote spans are
        #                    emitted by the service itself instead
        self.select = SelectStage(eng)
        self.build = BuildStage(eng)

    def close(self):
        self.select.close()


_INSTANCE_SEQ = itertools.count()


class GraphHostService:
    """RPC service owning one graph partition + its host-side caches.

    Methods (all reachable through ``handle``):
      select_build  targets -> node lists + SubgraphRows + cache counters
      invalidate    vertex ids -> dropped cache entries (both caches)
      report        cache stats + request counters
      metrics       this host's metrics registry in wire form (the
                    cluster-scrape building block: the device host
                    merges every host's wire losslessly)
      ping          liveness

    ``telemetry=TelemetryConfig(...)`` gives the host its own windowed
    metrics registry (select/build wall histograms + cache counters as
    collect-time callbacks); None (default) keeps the host metrics-free
    and the ``metrics`` method answers with an empty registry.
    """

    def __init__(self, graph, *, num_threads: int = 8,
                 nbr_cache_mode: str = "lru", nbr_capacity: int = 4096,
                 cache_rows: bool = True, row_capacity: int = 1024,
                 delay_s: float = 0.0, telemetry=None):
        self.graph = graph
        self.num_threads = num_threads
        # simulated one-way link latency (benchmarking only): lets a
        # single-machine run measure how much of a known RTT the device
        # host's pipelined remote stage hides
        self.delay_s = delay_s
        self.nbr_cache = (NeighborhoodCache(nbr_capacity)
                          if nbr_cache_mode != "none" else None)
        self.sg_cache = SubgraphRowCache(row_capacity) if cache_rows \
            else None
        self._pairs: Dict[Tuple, _StagePair] = {}
        self._lock = threading.Lock()
        self.requests = 0
        self.targets_served = 0
        # host-side observability (always on — two clock reads per call):
        # cumulative select/build wall split, so the device host's
        # store_report() can show WHERE remote prep time goes per host,
        # and span emission state for traced calls (payload["trace"])
        self.stage_times: Dict[str, float] = {"select": 0.0, "build": 0.0}
        self.spans_emitted = 0
        self._span_ids = SpanAllocator()
        # unique per process AND per in-process instance (an inproc
        # cluster scrape must keep same-pid hosts distinguishable)
        seq = next(_INSTANCE_SEQ)
        self._span_host = f"graph-host:{os.getpid()}" + \
            (f".{seq}" if seq else "")
        # per-host telemetry registry (opt-in; the hot path pays one
        # ``is None`` test plus two histogram records per select_build)
        if telemetry is not None:
            from repro.obs.metrics import MetricsRegistry
            reg = MetricsRegistry(self._span_host,
                                  window_s=telemetry.window_s,
                                  windows=telemetry.windows)
            self._h_select = reg.whist(
                "repro_host_select_seconds",
                help="graph-host Select stage wall time")
            self._h_build = reg.whist(
                "repro_host_build_seconds",
                help="graph-host Build stage wall time")
            reg.counter_fn("repro_host_requests_total",
                           lambda: self.requests,
                           help="select_build calls answered")
            reg.counter_fn("repro_host_targets_total",
                           lambda: self.targets_served,
                           help="targets served")
            if self.nbr_cache is not None:
                nc = self.nbr_cache
                reg.counter_fn("repro_nbr_cache_hits_total",
                               lambda: nc.hits,
                               help="neighborhood cache hits")
                reg.counter_fn("repro_nbr_cache_misses_total",
                               lambda: nc.misses,
                               help="neighborhood cache misses")
                reg.counter_fn("repro_nbr_cache_evictions_total",
                               lambda: nc.evictions,
                               help="neighborhood cache evictions")
            if self.sg_cache is not None:
                rc = self.sg_cache
                reg.counter_fn("repro_row_cache_hits_total",
                               lambda: rc.hits,
                               help="subgraph-row cache hits")
                reg.counter_fn("repro_row_cache_misses_total",
                               lambda: rc.misses,
                               help="subgraph-row cache misses")
            self.registry = reg
        else:
            self.registry = None
            self._h_select = None
            self._h_build = None

    def _pair(self, n: int, alpha: float, eps: float,
              e_pad: int) -> _StagePair:
        key = (int(n), float(alpha), float(eps), int(e_pad))
        with self._lock:
            pair = self._pairs.get(key)
            if pair is None:
                pair = _StagePair(self, *key)
                self._pairs[key] = pair
        return pair

    # -- RPC methods ---------------------------------------------------------
    def select_build(self, payload: dict) -> dict:
        pair = self._pair(payload["n"], payload["alpha"], payload["eps"],
                          payload["e_pad"])
        plan = BatchPlan(targets=np.asarray(payload["targets"],
                                            dtype=np.int64))
        t0 = now()
        plan = pair.select.run(plan)
        t1 = now()
        plan = pair.build.run(plan)
        t2 = now()
        with self._lock:
            self.requests += 1
            self.targets_served += len(plan.targets)
            self.stage_times["select"] += t1 - t0
            self.stage_times["build"] += t2 - t1
        if self._h_select is not None:
            self._h_select.record(t1 - t0)
            self._h_build.record(t2 - t1)
        result = {"node_lists": wire.node_lists_to_wire(plan.node_lists),
                  "rows": wire.rows_to_wire(plan.rows),
                  "nbr_hits": plan.nbr_hits,
                  "nbr_misses": plan.nbr_misses,
                  "build_hits": plan.build_hits,
                  "build_misses": plan.build_misses}
        trace = payload.get("trace")
        if trace is not None:
            # traced call: emit this host's select/build spans, children
            # of the CLIENT's rpc-stage span. Timestamps are THIS
            # process's clock — the client shifts them by its ping-based
            # offset estimate when stitching (tracer.ingest_remote).
            # Span ids come from this process's allocator (pid-prefixed,
            # so they can never collide with the client's ids).
            tid = threading.get_ident() & 0xFFFFFF
            common = dict(trace_id=int(trace["trace_id"]),
                          parent_id=int(trace["parent"]),
                          host=self._span_host, cat="remote")
            result["spans"] = [
                span_dict(name="remote.select",
                          span_id=self._span_ids.next_id(),
                          t0=t0, dur=t1 - t0, track="remote.select",
                          args={"tid": tid, "nbr_hits": plan.nbr_hits,
                                "nbr_misses": plan.nbr_misses},
                          **common),
                span_dict(name="remote.build",
                          span_id=self._span_ids.next_id(),
                          t0=t1, dur=t2 - t1, track="remote.build",
                          args={"tid": tid, "build_hits": plan.build_hits,
                                "build_misses": plan.build_misses},
                          **common)]
            with self._lock:
                self.spans_emitted += 2
        return result

    def invalidate(self, payload: dict) -> dict:
        vs = np.asarray(payload["vertices"], dtype=np.int64)
        dropped = 0
        if self.sg_cache is not None:
            dropped += self.sg_cache.invalidate(vs)
        if self.nbr_cache is not None:
            dropped += self.nbr_cache.invalidate(vs)
        return {"dropped": dropped}

    def report(self, payload: Optional[dict] = None) -> dict:
        with self._lock:
            stage_times = {k: round(v, 6)
                           for k, v in self.stage_times.items()}
        r = {"requests": self.requests,
             "targets_served": self.targets_served,
             # host-side Select/Build wall split + span counters, so the
             # device host's store_report() shows WHERE remote prep time
             # goes per host, not just call totals
             "stage_times": stage_times,
             "spans_emitted": self.spans_emitted,
             "models": [list(k) for k in self._pairs]}
        if self.nbr_cache is not None:
            r["nbr_cache"] = self.nbr_cache.stats()
        if self.sg_cache is not None:
            r["subgraph_cache"] = self.sg_cache.stats()
        return r

    def metrics(self, payload: Optional[dict] = None) -> dict:
        """This host's metrics registry in wire form (JSON scalars only,
        so it crosses the wire codec unchanged). Telemetry-free hosts
        answer with an empty registry rather than erroring — a mixed
        deployment's cluster scrape just sees fewer series."""
        if self.registry is None:
            return {"host": self._span_host, "families": {}}
        return self.registry.collect()

    def ping(self, payload: Optional[dict] = None) -> dict:
        # "clock" is this process's monotonic wall clock (obs.trace.now):
        # the client's ping loop turns (send time, rtt, clock) into a
        # per-endpoint offset estimate for stitching remote spans
        return {"pong": True, "num_vertices": self.graph.num_vertices,
                "clock": now()}

    # -- dispatch ------------------------------------------------------------
    _METHODS = ("select_build", "invalidate", "report", "metrics",
                "ping")

    def handle(self, request: dict) -> dict:
        method = request.get("method")
        if self.delay_s:
            time.sleep(self.delay_s)
        t0 = time.perf_counter()
        if method not in self._METHODS:
            return {"ok": False, "method": method,
                    "error": f"unknown method {method!r}; "
                             f"available: {list(self._METHODS)}",
                    "error_type": "LookupError"}
        try:
            result = getattr(self, method)(request.get("payload"))
        except Exception as e:                     # noqa: BLE001
            return {"ok": False, "method": method, "error": str(e),
                    "error_type": type(e).__name__}
        return {"ok": True, "result": result,
                "remote_s": time.perf_counter() - t0}

    def close(self):
        with self._lock:
            pairs, self._pairs = list(self._pairs.values()), {}
        for p in pairs:
            p.close()


def main(argv=None) -> int:
    import argparse

    from repro.distributed.rpc import GraphHostServer
    from repro.graphs.synthetic import get_graph

    ap = argparse.ArgumentParser(
        description="Serve one graph partition's Select/Build stages "
                    "over a SocketTransport endpoint.")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 = ephemeral; the chosen port is printed")
    ap.add_argument("--dataset", default="flickr")
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--seed", type=int, default=0,
                    help="must match the device host so both processes "
                         "materialize the identical synthetic graph")
    ap.add_argument("--num-threads", type=int, default=4)
    ap.add_argument("--nbr-cache", default="lru",
                    choices=("lru", "none"))
    ap.add_argument("--nbr-capacity", type=int, default=4096)
    ap.add_argument("--no-row-cache", action="store_true")
    ap.add_argument("--row-capacity", type=int, default=1024)
    ap.add_argument("--delay-ms", type=float, default=0.0,
                    help="simulated link latency per call (benchmarks)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve Prometheus exposition on this port "
                         "(0 = ephemeral, printed; default = off); "
                         "also enables the host's telemetry registry")
    ap.add_argument("--metrics-window-s", type=float, default=60.0,
                    help="telemetry sliding-window length")
    args = ap.parse_args(argv)

    telemetry = None
    if args.metrics_port is not None:
        from repro.obs.metrics import TelemetryConfig
        telemetry = TelemetryConfig(port=args.metrics_port,
                                    window_s=args.metrics_window_s)
    graph = get_graph(args.dataset, scale=args.scale, seed=args.seed)
    service = GraphHostService(
        graph, num_threads=args.num_threads,
        nbr_cache_mode=args.nbr_cache, nbr_capacity=args.nbr_capacity,
        cache_rows=not args.no_row_cache, row_capacity=args.row_capacity,
        delay_s=args.delay_ms / 1e3, telemetry=telemetry)
    metrics_server = None
    if telemetry is not None:
        from repro.obs.promexp import MetricsHTTPServer, render_wire
        metrics_server = MetricsHTTPServer(
            lambda: render_wire(service.metrics()),
            host=args.host, port=telemetry.port)
        print(f"GRAPH_HOST_METRICS {metrics_server.host} "
              f"{metrics_server.port}", flush=True)
    server = GraphHostServer(service, host=args.host, port=args.port)
    print(f"GRAPH_HOST_LISTENING {server.host} {server.port}",
          flush=True)
    try:
        server.wait()
    except KeyboardInterrupt:
        server.close()
    finally:
        if metrics_server is not None:
            metrics_server.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
