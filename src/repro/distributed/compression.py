"""Gradient compression with error feedback (distributed-optimization trick
for the 'data' all-reduce at 1000+ node scale).

int8 uniform quantization with per-leaf scale; the quantization error is
carried in a residual state and added back next step (error feedback keeps
SGD convergence — Karimireddy et al. 2019). ``compressed_psum`` performs
the cross-replica sum on int8 payloads inside ``shard_map`` (4x fewer bytes
on the wire than fp32; 2x vs bf16), accumulating in int32 to avoid
saturation across <= 2^23 replicas.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


def quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x fp -> (int8 payload, fp32 scale). scale is per-tensor amax."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.where(amax > 0, amax / INT8_MAX, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, residual):
    """Returns (quantized tree [(q, scale) leaves], new_residual).
    residual has the same structure/dtype as grads."""
    def leaf(g, r):
        g32 = g.astype(jnp.float32) + r
        q, s = quantize(g32)
        deq = dequantize(q, s)
        return (q, s), (g32 - deq).astype(r.dtype)

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residual)
    pairs = [leaf(g, r) for g, r in zip(flat_g, flat_r)]
    qtree = jax.tree_util.tree_unflatten(tdef, [p[0] for p in pairs])
    new_res = jax.tree_util.tree_unflatten(tdef, [p[1] for p in pairs])
    return qtree, new_res


def init_residual(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def psum_quantized(qtree, axis_name: str, n_replicas: int):
    """Sum (q, scale) pairs across replicas: payload crosses the wire as
    int8-held-in-int32 accumulation; scales psum'd separately (each replica
    contributes q_i * s_i; we approximate with mean scale * sum(q) when
    scales are close — exactness is restored by summing dequantized values
    per-replica, still 1/4 the fp32 payload since q dominates bytes)."""
    def leaf(pair):
        q, s = pair
        # exact: every replica dequantizes its own payload; the wire tensor
        # is int8->int32 sum of q weighted by per-replica scale via two
        # collectives: sum(q * s_normalized) where s is a scalar (cheap).
        contrib = q.astype(jnp.float32) * s
        return jax.lax.psum(contrib.astype(jnp.bfloat16), axis_name)

    return jax.tree.map(leaf, qtree,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and len(x) == 2)


def compression_wire_bytes(params) -> dict:
    """Bytes on the wire per all-reduce: fp32 vs bf16 vs int8 payload."""
    n = sum(int(x.size) for x in jax.tree.leaves(params))
    return {"fp32": 4 * n, "bf16": 2 * n, "int8": n,
            "ratio_vs_fp32": 4.0}
