"""Sharding policies: logical-axis rules for activations and path-based
PartitionSpecs for parameters, optimizer state, and decode caches.

Conventions (single-pod mesh ('data','model'); multi-pod adds 'pod'):
  * batch dims           -> ('pod','data')   (replicated if not divisible)
  * attention heads / ff hidden / vocab / experts -> 'model'
  * FSDP (>=100B archs): the non-'model' matrix dim additionally -> 'data'
  * ZeRO-1: optimizer moments get 'data' added on their largest replicated
    dim even when params don't (update shards over data, params re-gather)
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import data_axes

BLOCK_KEYS = ("blocks", "dense_blocks", "enc_blocks")


def shard_devices(num_shards: int) -> list:
    """Device list backing ``num_shards`` logical feature-store shards.

    One device per shard when the host has enough; otherwise shards are
    simulated — every table lands on the default device but keeps its own
    budget/placement accounting (the store's ``simulated`` flag reports
    which regime is active). The same helper keeps the store and any
    future mesh-based layout agreeing on device order."""
    devs = jax.devices()
    if len(devs) >= num_shards:
        return list(devs[:num_shards])
    return [devs[0]] * num_shards


def activation_rules(cfg: ModelConfig, mesh) -> Dict[str, Any]:
    """Logical axis -> mesh axis mapping for repro.models.common.shard()."""
    da = data_axes(mesh)
    n_model = mesh.shape["model"]

    def if_div(n, axis="model"):
        return axis if (n and n % n_model == 0) else None

    return {
        "batch": da,
        # heads stay on 'model' even when uneven (GSPMD pads); kv heads are
        # small — replicate unless they divide evenly
        "heads": "model" if cfg.n_heads else None,
        "kv_heads": if_div(cfg.n_kv_heads),
        "ff": "model",
        "vocab": "model",
        "experts": if_div(cfg.moe.num_experts) if cfg.moe else None,
        # inner-expert ff dim: shard over 'model' ONLY when experts aren't
        # (both on 'model' would be a duplicate-axis spec)
        "expert_ff": ("model" if cfg.moe and not if_div(cfg.moe.num_experts)
                      else None),
    }


def batch_spec(global_batch: int, mesh) -> P:
    da = data_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in da]))
    if global_batch % n == 0:
        return P(da)
    if global_batch % mesh.shape["data"] == 0:
        return P("data")
    return P(None)


# ---------------------------------------------------------------------------
# parameter specs

_IN_OUT = {  # name -> (spec for 2D [in, out]-style matrices)
    # attention / generic projections: [d_in, sharded_out]
    "wq": "in_out", "wk": "in_out", "wv": "in_out",
    "w_gate": "in_out", "w_up": "in_out", "w_in": "in_out",
    "in_proj": "in_out", "w_uq": "in_out",
    # output projections: [sharded_in, d_out]
    "wo": "out_in", "w_down": "out_in", "w_out": "out_in",
    "out_proj": "out_in",
}


def _param_spec(cfg: ModelConfig, name: str, shape, fsdp_axis):
    """Spec for the *unstacked* param."""
    nd = len(shape)
    if name == "embed":
        return P("model", fsdp_axis)
    if name == "lm_head":
        return P(fsdp_axis, "model")
    if name in ("pos_emb", "enc_pos_emb"):
        return P(None, None)
    if name == "router":
        return P(None, None)
    if name == "conv_w":
        return P(None, "model")
    if name in ("conv_b", "b_in", "bq", "bk", "bv"):
        return P("model")
    if name in ("w_dkv", "w_kr", "w_dq"):             # MLA down-proj [D, r]
        return P(fsdp_axis, None)
    if name in ("w_uk", "w_uv"):                      # MLA up-proj [r, H*d]
        return P(None, "model")
    if name == "proj":                                # MTP [2D, D]
        return P(fsdp_axis, None)
    kind = _IN_OUT.get(name)
    if kind and nd == 2:
        return P(fsdp_axis, "model") if kind == "in_out" \
            else P("model", fsdp_axis)
    if kind and nd == 3:                              # MoE expert stacks
        return (P("model", fsdp_axis, None) if kind == "in_out"
                else P("model", None, fsdp_axis))
    return P(*([None] * nd))                          # norms, scalars, bias


def _sanitize(spec: P, shape, mesh) -> P:
    """Drop axis assignments whose dimension doesn't divide evenly: pjit
    ARGUMENT shardings must tile exactly (constraints may pad, inputs may
    not). E.g. whisper's vocab 51865 cannot shard 16-ways."""
    if mesh is None:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, a in enumerate(parts):
        if a is None:
            out.append(None)
            continue
        axes = (a,) if isinstance(a, str) else tuple(a)
        n = int(np.prod([mesh.shape[x] for x in axes]))
        out.append(a if shape[dim] % n == 0 else None)
    return P(*out)


def param_pspecs(cfg: ModelConfig, params_tree, mesh=None):
    """PartitionSpec pytree matching ``params_tree`` (shapes or arrays)."""
    fsdp_axis = "data" if cfg.sharding.fsdp else None

    def visit(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        stacked = any(n in BLOCK_KEYS for n in names)
        name = names[-1]
        shape = leaf.shape
        base_shape = shape[1:] if stacked else shape
        spec = _sanitize(_param_spec(cfg, name, base_shape, fsdp_axis),
                         base_shape, mesh)
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(visit, params_tree)


def cache_pspecs(cfg: ModelConfig, cache_tree, mesh, global_batch: int):
    """Decode-cache specs: batch over data axes; head-ish dims over model
    when divisible. Cache leaves are [L, B, ...]."""
    bs = batch_spec(global_batch, mesh)
    b_axis = bs[0] if len(bs) else None
    n_model = mesh.shape["model"]

    seq_cp = cfg.sharding.cache_seq_shard

    def visit(path, leaf):
        name = getattr(path[-1], "key", "")
        nd = len(leaf.shape)
        if name in ("k", "v", "cross_k", "cross_v"):  # [L,B,S,Kh,Dh]
            kh = leaf.shape[3]
            if kh % n_model == 0:
                return P(None, b_axis, None, "model", None)
            # context parallelism: kv-heads don't divide the model axis
            # (qwen 20H, phi3 10H) -> shard the SEQ dim instead; softmax
            # statistics cross shards as tiny all-reduces
            if seq_cp and leaf.shape[2] % n_model == 0:
                return P(None, b_axis, "model", None, None)
            return P(None, b_axis, None, None, None)
        if name in ("ckv", "kr"):                     # [L,B,S,r]
            if seq_cp and leaf.shape[2] % n_model == 0:
                return P(None, b_axis, "model", None)
            return P(None, b_axis, None, None)
        if name == "ssm":                             # [..,B,H,P,N]
            h = leaf.shape[-3]
            pre = [None] * (nd - 4)
            return P(*pre, b_axis,
                     "model" if h % n_model == 0 else None, None, None)
        if name == "conv":                            # [..,B,w,d_xbc]
            pre = [None] * (nd - 3)
            return P(*pre, b_axis, None,
                     "model" if leaf.shape[-1] % n_model == 0 else None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(visit, cache_tree)


def zero1_pspecs(param_specs, params_tree, mesh):
    """Moment specs: add 'data' on the largest still-replicated dim."""
    n_data = mesh.shape["data"]

    def visit(spec, leaf):
        shape = leaf.shape
        parts = list(spec) + [None] * (len(shape) - len(spec))
        if any(p == "data" or (isinstance(p, tuple) and "data" in p)
               for p in parts):
            return P(*parts)          # FSDP already shards over 'data'
        # pick largest replicated dim divisible by n_data
        cand = [(shape[i], i) for i in range(len(shape))
                if parts[i] is None and shape[i] % n_data == 0
                and shape[i] >= n_data]
        if not cand:
            return P(*parts)
        _, i = max(cand)
        parts[i] = "data"
        return P(*parts)

    return jax.tree_util.tree_map_with_path(
        lambda path, spec, leaf: visit(spec, leaf), param_specs, params_tree)


def named(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
