"""Versioned wire codec for host<->host payloads (no pickle).

Everything that crosses a transport — RPC requests, per-target node
lists, built ``SubgraphRows``, full ``BatchPlan`` payloads including the
store's per-shard slot lists and generation pins — is a tree of plain
JSON values plus numpy arrays. The frame layout keeps the two worlds
separate so decode is exact and bounded:

    MAGIC "ACKW" | u16 version | u64 frame length      (14-byte header)
    u32 meta length | meta JSON                        (structure)
    raw array buffers, concatenated                    (data)

The meta JSON mirrors the tree; every ndarray is replaced by a
placeholder recording its exact ``dtype.str`` (endianness included),
shape (0-d scalars round-trip as 0-d), and (offset, nbytes) into the
buffer section. Decoding is ``np.frombuffer`` + reshape — bitwise
identical to what was encoded, which is what lets the loopback transport
prove the remote pipeline equals the in-process one.

Version mismatches and truncated/corrupt frames raise typed errors with
actionable messages (``WireVersionError`` / ``WireFormatError``) instead
of garbage arrays.
"""
from __future__ import annotations

import json
import struct
from typing import Any, Dict, List, Sequence

import numpy as np

MAGIC = b"ACKW"
WIRE_VERSION = 1

_HEADER = struct.Struct(">4sHQ")          # magic, version, frame length
_META_LEN = struct.Struct(">I")

_ND = "__nd__"                            # ndarray placeholder key
_BYTES = "__bytes__"                      # raw-bytes placeholder key
_RESERVED = (_ND, _BYTES)


class WireError(ValueError):
    """Base class for wire codec failures."""


class WireFormatError(WireError):
    """Frame is not a well-formed ACK wire frame (bad magic, truncation,
    out-of-bounds buffer reference, unencodable value)."""


class WireVersionError(WireError):
    """Frame was produced by an incompatible codec version."""


# -- generic tree codec ------------------------------------------------------

def encode(tree: Any) -> bytes:
    """Encode a JSON+ndarray tree into one self-describing frame."""
    buffers: List[bytes] = []
    offset = 0

    def enc(node):
        nonlocal offset
        if isinstance(node, np.ndarray):
            # record the ORIGINAL shape: ascontiguousarray promotes 0-d
            # scalars (store_gen/shard_gen pins) to 1-d on some numpys
            raw = np.ascontiguousarray(node).tobytes()
            ph = {_ND: [node.dtype.str, list(node.shape), offset,
                        len(raw)]}
            buffers.append(raw)
            offset += len(raw)
            return ph
        if isinstance(node, (bytes, bytearray, memoryview)):
            raw = bytes(node)
            ph = {_BYTES: [offset, len(raw)]}
            buffers.append(raw)
            offset += len(raw)
            return ph
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if not isinstance(k, str):
                    k = str(k)           # payload dicts may key by int id
                if k in _RESERVED:
                    raise WireFormatError(
                        f"dict key {k!r} is reserved by the wire codec")
                out[k] = enc(v)
            return out
        if isinstance(node, (list, tuple)):
            return [enc(v) for v in node]
        if isinstance(node, (np.integer,)):
            return int(node)
        if isinstance(node, (np.floating,)):
            return float(node)
        if isinstance(node, (np.bool_,)):
            return bool(node)
        if node is None or isinstance(node, (bool, int, float, str)):
            return node
        raise WireFormatError(
            f"cannot encode {type(node).__name__} on the wire; "
            "allowed: None/bool/int/float/str/bytes, numpy arrays, "
            "and lists/dicts of those")

    meta = json.dumps(enc(tree), separators=(",", ":")).encode("utf-8")
    body = b"".join(buffers)
    frame_len = _HEADER.size + _META_LEN.size + len(meta) + len(body)
    return b"".join([_HEADER.pack(MAGIC, WIRE_VERSION, frame_len),
                     _META_LEN.pack(len(meta)), meta, body])


def frame_length(header: bytes) -> int:
    """Total frame length declared by a 14-byte header (transports read
    the header first, then exactly the rest). Validates magic+version."""
    if len(header) < _HEADER.size:
        raise WireFormatError(
            f"short header: got {len(header)} bytes, "
            f"need {_HEADER.size}")
    magic, version, length = _HEADER.unpack_from(header)
    if magic != MAGIC:
        raise WireFormatError(
            f"bad magic {magic!r}: not an ACK wire frame "
            f"(expected {MAGIC!r})")
    if version != WIRE_VERSION:
        raise WireVersionError(
            f"wire version mismatch: peer sent v{version}, this process "
            f"speaks v{WIRE_VERSION} — upgrade the older side so device "
            "host and graph hosts run the same repro version")
    return int(length)


def decode(frame: bytes) -> Any:
    """Decode one frame back into the original tree (arrays bitwise)."""
    declared = frame_length(frame)       # validates magic + version
    if len(frame) < declared:
        raise WireFormatError(
            f"frame truncated: header declares {declared} bytes, "
            f"got {len(frame)}")
    pos = _HEADER.size
    (meta_len,) = _META_LEN.unpack_from(frame, pos)
    pos += _META_LEN.size
    if pos + meta_len > len(frame):
        raise WireFormatError(
            f"frame truncated inside meta section: need {meta_len} "
            f"meta bytes at offset {pos}, frame is {len(frame)}")
    try:
        meta = json.loads(frame[pos:pos + meta_len].decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise WireFormatError(f"corrupt meta section: {e}") from e
    body_off = pos + meta_len
    body_len = len(frame) - body_off

    def dec(node):
        if isinstance(node, dict):
            if set(node) == {_ND}:
                dt, shape, off, nbytes = node[_ND]
                if off < 0 or off + nbytes > body_len:
                    raise WireFormatError(
                        f"array buffer [{off}, {off + nbytes}) outside "
                        f"body of {body_len} bytes (corrupt frame)")
                dtype = np.dtype(dt)
                count = nbytes // dtype.itemsize if dtype.itemsize else 0
                a = np.frombuffer(frame, dtype=dtype, count=count,
                                  offset=body_off + off)
                return a.reshape(shape)
            if set(node) == {_BYTES}:
                off, nbytes = node[_BYTES]
                if off < 0 or off + nbytes > body_len:
                    raise WireFormatError(
                        f"bytes buffer [{off}, {off + nbytes}) outside "
                        f"body of {body_len} bytes (corrupt frame)")
                return frame[body_off + off:body_off + off + nbytes]
            return {k: dec(v) for k, v in node.items()}
        if isinstance(node, list):
            return [dec(v) for v in node]
        return node

    return dec(meta)


# -- domain helpers ----------------------------------------------------------

def node_lists_to_wire(node_lists: Sequence[np.ndarray]) -> dict:
    """Var-length per-target node lists -> one concat array + offsets."""
    lists = [np.asarray(nl, dtype=np.int64) for nl in node_lists]
    offsets = np.zeros(len(lists) + 1, dtype=np.int64)
    if lists:
        offsets[1:] = np.cumsum([len(nl) for nl in lists])
        data = np.concatenate(lists) if offsets[-1] else \
            np.empty(0, np.int64)
    else:
        data = np.empty(0, np.int64)
    return {"data": data, "offsets": offsets}


def node_lists_from_wire(d: dict) -> List[np.ndarray]:
    data, offsets = np.asarray(d["data"]), np.asarray(d["offsets"])
    return [data[offsets[i]:offsets[i + 1]]
            for i in range(len(offsets) - 1)]


_ROW_FIELDS = ("adj", "adj_mean", "mask", "edge_src", "edge_dst",
               "edge_w", "self_w", "edge_w_mean")
_ROW_SCALARS = ("n_vertices", "n_edges", "edges_dropped")


def rows_to_wire(rows: Sequence) -> dict:
    """Stack C per-target ``SubgraphRows`` into [C, ...] arrays (fixed
    shapes — the decoupling property — make the stack exact)."""
    d: Dict[str, np.ndarray] = {
        f: np.stack([getattr(r, f) for r in rows]) for f in _ROW_FIELDS}
    for f in _ROW_SCALARS:
        d[f] = np.asarray([getattr(r, f) for r in rows], dtype=np.int64)
    return d


def rows_from_wire(d: dict) -> List:
    from repro.core.subgraph import SubgraphRows
    c = d["adj"].shape[0]
    out = []
    for i in range(c):
        kw = {f: np.ascontiguousarray(d[f][i]) for f in _ROW_FIELDS}
        kw.update({f: int(d[f][i]) for f in _ROW_SCALARS})
        out.append(SubgraphRows(**kw).freeze())
    return out


def plan_to_wire(plan) -> dict:
    """BatchPlan -> wire tree: everything downstream stages read (Pack
    reads targets/node_lists/rows + the cache counters; the device side
    reads ``device``, whose store payload carries its generation pin —
    ``store_gen``/``shard_gen`` ride along bitwise, so residency pinning
    survives the hop). Frontiers ride along for cache-exact invalidation
    on whichever host holds the caches."""
    d: Dict[str, Any] = {
        "targets": np.asarray(plan.targets, dtype=np.int64),
        "nbr_hits": int(plan.nbr_hits),
        "nbr_misses": int(plan.nbr_misses),
        "build_hits": int(plan.build_hits),
        "build_misses": int(plan.build_misses),
        "row_gen": None if plan.row_gen is None else int(plan.row_gen),
    }
    if plan.node_lists is not None:
        d["node_lists"] = node_lists_to_wire(plan.node_lists)
    if plan.frontiers:
        keys = [int(t) for t, fr in plan.frontiers.items()
                if fr is not None]
        d["frontiers"] = {
            "targets": np.asarray(keys, dtype=np.int64),
            **node_lists_to_wire([plan.frontiers[t] for t in keys])}
    if plan.rows is not None:
        d["rows"] = rows_to_wire(plan.rows)
    if plan.device is not None:
        d["device"] = {k: np.asarray(v) for k, v in plan.device.items()}
    return d


def plan_from_wire(d: dict):
    from repro.core.batchplan import BatchPlan
    plan = BatchPlan(targets=np.asarray(d["targets"]))
    plan.nbr_hits = int(d["nbr_hits"])
    plan.nbr_misses = int(d["nbr_misses"])
    plan.build_hits = int(d["build_hits"])
    plan.build_misses = int(d["build_misses"])
    plan.row_gen = d.get("row_gen")
    if "node_lists" in d:
        plan.node_lists = node_lists_from_wire(d["node_lists"])
    if "frontiers" in d:
        fr = d["frontiers"]
        fronts = node_lists_from_wire(fr)
        plan.frontiers = {int(t): f for t, f
                          in zip(np.asarray(fr["targets"]), fronts)}
    if "rows" in d:
        plan.rows = rows_from_wire(d["rows"])
    if "device" in d:
        plan.device = dict(d["device"])
    return plan


def payload_nbytes(tree: Any) -> int:
    """Total array bytes in a tree (transfer accounting helper)."""
    if isinstance(tree, np.ndarray):
        return int(tree.nbytes)
    if isinstance(tree, dict):
        return sum(payload_nbytes(v) for v in tree.values())
    if isinstance(tree, (list, tuple)):
        return sum(payload_nbytes(v) for v in tree)
    return 0


__all__ = ["MAGIC", "WIRE_VERSION", "WireError", "WireFormatError",
           "WireVersionError", "encode", "decode", "frame_length",
           "node_lists_to_wire", "node_lists_from_wire",
           "rows_to_wire", "rows_from_wire",
           "plan_to_wire", "plan_from_wire", "payload_nbytes"]
