"""GAT attention kernel (paper §4.1 "Attention") in ACK dense mode.

Per subgraph c and head hh, on a [N, N] dense score tile (decoupling keeps
N <= 256, so the whole attention matrix lives in VMEM):

    e[i, j]    = LeakyReLU(s_dst[i] + s_src[j])       (VPU)
    e          = where(struct[i, j], e, -inf)          structural mask
    attn       = softmax_j(e)                          (VPU, row-wise)
    out[:, hh] = attn @ z[:, hh]                       (MXU)

The head loop is unrolled in the kernel (n_heads is static and small).
Softmax here is the Activation-Unit analogue (paper implements it in HLS);
on TPU it is VPU elementwise + the MXU matmul for the weighted aggregation.

Grid: (C,). VMEM at N=256, F=256, heads<=8: z 256 KB, struct 256 KB,
scores 256 KB (per head, reused), out 256 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(z_ref, ssrc_ref, sdst_ref, struct_ref, o_ref, *,
            n_heads: int, negative_slope: float):
    n = z_ref.shape[1]
    fh = z_ref.shape[2] // n_heads
    struct = struct_ref[0] > 0                        # [N, N] bool
    for hh in range(n_heads):                         # static unroll
        s_src = ssrc_ref[0, :, hh]                    # [N]
        s_dst = sdst_ref[0, :, hh]
        e = s_dst[:, None] + s_src[None, :]
        e = jnp.where(e >= 0, e, negative_slope * e)  # leaky relu
        e = jnp.where(struct, e, NEG_INF)
        m = jnp.max(e, axis=1, keepdims=True)
        ex = jnp.exp(e - m)
        ex = jnp.where(struct, ex, 0.0)
        attn = ex / jnp.maximum(jnp.sum(ex, axis=1, keepdims=True), 1e-20)
        zh = z_ref[0, :, hh * fh:(hh + 1) * fh].astype(jnp.float32)
        o_ref[0, :, hh * fh:(hh + 1) * fh] = jnp.dot(
            attn, zh, preferred_element_type=jnp.float32
        ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("n_heads", "negative_slope",
                                    "interpret"))
def gat_attention(z, s_src, s_dst, struct, *, n_heads: int,
                  negative_slope: float = 0.2, interpret: bool = False):
    """z [C,N,F] transformed features; s_src/s_dst [C,N,h] attention terms;
    struct [C,N,N] structural mask (>0 where edge j->i or i==j, rows with
    no structure produce zeros). Returns [C,N,F]."""
    C, N, F = z.shape
    assert F % n_heads == 0
    return pl.pallas_call(
        functools.partial(_kernel, n_heads=n_heads,
                          negative_slope=negative_slope),
        grid=(C,),
        in_specs=[
            pl.BlockSpec((1, N, F), lambda c: (c, 0, 0)),
            pl.BlockSpec((1, N, n_heads), lambda c: (c, 0, 0)),
            pl.BlockSpec((1, N, n_heads), lambda c: (c, 0, 0)),
            pl.BlockSpec((1, N, N), lambda c: (c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, N, F), lambda c: (c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((C, N, F), z.dtype),
        interpret=interpret,
    )(z, s_src, s_dst, struct)
