"""Jit'd kernel entry points with automatic backend dispatch.

On TPU the Pallas kernels run compiled; on CPU (this container) they run in
``interpret=True`` mode for correctness, and callers that want production
CPU speed use the XLA reference path instead (``impl='xla'``). The engine's
ACK dispatcher (core.ack) selects between dense/sg the way the paper's mode
mux does.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.fused_gnn import BLOCK_F_CANDIDATES  # noqa: F401
from repro.kernels.fused_gnn import fused_gnn_layer as _fused_pallas
from repro.kernels.gat_attention import gat_attention as _gat_pallas
from repro.kernels.scatter_gather import BLOCK_E_CANDIDATES  # noqa: F401
from repro.kernels.scatter_gather import \
    scatter_gather_aggregate as _sg_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fused_gnn_layer(*args, impl: str = "pallas", **kw):
    if impl == "xla":
        return ref.fused_gnn_layer_ref(*args, **kw)
    return _fused_pallas(*args, interpret=_interpret(), **kw)


def scatter_gather_aggregate(*args, impl: str = "pallas", **kw):
    if impl == "xla":
        return ref.scatter_gather_aggregate_ref(*args, **kw)
    return _sg_pallas(*args, interpret=_interpret(), **kw)


def gat_attention(*args, impl: str = "pallas", **kw):
    if impl == "xla":
        return ref.gat_attention_ref(*args, **kw)
    return _gat_pallas(*args, interpret=_interpret(), **kw)
