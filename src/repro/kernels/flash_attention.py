"""Flash attention (forward) as a Pallas TPU kernel — the prefill fix.

The dry-run shows dense-arch prefill is memory-bound: XLA materializes
[S, S] score tiles at every fusion boundary (phi3-medium 32k prefill:
88 s memory term vs 5 s compute term). The chunked-XLA path (models/
attention.py) fixes peak memory but not boundary traffic; this kernel holds
the score tile in VMEM for its whole lifetime, so HBM traffic collapses to
Q/K/V/O + the running statistics.

Grid (B*Kh*G, Sq/BQ, Sk/BK), K-blocks innermost with VMEM carries for the
online-softmax statistics (m, l) and the output accumulator. Causal masking
skips fully-masked K-blocks via pl.when. Per-step VMEM at BQ=BK=512,
Dh=128: q/k/v 256 KB each + acc 256 KB + scores 1 MB.

bytes(HBM) = Q + K + V + O = 4*S*Dh*bytes vs naive + 2*S^2*4:
at S=32k, Dh=128 that is a ~128x traffic cut on the attention op.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, scale: float, block_q: int, block_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:
        # K-block strictly above the diagonal of this Q-block: skip
        run = (ki * block_k) <= (qi * block_q + block_q - 1)

    @pl.when(run if causal else True)
    def _body():
        q = q_ref[0].astype(jnp.float32)               # [BQ, D]
        k = k_ref[0].astype(jnp.float32)               # [BK, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # [BQ, BK]
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]                            # [BQ, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                         # [BQ, BK]
        alpha = jnp.exp(m_prev - m_new)                # [BQ, 1]
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1,
                                                  keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-20)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                    block_k: int = 512, interpret: bool = False):
    """q [B,H,Sq,D]; k/v [B,H,Sk,D] (GQA pre-broadcast or Kh==H).
    Returns [B,H,Sq,D]. Forward-only (serving path)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    scale = 1.0 / (D ** 0.5)
    qf = q.reshape(B * H, Sq, D)
    kf = k.reshape(B * H, Sk, D)
    vf = v.reshape(B * H, Sk, D)
    grid = (B * H, Sq // bq, Sk // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, causal=causal, scale=scale,
                          block_q=bq, block_k=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, D)


def flash_cost(B, H, Sq, Sk, D, causal=True, bytes_per=2):
    """Analytic roofline terms for the kernel (used by launch.roofline for
    cells that select the Pallas path — custom calls are invisible to
    cost_analysis)."""
    frac = 0.5 if causal and Sq == Sk else 1.0
    flops = 4.0 * B * H * Sq * Sk * D * frac
    hbm = bytes_per * B * H * (Sq * D * 2 + Sk * D * 2)
    return {"flops": flops, "hbm_bytes": hbm}
