"""Pure-jnp oracles for every Pallas kernel (the ``assert_allclose``
references for the shape/dtype sweeps in tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30

ACTS = {"none": lambda x: x, "relu": jax.nn.relu, "elu": jax.nn.elu}


def fused_gnn_layer_ref(adj, h, w_neigh, w_self=None, b=None, mask=None, *,
                        act="relu", **_):
    C, N, Fin = h.shape
    w_any = w_neigh if w_neigh is not None else w_self
    Fout = w_any.shape[1]
    acc = jnp.zeros((C, N, Fout), jnp.float32)
    if w_neigh is not None:
        z = jnp.einsum("cij,cjf->cif", adj.astype(jnp.float32),
                       h.astype(jnp.float32))
        acc += jnp.einsum("cnf,fg->cng", z, w_neigh.astype(jnp.float32))
    if w_self is not None:
        acc += jnp.einsum("cnf,fg->cng", h.astype(jnp.float32),
                          w_self.astype(jnp.float32))
    if b is not None:
        acc += b.astype(jnp.float32)
    out = ACTS[act](acc)
    if mask is not None:
        out = out * mask[..., None].astype(jnp.float32)
    return out.astype(h.dtype)


def scatter_gather_aggregate_ref(src, dst, w, h, **_):
    C, E = src.shape
    _, N, F = h.shape

    def one(src_c, dst_c, w_c, h_c):
        upd = h_c.astype(jnp.float32)[src_c] * w_c[:, None]
        return jax.ops.segment_sum(upd, dst_c, num_segments=N)

    return jax.vmap(one)(src, dst, w.astype(jnp.float32), h).astype(h.dtype)


def gat_attention_ref(z, s_src, s_dst, struct, *, n_heads,
                      negative_slope=0.2, **_):
    C, N, F = z.shape
    fh = F // n_heads
    zf = z.astype(jnp.float32).reshape(C, N, n_heads, fh)
    e = (s_dst.astype(jnp.float32).transpose(0, 2, 1)[:, :, :, None]
         + s_src.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :])
    e = jnp.where(e >= 0, e, negative_slope * e)
    emask = (struct > 0)[:, None, :, :]
    e = jnp.where(emask, e, NEG_INF)
    attn = jax.nn.softmax(e, axis=-1)
    attn = jnp.where(emask, attn, 0.0)
    out = jnp.einsum("chij,cjhf->cihf", attn, zf)
    return out.reshape(C, N, F).astype(z.dtype)
