"""ACK scatter-gather (sparse) mode as a Pallas TPU kernel.

Faithful port of the paper's Scatter-Gather pipelines with the one FPGA
mechanism that does not transfer — the butterfly routing network — replaced
by a TPU-native equivalent: **routing as one-hot matmuls on the MXU**.

Per edge block of size EB (the p_sg-parallel pipelines analogue):
  Scatter:  gather source rows     P = onehot(src)   [EB,N] @ H [N,F]
            apply edge weights     U = w[:,None] * P           (VPU)
  Route+Gather: accumulate at dst  out += onehot(dst)^T-style  [N,EB] @ U

The one-hot matrices are built in-register from iota comparisons — no
gather/scatter memory ops, no RAW hazard (the paper's RAW unit): each edge
block's contributions are summed by the matmul reduction, and blocks are
accumulated sequentially through a VMEM-resident accumulator.

Grid: (C, E/EB) with out revisited across the E dimension (accumulate).
VMEM at N=256, F=512, EB=256: H 512 KB + onehots 2x256 KB + out 512 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# block_e autotune grid (obs.calib.run_block_autotune): candidate edge-
# block sizes (the paper's p_sg pipeline-parallelism analogue). E pads to
# a block multiple, so every candidate is legal at any E; a larger EB
# trades fewer accumulator round-trips for bigger one-hot matmuls.
# NOTE: changing block_e regroups the fp32 edge accumulation, so tuned
# results are allclose but not bit-identical to the default — dispatch
# bitwise-equality tests run with autotune off for this kernel.
BLOCK_E_CANDIDATES = (128, 256, 512)


def _kernel(src_ref, dst_ref, w_ref, h_ref, o_ref, acc_ref):
    e_blk = pl.program_id(1)
    n = h_ref.shape[1]
    eb = src_ref.shape[1]

    @pl.when(e_blk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    src = src_ref[0]                                  # [EB] int32
    dst = dst_ref[0]
    w = w_ref[0]                                      # [EB] f32
    iota_n = jax.lax.broadcasted_iota(jnp.int32, (eb, n), 1)
    onehot_src = (iota_n == src[:, None]).astype(jnp.float32)   # [EB,N]
    onehot_dst = (iota_n == dst[:, None]).astype(jnp.float32)   # [EB,N]
    p = jnp.dot(onehot_src, h_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32)   # Scatter: gather rows
    u = w[:, None] * p                                # x edge weight (VPU)
    upd = jnp.dot(onehot_dst.T, u,
                  preferred_element_type=jnp.float32)  # Route + Gather
    acc_ref[...] += upd                               # fp32 accumulation

    @pl.when(e_blk == pl.num_programs(1) - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def scatter_gather_aggregate(src, dst, w, h, *, block_e: int = 256,
                             interpret: bool = False):
    """Edge-list feature aggregation (Algorithm 4).

    src/dst [C,E] int32 (padding edges must carry w==0 and any valid index);
    w [C,E] float; h [C,N,F]. Returns out [C,N,F] with
    out[c,i] = sum_e (dst[c,e]==i) * w[c,e] * h[c, src[c,e]].
    """
    C, E = src.shape
    _, N, F = h.shape
    eb = min(block_e, E)
    if E % eb:                                        # pad to block multiple
        padn = eb - E % eb
        zpad = lambda a, v: jnp.pad(a, ((0, 0), (0, padn)),  # noqa: E731
                                    constant_values=v)
        src, dst, w = zpad(src, 0), zpad(dst, 0), zpad(w, 0)
        E = E + padn

    grid = (C, E // eb)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, eb), lambda c, e: (c, e)),        # src
            pl.BlockSpec((1, eb), lambda c, e: (c, e)),        # dst
            pl.BlockSpec((1, eb), lambda c, e: (c, e)),        # w
            pl.BlockSpec((1, N, F), lambda c, e: (c, 0, 0)),   # h
        ],
        out_specs=pl.BlockSpec((1, N, F), lambda c, e: (c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((C, N, F), h.dtype),
        scratch_shapes=[pltpu.VMEM((N, F), jnp.float32)],
        interpret=interpret,
    )(src, dst, w, h)
