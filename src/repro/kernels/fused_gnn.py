"""ACK dense (systolic) mode as a fused Pallas TPU kernel.

One GNN layer for a batch of C padded subgraphs:

    out[c] = act( alpha * A[c] @ (H[c] @ W_neigh)
                  + (H[c] @ W_self  if W_self is given)
                  + b ) * mask[c]

Both Feature Aggregation (A @ ·, the densified sparse kernel) and Feature
Transformation (· @ W) run on the MXU — the TPU-native expression of the
paper's single-module ACK: one compute unit executes every kernel, so there
is no FA/FT resource split to load-balance (paper Eq. 1 / §4.3).

Fusion detail (beyond-paper): associativity lets us compute
A @ (H @ W) instead of (A @ H) @ W, so the aggregated intermediate never
round-trips to HBM and the per-block FLOPs N·Fin·bf + N²·bf sum EXACTLY to
the unfused total across the f_out grid — zero redundant compute.

Grid: (C, f_out / BF). Per-step VMEM at N=256, Fin=512, BF=256 is ~1.8 MB
(A 256 KB, H 512 KB, W 512 KB, acc 2x256 KB) — comfortably inside VMEM, and
Mosaic double-buffers the HBM->VMEM streams across grid steps (the on-chip
analogue of the paper's double/triple buffering).

Covers GCN (W_neigh only), SAGE (+W_self), GIN (fold (1+eps)I into A on the
host: A' = A_bin + (1+eps)I, then MLP layer 2 is W_self-only with A unused).
GAT's attention kernel is kernels/gat_attention.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ACTS = {"none": lambda x: x, "relu": jax.nn.relu, "elu": jax.nn.elu}

# block_f autotune grid (obs.calib.run_block_autotune): candidate output-
# feature block widths, all 128-lane multiples except the 64 half-tile
# for narrow heads. bf partitions Fout COLUMNS only — every candidate
# computes each output column from the identical full-[Fin]/[N] reduction,
# so tuning block_f never changes numerics, only VMEM footprint vs grid
# parallelism. Candidates that don't divide Fout are skipped by the tuner
# (the kernel asserts Fout % bf == 0).
BLOCK_F_CANDIDATES = (64, 128, 256, 512)


def _kernel(a_ref, h_ref, wn_ref, ws_ref, b_ref, m_ref, o_ref, *,
            act: str, use_agg: bool, use_self: bool):
    h = h_ref[0]                                   # [N, Fin]
    acc = jnp.zeros(o_ref.shape[1:], jnp.float32)  # [N, BF]
    if use_agg:
        hw = jnp.dot(h, wn_ref[...],
                     preferred_element_type=jnp.float32)      # FT (MXU)
        acc += jnp.dot(a_ref[0].astype(jnp.float32), hw,
                       preferred_element_type=jnp.float32)    # FA (MXU)
    if use_self:
        acc += jnp.dot(h, ws_ref[...], preferred_element_type=jnp.float32)
    acc += b_ref[0].astype(jnp.float32)
    out = ACTS[act](acc) * m_ref[0][:, None].astype(jnp.float32)
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("act", "block_f", "interpret"))
def fused_gnn_layer(adj, h, w_neigh, w_self=None, b=None, mask=None, *,
                    act: str = "relu", block_f: int = 256,
                    interpret: bool = False):
    """adj [C,N,N]; h [C,N,Fin]; w_neigh [Fin,Fout] (or None); w_self
    [Fin,Fout] or None; b [Fout]; mask [C,N]. Returns [C,N,Fout]."""
    C, N, Fin = h.shape
    use_agg = w_neigh is not None
    use_self = w_self is not None
    w_any = w_neigh if use_agg else w_self
    Fout = w_any.shape[1]
    bf = min(block_f, Fout)
    assert Fout % bf == 0, (Fout, bf)
    if b is None:
        b = jnp.zeros((Fout,), h.dtype)
    if mask is None:
        mask = jnp.ones((C, N), h.dtype)
    wn = w_neigh if use_agg else jnp.zeros((Fin, Fout), h.dtype)
    ws = w_self if use_self else jnp.zeros((Fin, Fout), h.dtype)

    grid = (C, Fout // bf)
    return pl.pallas_call(
        functools.partial(_kernel, act=act, use_agg=use_agg,
                          use_self=use_self),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, N, N), lambda c, j: (c, 0, 0)),       # adj
            pl.BlockSpec((1, N, Fin), lambda c, j: (c, 0, 0)),     # h
            pl.BlockSpec((Fin, bf), lambda c, j: (0, j)),          # w_neigh
            pl.BlockSpec((Fin, bf), lambda c, j: (0, j)),          # w_self
            pl.BlockSpec((1, bf), lambda c, j: (0, j)),            # b
            pl.BlockSpec((1, N), lambda c, j: (c, 0)),             # mask
        ],
        out_specs=pl.BlockSpec((1, N, bf), lambda c, j: (c, 0, j)),
        out_shape=jax.ShapeDtypeStruct((C, N, Fout), h.dtype),
        interpret=interpret,
    )(adj, h, wn, ws, b.reshape(1, Fout), mask)
