"""Rotary position embedding with partial-fraction support.

``rope_fraction`` < 1.0 rotates only the first ``fraction * head_dim`` dims
(chatglm3's "2d rope" applies rotary to half the head dim); fraction 0 is a
no-op (whisper uses learned absolute positions).
"""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(rot_dim: int, theta: float, dtype=jnp.float32):
    return 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=dtype) / rot_dim))


def apply_rope(x, positions, theta: float = 10000.0, fraction: float = 1.0):
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    if fraction <= 0.0:
        return x
    d = x.shape[-1]
    rot = int(d * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    freqs = rope_freqs(rot, theta)                       # [rot/2]
    angles = positions[..., None, None].astype(jnp.float32) * freqs  # [...,S,1,rot/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)
