"""Multi-head / grouped-query attention with causal, cross and decode paths.

Shapes: hidden [B, S, D]; q [B, S, H, Dh]; k/v [B, S, Kh, Dh] with H % Kh == 0.
Decode path consumes a KV cache [B, S_max, Kh, Dh] and a scalar position.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, shard, split_keys
from repro.models.rope import apply_rope

NEG_INF = -1e30


def init_attn(key, d_model, n_heads, n_kv, head_dim, qkv_bias=False,
              dtype=jnp.float32):
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (d_model, n_heads * head_dim), dtype=dtype),
        "wk": dense_init(ks[1], (d_model, n_kv * head_dim), dtype=dtype),
        "wv": dense_init(ks[2], (d_model, n_kv * head_dim), dtype=dtype),
        "wo": dense_init(ks[3], (n_heads * head_dim, d_model), dtype=dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def qkv(params, x, n_heads, n_kv, head_dim):
    B, S, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, n_heads, head_dim)
    k = k.reshape(B, S, n_kv, head_dim)
    v = v.reshape(B, S, n_kv, head_dim)
    return q, k, v


def gqa_scores(q, k):
    """q [B,Sq,H,Dh], k [B,Sk,Kh,Dh] -> scores [B,Kh,G,Sq,Sk]."""
    B, Sq, H, Dh = q.shape
    Kh = k.shape[2]
    G = H // Kh
    qg = q.reshape(B, Sq, Kh, G, Dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32)
    return s / jnp.sqrt(Dh).astype(jnp.float32)


def gqa_out(probs, v):
    """probs [B,Kh,G,Sq,Sk], v [B,Sk,Kh,Dh] -> [B,Sq,H,Dh]."""
    B, Kh, G, Sq, _ = probs.shape
    Dh = v.shape[-1]
    o = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return o.reshape(B, Sq, Kh * G, Dh)


def chunked_gqa_attention(q, k, v, *, causal=True, block_q=1024):
    """Flash-style online attention in plain XLA: the [S, S] score matrix
    is never materialized -- queries are processed in blocks of ``block_q``
    under ``lax.map``, each block seeing only a [..., Bq, S] score tile.
    Peak temp memory drops from O(S^2) to O(S * block_q) per head group.

    q [B,S,H,Dh]; k/v [B,S,Kh,Dh]. Returns [B,S,H,Dh].
    """
    B, S, H, Dh = q.shape
    Kh = k.shape[2]
    G = H // Kh
    bq = min(block_q, S)
    assert S % bq == 0
    nblk = S // bq
    qg = q.reshape(B, S, Kh, G, Dh).transpose(0, 2, 3, 1, 4)  # [B,Kh,G,S,D]
    kt = k.transpose(0, 2, 1, 3)                              # [B,Kh,S,D]
    vt = v.transpose(0, 2, 1, 3)
    scale = 1.0 / jnp.sqrt(Dh).astype(jnp.float32)

    def one_block(i):
        qb = jax.lax.dynamic_slice_in_dim(qg, i * bq, bq, axis=3)
        s = jnp.einsum("bkgqd,bksd->bkgqs", qb, kt,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            rows = i * bq + jnp.arange(bq)
            mask = rows[:, None] >= jnp.arange(S)[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        num = jnp.einsum("bkgqs,bksd->bkgqd", p.astype(vt.dtype), vt)
        den = jnp.sum(p, axis=-1)[..., None].astype(vt.dtype)
        return num / jnp.maximum(den, 1e-20)

    ob = jax.lax.map(one_block, jnp.arange(nblk))   # [nblk,B,Kh,G,bq,D]
    o = ob.transpose(1, 2, 3, 0, 4, 5).reshape(B, Kh, G, S, Dh)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, S, H, Dh)


def full_attention(params, x, *, n_heads, n_kv, head_dim, rope_theta=1e4,
                   rope_fraction=1.0, causal=True, positions=None,
                   chunk_q: int = 0):
    """Training / prefill attention. Returns [B, S, D].

    ``chunk_q`` > 0 switches to the chunked online-softmax path (beyond-
    paper memory optimization; 0 keeps the naive S x S baseline)."""
    B, S, _ = x.shape
    q, k, v = qkv(params, x, n_heads, n_kv, head_dim)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q = apply_rope(q, positions, rope_theta, rope_fraction)
    k = apply_rope(k, positions, rope_theta, rope_fraction)
    q = shard(q, ("batch", None, "heads", None))
    k = shard(k, ("batch", None, "kv_heads", None))
    v = shard(v, ("batch", None, "kv_heads", None))
    if chunk_q and S > chunk_q and S % chunk_q == 0:
        o = chunked_gqa_attention(q, k, v, causal=causal, block_q=chunk_q)
    else:
        s = gqa_scores(q, k)                              # [B,Kh,G,S,S]
        if causal:
            mask = jnp.tril(jnp.ones((S, S), bool))
            s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = gqa_out(p, v)
    o = shard(o, ("batch", None, "heads", None))
    return o.reshape(B, S, n_heads * head_dim) @ params["wo"]


def cross_attention(params, x, kv_cache, *, n_heads, n_kv, head_dim):
    """x [B,Sq,D] attends to precomputed (k,v) [B,Skv,Kh,Dh] (whisper)."""
    B, Sq, _ = x.shape
    q = (x @ params["wq"]).reshape(B, Sq, n_heads, head_dim)
    if "bq" in params:
        q = q + params["bq"].reshape(n_heads, head_dim)
    k, v = kv_cache
    s = gqa_scores(q, k)
    p = jax.nn.softmax(s, axis=-1)
    o = gqa_out(p, v)
    return o.reshape(B, Sq, n_heads * head_dim) @ params["wo"]


def cross_kv(params, enc_out, *, n_kv, head_dim):
    B, Skv, _ = enc_out.shape
    k = (enc_out @ params["wk"]).reshape(B, Skv, n_kv, head_dim)
    v = (enc_out @ params["wv"]).reshape(B, Skv, n_kv, head_dim)
    if "bk" in params:
        k = k + params["bk"].reshape(n_kv, head_dim)
        v = v + params["bv"].reshape(n_kv, head_dim)
    return k, v


def decode_attention(params, x, k_cache, v_cache, pos, *, n_heads, n_kv,
                     head_dim, rope_theta=1e4, rope_fraction=1.0):
    """One-token decode. x [B,1,D]; caches [B,S,Kh,Dh]; pos scalar int32.

    Writes the new k/v at ``pos`` then attends over positions <= pos.
    Returns (out [B,1,D], k_cache, v_cache).
    """
    B = x.shape[0]
    S = k_cache.shape[1]
    q, k, v = qkv(params, x, n_heads, n_kv, head_dim)
    posv = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q, posv, rope_theta, rope_fraction)
    k = apply_rope(k, posv, rope_theta, rope_fraction)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))
    s = gqa_scores(q, k_cache)                            # [B,Kh,G,1,S]
    valid = (jnp.arange(S) <= pos)[None, None, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = gqa_out(p, v_cache)
    out = o.reshape(B, 1, n_heads * head_dim) @ params["wo"]
    return out, k_cache, v_cache
