"""Feed-forward blocks: SwiGLU (llama-family) and plain GELU MLP (whisper)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.common import act_fn, dense_init, shard, split_keys


def init_mlp(key, d_model, d_ff, act="silu", dtype=jnp.float32):
    ks = split_keys(key, 3)
    if act == "silu":                     # SwiGLU: gate/up/down
        return {
            "w_gate": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
            "w_up": dense_init(ks[1], (d_model, d_ff), dtype=dtype),
            "w_down": dense_init(ks[2], (d_ff, d_model), dtype=dtype),
        }
    return {                               # plain 2-layer MLP
        "w_in": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": dense_init(ks[1], (d_ff, d_model), dtype=dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def mlp(params, x, act="silu"):
    f = act_fn(act)
    axes = ("batch",) + (None,) * (x.ndim - 2) + ("ff",)
    if "w_gate" in params:
        h = f(x @ params["w_gate"]) * (x @ params["w_up"])
        h = shard(h, axes)
        return h @ params["w_down"]
    h = f(x @ params["w_in"] + params["b_in"])
    h = shard(h, axes)
    return h @ params["w_out"] + params["b_out"]
