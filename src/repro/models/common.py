"""Shared model building blocks: norms, dense init, activation, sharding hook.

Models are functional: ``init_*`` returns nested dicts of jnp arrays,
``apply``-style functions are pure. Activation sharding is annotated through
``shard()`` with *logical* axis names; the mapping to mesh axes is installed
by the launcher (see repro.distributed.sharding) and is a no-op otherwise, so
the same model code runs in single-device smoke tests and 512-device dry-runs.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

_tls = threading.local()


def _rules() -> Optional[dict]:
    return getattr(_tls, "rules", None)


@contextlib.contextmanager
def logical_axis_rules(rules: dict):
    """rules: logical axis name -> mesh axis (str, tuple, or None)."""
    old = _rules()
    _tls.rules = rules
    try:
        yield
    finally:
        _tls.rules = old


def logical_to_pspec(axes: Sequence[Optional[str]], rules=None) -> P:
    rules = rules if rules is not None else (_rules() or {})
    return P(*[rules.get(a) if a is not None else None for a in axes])


def shard(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Constrain activation sharding by logical axes; no-op without rules."""
    rules = _rules()
    if not rules:
        return x
    spec = logical_to_pspec(axes, rules)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# initializers


def dense_init(key, shape, in_axis=-2, dtype=jnp.float32):
    """LeCun-normal (fan-in) init used for all projection matrices."""
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms / activations


def rms_norm(x, scale, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def layer_norm(x, scale, bias, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * scale + bias


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def softplus(x):
    return jax.nn.softplus(x)


# ---------------------------------------------------------------------------
# misc


def split_keys(key, n):
    return list(jax.random.split(key, n))


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)


def param_count(tree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))
