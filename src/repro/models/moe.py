"""Mixture-of-Experts with sort-based capacity dispatch (fixed shapes).

Routing avoids the GShard [T, E, C] one-hot dispatch tensor: (token, k) pairs
are stably sorted by expert id, ranked within their expert via a cumulative
offset, and scattered into a dense per-expert buffer [E, C, D] (capacity drop
beyond C). Expert FFNs then run as one batched matmul — exactly the routed
FLOPs (x capacity factor), so the roofline's MODEL_FLOPS/HLO_FLOPs ratio
stays honest. The buffer's expert axis is sharded over 'model' (expert
parallelism); token gathers across the data axis lower to collectives that
the dry-run measures.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.common import dense_init, shard, split_keys
from repro.models.mlp import init_mlp, mlp


def capacity(n_tokens: int, moe: MoEConfig) -> int:
    c = int(n_tokens * moe.top_k * moe.capacity_factor) // moe.num_experts
    return max(8, c + (-c) % 8)       # multiple of 8 for TPU sublanes


def init_moe(key, d_model, moe: MoEConfig, dtype=jnp.float32):
    ks = split_keys(key, 5)
    E, F = moe.num_experts, moe.d_ff_expert
    p = {
        "router": dense_init(ks[0], (d_model, E), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (E, d_model, F), dtype=dtype),
        "w_up": dense_init(ks[2], (E, d_model, F), dtype=dtype),
        "w_down": dense_init(ks[3], (E, F, d_model), in_axis=-2, dtype=dtype),
    }
    if moe.num_shared:
        f_sh = moe.d_ff_shared or moe.d_ff_expert * moe.num_shared
        p["shared"] = init_mlp(ks[4], d_model, f_sh, "silu", dtype)
    return p


def route(router_w, x2d, moe: MoEConfig):
    """x2d [T, D] -> (expert ids [T,k], probs [T,k], aux load-balance loss)."""
    logits = x2d.astype(jnp.float32) @ router_w.astype(jnp.float32)  # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, moe.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)   # renormalize
    # Switch-style aux loss: E * sum_e f_e * P_e
    T, E = logits.shape
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (T * moe.top_k)
    aux = E * jnp.sum(me * ce)
    return top_e, top_p, aux


def dispatch_indices(top_e, n_tokens: int, moe: MoEConfig, cap: int):
    """Sort-based ranking. Returns (dest slot [T*k] in [0, E*C] where E*C
    means 'dropped', token index [T*k] in sorted order, perm)."""
    k = moe.top_k
    flat_e = top_e.reshape(-1)                                # [T*k]
    perm = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[perm]
    counts = jnp.zeros((moe.num_experts,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts                      # exclusive
    rank = jnp.arange(n_tokens * k, dtype=jnp.int32) - starts[sorted_e]
    keep = rank < cap
    dest = jnp.where(keep, sorted_e * cap + rank, moe.num_experts * cap)
    tok = perm // k                                           # source token
    return dest, tok, perm


def moe_ffn(params, x, moe: MoEConfig, *, act="silu"):
    """x [B, S, D] -> ([B, S, D], aux_loss)."""
    B, S, D = x.shape
    T = B * S
    x2d = x.reshape(T, D)
    cap = capacity(T, moe)
    E = moe.num_experts
    top_e, top_p, aux = route(params["router"], x2d, moe)
    dest, tok, perm = dispatch_indices(top_e, T, moe, cap)

    # scatter tokens into expert buffer (extra row catches drops)
    buf = jnp.zeros((E * cap + 1, D), x.dtype).at[dest].set(x2d[tok])
    eb = buf[:E * cap].reshape(E, cap, D)
    eb = shard(eb, ("experts", None, None))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, params["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", eb, params["w_up"])
    h = shard(h, ("experts", None, "expert_ff"))
    out_e = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    out_e = shard(out_e, ("experts", None, None))

    # combine: gather back, weight by router prob, sum over k
    flat = jnp.concatenate(
        [out_e.reshape(E * cap, D), jnp.zeros((1, D), x.dtype)], axis=0)
    contrib = flat[dest] * top_p.reshape(-1)[perm][:, None].astype(x.dtype)
    y = jnp.zeros((T, D), x.dtype).at[tok].add(contrib)

    if "shared" in params:
        y = y + mlp(params["shared"], x2d, act)
    return y.reshape(B, S, D), aux


@jax.custom_vjp
def _routed_dispatch(x2d, slot_tok, dest_tk, k):
    """eb[s] = x2d[slot_tok[s]-1] (0 rows for empty slots). The dispatch
    map (t,i)<->slot is a partial bijection, so the BACKWARD is also a
    gather: dx2d[t] = sum_i g_eb[dest_tk[t,i]]. Without this custom_vjp,
    autodiff emits a [E*cap, D] scatter-add that XLA expands in fp32 and
    GSPMD lowers as replicate+all-reduce (measured 7.7 GB/layer/device on
    dsv2-lite); as gathers everything stays bf16 and sharded."""
    return x2d[jnp.maximum(slot_tok - 1, 0)] \
        * (slot_tok > 0)[:, None].astype(x2d.dtype)


def _routed_dispatch_fwd(x2d, slot_tok, dest_tk, k):
    return _routed_dispatch(x2d, slot_tok, dest_tk, k), \
        (slot_tok, dest_tk, x2d.shape[0], k)


def _routed_dispatch_bwd(res, g):
    slot_tok, dest_tk, T, k = res
    gt = g.at[dest_tk].get(mode="fill", fill_value=0)    # [T*k, D]
    dx = jnp.sum(gt.reshape(T, k, g.shape[-1]), axis=1)
    return dx, None, None, None


_routed_dispatch.defvjp(_routed_dispatch_fwd, _routed_dispatch_bwd)


@jax.custom_vjp
def _routed_combine(flat, dest_tk, slot_pair):
    """contrib[t*k+i] = flat[dest_tk[t*k+i]] (0 when dropped); backward is
    the inverse gather dflat[s] = g[slot_pair[s]-1]."""
    return flat.at[dest_tk].get(mode="fill", fill_value=0)


def _routed_combine_fwd(flat, dest_tk, slot_pair):
    return _routed_combine(flat, dest_tk, slot_pair), (slot_pair,)


def _routed_combine_bwd(res, g):
    (slot_pair,) = res
    dflat = g[jnp.maximum(slot_pair - 1, 0)] \
        * (slot_pair > 0)[:, None].astype(g.dtype)
    return dflat, None, None


_routed_combine.defvjp(_routed_combine_fwd, _routed_combine_bwd)


def moe_ffn_gather(params, x, moe: MoEConfig, *, act="silu"):
    """Gather-based dispatch (optimized variant).

    The scatter formulation routes the [E*cap, D] activation buffer through
    an UNSHARDED scatter that GSPMD can only lower as replicate +
    all-reduce — measured 8.8 TB/device of all-reduce on dsv2-lite train.
    Here only *index* vectors are scattered (a few MB); every large tensor
    (forward AND backward, via the custom_vjp pair above) moves through
    gathers whose outputs carry explicit expert/data sharding constraints.
    """
    B, S, D = x.shape
    T = B * S
    x2d = x.reshape(T, D)
    x2d = shard(x2d, ("batch", None))
    cap = capacity(T, moe)
    E = moe.num_experts
    k = moe.top_k
    top_e, top_p, aux = route(params["router"], x2d, moe)
    dest, tok, perm = dispatch_indices(top_e, T, moe, cap)

    # index-only scatters (int32, ~MBs): slot -> token+1 (0 = empty slot)
    slot_tok = jnp.zeros((E * cap,), jnp.int32).at[dest].set(
        tok.astype(jnp.int32) + 1, mode="drop")
    # (t, i) -> slot (E*cap = dropped); slot -> (t*k+i)+1
    dest_tk = jnp.zeros((T * k,), jnp.int32).at[perm].set(
        dest.astype(jnp.int32))
    slot_pair = jnp.zeros((E * cap,), jnp.int32).at[dest].set(
        perm.astype(jnp.int32) + 1, mode="drop")

    eb = _routed_dispatch(x2d, slot_tok, dest_tk, k)
    eb = shard(eb.reshape(E, cap, D), ("experts", None, None))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, params["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", eb, params["w_up"])
    h = shard(h, ("experts", None, "expert_ff"))
    out_e = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    out_e = shard(out_e, ("experts", None, None))

    contrib = _routed_combine(out_e.reshape(E * cap, D), dest_tk,
                              slot_pair)                     # [T*k, D]
    contrib = shard(contrib, ("batch", None))
    w_tok = top_p.reshape(T * k).astype(x.dtype)
    y = jnp.einsum("tkd,tk->td", contrib.reshape(T, k, D),
                   w_tok.reshape(T, k))
    y = shard(y, ("batch", None))
    if "shared" in params:
        y = y + mlp(params["shared"], x2d, act)
    return y.reshape(B, S, D), aux


def moe_apply(params, x, moe: MoEConfig, *, act="silu"):
    """Dispatch-implementation mux (baseline scatter vs optimized gather)."""
    fn = moe_ffn_gather if moe.dispatch == "gather" else moe_ffn
    return fn(params, x, moe, act=act)


def moe_ffn_dense_oracle(params, x, moe: MoEConfig, *, act="silu"):
    """Reference: run every expert on every token, mask by routing. O(T*E*F)
    — test-only oracle (no capacity drop ⇒ matches when nothing overflows)."""
    B, S, D = x.shape
    x2d = x.reshape(B * S, D)
    top_e, top_p, _ = route(params["router"], x2d, moe)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", x2d, params["w_gate"])) * \
        jnp.einsum("td,edf->tef", x2d, params["w_up"])
    out_all = jnp.einsum("tef,efd->ted", h, params["w_down"])  # [T,E,D]
    w = jnp.zeros((x2d.shape[0], moe.num_experts), x.dtype)
    w = w.at[jnp.arange(x2d.shape[0])[:, None], top_e].add(top_p.astype(x.dtype))
    y = jnp.einsum("ted,te->td", out_all, w)
    if "shared" in params:
        y = y + mlp(params["shared"], x2d, act)
    return y.reshape(B, S, D)
