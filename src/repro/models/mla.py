"""Multi-head Latent Attention (DeepSeek V2/V3).

KV is compressed into a rank-``r`` latent ``c_kv`` plus a shared rotary key
``k_rope``; only those are cached at decode (cache is O(S * (r + rope_dim)),
independent of head count). Decode uses the *absorbed* formulation: W_uk is
folded into the query and W_uv into the output so per-head K/V are never
materialized. Prefill/train materialize per-head K/V (cheaper at long Sq).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.models.common import dense_init, rms_norm, shard, split_keys
from repro.models.rope import apply_rope

NEG_INF = -1e30


def init_mla(key, d_model, n_heads, mla: MLAConfig, dtype=jnp.float32):
    ks = split_keys(key, 8)
    qk_dim = mla.qk_nope_head_dim + mla.qk_rope_head_dim
    p = {
        "w_dkv": dense_init(ks[0], (d_model, mla.kv_lora_rank), dtype=dtype),
        "w_kr": dense_init(ks[1], (d_model, mla.qk_rope_head_dim), dtype=dtype),
        "w_uk": dense_init(ks[2], (mla.kv_lora_rank,
                                   n_heads * mla.qk_nope_head_dim),
                           in_axis=0, dtype=dtype),
        "w_uv": dense_init(ks[3], (mla.kv_lora_rank,
                                   n_heads * mla.v_head_dim),
                           in_axis=0, dtype=dtype),
        "wo": dense_init(ks[4], (n_heads * mla.v_head_dim, d_model),
                         dtype=dtype),
        "kv_norm": jnp.ones((mla.kv_lora_rank,), dtype),
    }
    if mla.q_lora_rank:
        p["w_dq"] = dense_init(ks[5], (d_model, mla.q_lora_rank), dtype=dtype)
        p["w_uq"] = dense_init(ks[6], (mla.q_lora_rank, n_heads * qk_dim),
                               in_axis=0, dtype=dtype)
        p["q_norm"] = jnp.ones((mla.q_lora_rank,), dtype)
    else:
        p["wq"] = dense_init(ks[5], (d_model, n_heads * qk_dim), dtype=dtype)
    return p


def _queries(params, x, n_heads, mla: MLAConfig):
    B, S, _ = x.shape
    qk_dim = mla.qk_nope_head_dim + mla.qk_rope_head_dim
    if "w_dq" in params:
        q = rms_norm(x @ params["w_dq"], params["q_norm"]) @ params["w_uq"]
    else:
        q = x @ params["wq"]
    q = q.reshape(B, S, n_heads, qk_dim)
    return q[..., :mla.qk_nope_head_dim], q[..., mla.qk_nope_head_dim:]


def mla_full(params, x, *, n_heads, mla: MLAConfig, rope_theta=1e4,
             causal=True, positions=None, chunk_q: int = 0):
    """Train / prefill path. Returns (out [B,S,D], (c_kv, k_rope)).

    ``chunk_q`` > 0: online-softmax over query blocks (the [S,S] score
    tensor is never materialized) — the optimized variant for 32k prefill.
    """
    B, S, _ = x.shape
    nope, rope_d, vd = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q_nope, q_rope = _queries(params, x, n_heads, mla)
    q_rope = apply_rope(q_rope, positions, rope_theta)
    c_kv = rms_norm(x @ params["w_dkv"], params["kv_norm"])     # [B,S,r]
    k_rope = apply_rope((x @ params["w_kr"])[:, :, None, :],
                        positions, rope_theta)                   # [B,S,1,rd]
    k_nope = (c_kv @ params["w_uk"]).reshape(B, S, n_heads, nope)
    v = (c_kv @ params["w_uv"]).reshape(B, S, n_heads, vd)
    q_nope = shard(q_nope, ("batch", None, "heads", None))
    k_nope = shard(k_nope, ("batch", None, "heads", None))
    scale = 1.0 / jnp.sqrt(jnp.float32(nope + rope_d))

    if chunk_q and S > chunk_q and S % chunk_q == 0:
        bq = chunk_q
        assert S % bq == 0
        kr = k_rope[:, :, 0, :]

        def one_block(i):
            qs = jax.lax.dynamic_slice_in_dim(q_nope, i * bq, bq, 1)
            qr = jax.lax.dynamic_slice_in_dim(q_rope, i * bq, bq, 1)
            sb = (jnp.einsum("bqhd,bshd->bhqs", qs, k_nope,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bqhd,bsd->bhqs", qr, kr,
                               preferred_element_type=jnp.float32)) * scale
            if causal:
                rows = i * bq + jnp.arange(bq)
                mask = rows[:, None] >= jnp.arange(S)[None, :]
                sb = jnp.where(mask[None, None], sb, NEG_INF)
            m = jnp.max(sb, axis=-1, keepdims=True)
            pb = jnp.exp(sb - m)
            num = jnp.einsum("bhqs,bshd->bqhd", pb.astype(v.dtype), v)
            den = jnp.sum(pb, axis=-1).astype(v.dtype)  # [B,h,q]
            return num / jnp.maximum(den.transpose(0, 2, 1)[..., None],
                                     1e-20)

        ob = jax.lax.map(one_block, jnp.arange(S // bq))
        o = ob.transpose(1, 0, 2, 3, 4).reshape(B, S, n_heads, vd)
    else:
        s = (jnp.einsum("bqhd,bshd->bhqs", q_nope, k_nope,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bqhd,bsxd->bhqs", q_rope,
                          k_rope, preferred_element_type=jnp.float32)) * scale
        if causal:
            mask = jnp.tril(jnp.ones((S, S), bool))
            s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqs,bshd->bqhd", p.astype(v.dtype), v)
    o = shard(o, ("batch", None, "heads", None))
    out = o.reshape(B, S, n_heads * vd) @ params["wo"]
    return out, (c_kv, k_rope[:, :, 0, :])


def mla_decode(params, x, ckv_cache, krope_cache, pos, *, n_heads,
               mla: MLAConfig, rope_theta=1e4):
    """Absorbed one-token decode.

    x [B,1,D]; ckv_cache [B,S,r]; krope_cache [B,S,rope_dim]; pos scalar.
    Returns (out [B,1,D], ckv_cache, krope_cache).
    """
    B = x.shape[0]
    S = ckv_cache.shape[1]
    nope, rope_d, vd = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    r = mla.kv_lora_rank
    posv = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope = _queries(params, x, n_heads, mla)
    q_rope = apply_rope(q_rope, posv, rope_theta)                # [B,1,H,rd]
    c_kv = rms_norm(x @ params["w_dkv"], params["kv_norm"])      # [B,1,r]
    k_rope = apply_rope((x @ params["w_kr"])[:, :, None, :], posv,
                        rope_theta)[:, :, 0, :]                  # [B,1,rd]
    ckv_cache = jax.lax.dynamic_update_slice(
        ckv_cache, c_kv.astype(ckv_cache.dtype), (0, pos, 0))
    krope_cache = jax.lax.dynamic_update_slice(
        krope_cache, k_rope.astype(krope_cache.dtype), (0, pos, 0))
    # absorb W_uk into q: q_lat [B,1,H,r]
    w_uk = params["w_uk"].reshape(r, n_heads, nope)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)
    scale = 1.0 / jnp.sqrt(jnp.float32(nope + rope_d))
    s = (jnp.einsum("bqhr,bsr->bhqs", q_lat, ckv_cache,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bqhd,bsd->bhqs", q_rope, krope_cache,
                      preferred_element_type=jnp.float32)) * scale
    valid = (jnp.arange(S) <= pos)[None, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhqs,bsr->bqhr", p.astype(ckv_cache.dtype), ckv_cache)
    w_uv = params["w_uv"].reshape(r, n_heads, vd)
    o = jnp.einsum("bqhr,rhd->bqhd", ctx, w_uv)
    out = o.reshape(B, 1, n_heads * vd) @ params["wo"]
    return out, ckv_cache, krope_cache
