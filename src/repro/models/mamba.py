"""Mamba-2 block with the SSD (state-space duality) chunked algorithm.

Sequence mixing cost is O(S·Q) per head (Q = chunk size) instead of O(S²):
within a chunk the recurrence is computed as a small dense [Q,Q] masked
matmul (MXU-friendly — the TPU analogue of the paper's systolic mode), and
chunks are chained with a `lax.scan` carrying the [B,H,P,N] state. Decode is
a single recurrence step on O(1) state — the "receptive field decoupled from
sequence length" property that qualifies SSM archs for the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.common import dense_init, rms_norm, shard, split_keys


def dims(d_model: int, ssm: SSMConfig):
    d_inner = ssm.expand * d_model
    n_heads = d_inner // ssm.head_dim
    d_conv_in = d_inner + 2 * ssm.ngroups * ssm.d_state
    return d_inner, n_heads, d_conv_in


def init_mamba(key, d_model: int, ssm: SSMConfig, dtype=jnp.float32):
    d_inner, H, d_xbc = dims(d_model, ssm)
    ks = split_keys(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d_model, 2 * d_inner
                                      + 2 * ssm.ngroups * ssm.d_state + H),
                              dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (ssm.d_conv, d_xbc)) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros((d_xbc,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dtype),
        "D": jnp.ones((H,), dtype),
        "norm": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(ks[2], (d_inner, d_model), dtype=dtype),
    }


# ---------------------------------------------------------------------------
# SSD core


def ssd_reference(x, dt, A, B, C):
    """Naive step-by-step recurrence oracle. x [b,S,H,P]; dt [b,S,H];
    A [H] (negative); B, C [b,S,H,N]. Returns y [b,S,H,P]."""
    b, S, H, P = x.shape
    N = B.shape[-1]

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        decay = jnp.exp(dt_t * A)[..., None, None]           # [b,H,1,1]
        dBx = jnp.einsum("bhn,bhp,bh->bhpn", B_t, x_t, dt_t)
        h = decay * h + dBx
        y = jnp.einsum("bhn,bhpn->bhp", C_t, h)
        return h, y

    h0 = jnp.zeros((b, H, P, N), jnp.float32)
    xs = (x.swapaxes(0, 1).astype(jnp.float32),
          dt.swapaxes(0, 1).astype(jnp.float32),
          B.swapaxes(0, 1).astype(jnp.float32),
          C.swapaxes(0, 1).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype)


def ssd_chunked(x, dt, A, B, C, chunk: int, h0=None):
    """Chunked SSD. Same signature as ssd_reference (S % chunk == 0).
    Returns (y, final_state [b,H,P,N])."""
    b, S, H, P = x.shape
    N = B.shape[-1]
    nc = S // chunk
    f32 = jnp.float32

    def rs(t):  # [b,S,...] -> [nc, b, chunk, ...]
        return t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xs = (rs(x.astype(f32)), rs(dt.astype(f32)), rs(B.astype(f32)),
          rs(C.astype(f32)))

    def body(state, inp):
        x_c, dt_c, B_c, C_c = inp                            # [b,Q,H,*]
        a = dt_c * A                                         # [b,Q,H] (<=0)
        cum = jnp.cumsum(a, axis=1)                          # inclusive
        total = cum[:, -1, :]                                # [b,H]
        # intra-chunk (dense masked matmul — MXU path)
        CB = jnp.einsum("bqhn,bshn->bhqs", C_c, B_c)
        diff = (cum.transpose(0, 2, 1)[:, :, :, None]
                - cum.transpose(0, 2, 1)[:, :, None, :])       # [b,H,Q,S]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        # mask BEFORE exp: t<s entries have positive exponents whose inf
        # would poison gradients through a post-hoc where()
        L = jnp.exp(jnp.where(mask, diff, -jnp.inf))
        scores = CB * L * dt_c.transpose(0, 2, 1)[:, :, None, :]
        y_intra = jnp.einsum("bhqs,bshp->bqhp", scores, x_c)
        # inter-chunk from carried state
        y_inter = jnp.einsum("bqhn,bhpn,bqh->bqhp", C_c, state,
                             jnp.exp(cum))
        # state update
        dec_out = jnp.exp(total[:, None, :] - cum) * dt_c    # [b,Q,H]
        upd = jnp.einsum("bshn,bshp,bsh->bhpn", B_c, x_c, dec_out)
        state = jnp.exp(total)[:, :, None, None] * state + upd
        return state, y_intra + y_inter

    if h0 is None:
        h0 = jnp.zeros((b, H, P, N), f32)
    state, ys = jax.lax.scan(body, h0, xs)
    y = ys.swapaxes(0, 1).reshape(b, S, H, P).astype(x.dtype)
    return y, state


def ssd_step(state, x_t, dt_t, A, B_t, C_t):
    """One decode step. state [b,H,P,N]; x_t [b,H,P]; dt_t [b,H];
    B_t, C_t [b,H,N]. Returns (state, y [b,H,P])."""
    f32 = jnp.float32
    decay = jnp.exp(dt_t.astype(f32) * A)[..., None, None]
    dBx = jnp.einsum("bhn,bhp,bh->bhpn", B_t.astype(f32), x_t.astype(f32),
                     dt_t.astype(f32))
    state = decay * state + dBx
    y = jnp.einsum("bhn,bhpn->bhp", C_t.astype(f32), state)
    return state, y.astype(x_t.dtype)


# ---------------------------------------------------------------------------
# full block


def _split_proj(params, x, d_model, ssm: SSMConfig):
    d_inner, H, _ = dims(d_model, ssm)
    gn = ssm.ngroups * ssm.d_state
    proj = x @ params["in_proj"]
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:2 * d_inner + 2 * gn]
    dt_raw = proj[..., 2 * d_inner + 2 * gn:]
    return z, xbc, dt_raw


def _split_xbc(xbc, d_inner, ssm: SSMConfig):
    gn = ssm.ngroups * ssm.d_state
    x_ssm = xbc[..., :d_inner]
    B = xbc[..., d_inner:d_inner + gn]
    C = xbc[..., d_inner + gn:]
    return x_ssm, B, C


def _bc_heads(t, b, S, H, ssm: SSMConfig):
    """[..., G*N] -> broadcast groups over heads -> [b,S,H,N]."""
    G = ssm.ngroups
    t = t.reshape(b, S, G, ssm.d_state)
    return jnp.repeat(t, H // G, axis=2)


def mamba_block(params, x, d_model: int, ssm: SSMConfig):
    """Full-sequence mixing. x [B,S,D] -> [B,S,D]."""
    b, S, _ = x.shape
    d_inner, H, d_xbc = dims(d_model, ssm)
    z, xbc, dt_raw = _split_proj(params, x, d_model, ssm)
    # causal depthwise conv, width d_conv
    pad = jnp.pad(xbc, ((0, 0), (ssm.d_conv - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + S] * params["conv_w"][i]
               for i in range(ssm.d_conv)) + params["conv_b"]
    xbc = jax.nn.silu(conv)
    x_ssm, B, C = _split_xbc(xbc, d_inner, ssm)
    x_h = x_ssm.reshape(b, S, H, ssm.head_dim)
    x_h = shard(x_h, ("batch", None, "heads", None))
    B_h = _bc_heads(B, b, S, H, ssm)
    C_h = _bc_heads(C, b, S, H, ssm)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, _ = ssd_chunked(x_h, dt, A, B_h, C_h, min(ssm.chunk_size, S))
    y = y + x_h * params["D"][None, None, :, None]
    y = y.reshape(b, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    return y @ params["out_proj"]


def init_mamba_cache(d_model: int, ssm: SSMConfig, batch: int,
                     dtype=jnp.float32):
    d_inner, H, d_xbc = dims(d_model, ssm)
    return {
        "conv": jnp.zeros((batch, ssm.d_conv - 1, d_xbc), dtype),
        "ssm": jnp.zeros((batch, H, ssm.head_dim, ssm.d_state), jnp.float32),
    }


def mamba_decode(params, x, cache, d_model: int, ssm: SSMConfig):
    """One-token step. x [B,1,D] -> ([B,1,D], cache)."""
    b = x.shape[0]
    d_inner, H, d_xbc = dims(d_model, ssm)
    z, xbc, dt_raw = _split_proj(params, x[:, 0], d_model, ssm)
    window = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)
    conv = jnp.einsum("bkc,kc->bc", window, params["conv_w"]) + params["conv_b"]
    new_conv = window[:, 1:]
    # conv ran in the cache dtype (fp32) -- return to the compute dtype so
    # the residual stream keeps a stable scan-carry type
    xbc_a = jax.nn.silu(conv).astype(x.dtype)
    x_ssm, B, C = _split_xbc(xbc_a, d_inner, ssm)
    x_h = x_ssm.reshape(b, H, ssm.head_dim)
    B_h = _bc_heads(B, b, 1, H, ssm)[:, 0]
    C_h = _bc_heads(C, b, 1, H, ssm)[:, 0]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    state, y = ssd_step(cache["ssm"], x_h, dt, A, B_h, C_h)
    y = y + x_h * params["D"][None, :, None]
    y = y.reshape(b, d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    out = (y @ params["out_proj"])[:, None, :]
    return out, {"conv": new_conv, "ssm": state}
