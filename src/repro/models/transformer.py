"""Model assembly for every assigned architecture family.

One functional API:
  init_params(cfg, key, max_seq)         -> params pytree
  train_logits(cfg, params, batch)       -> (logits [B,S,V], aux)
  prefill(cfg, params, batch)            -> (logits [B,S,V], cache)
  decode_step(cfg, params, cache, token, pos) -> (logits [B,1,V], cache)
  init_cache / cache_specs               -> decode-cache pytrees

Layer stacks are `lax.scan` over parameters stacked on axis 0 so HLO size and
compile time stay bounded for 28–72-layer models on a 512-device dry-run
mesh. Heterogeneous stacks (deepseek dense-first-k, jamba 8-layer periods)
use one scan per homogeneous segment (period bodies are unrolled in Python).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import mamba as mb
from repro.models.attention import (cross_attention, cross_kv,
                                    decode_attention, full_attention,
                                    init_attn)
from repro.models.common import (cast_tree, dense_init, embed_init,
                                 layer_norm, rms_norm, shard, split_keys)
from repro.models.mla import init_mla, mla_decode, mla_full
from repro.models.mlp import init_mlp, mlp
from repro.models.moe import init_moe, moe_apply


def _pdt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype.param_dtype)


def _cdt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype.compute_dtype)


# ---------------------------------------------------------------------------
# layer kinds


def _is_moe_layer(cfg: ModelConfig, idx: int) -> bool:
    if cfg.moe is None:
        return False
    m = cfg.moe
    if m.layout == "every":
        return True
    if m.layout == "alternate":
        return idx % 2 == 1
    if m.layout == "dense_first_k":
        return idx >= m.dense_first_k
    raise ValueError(m.layout)


def _jamba_is_attn(cfg: ModelConfig, idx: int) -> bool:
    # 1 attention layer per period, in the middle of the period
    return idx % cfg.hybrid_attn_period == cfg.hybrid_attn_period // 2


# ---------------------------------------------------------------------------
# single-layer init / apply


def _init_block(cfg: ModelConfig, key, kind: str):
    """kind: dense | moe | mamba | enc | dec"""
    dt = _pdt(cfg)
    D, H, Kh, Dh = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.resolved_head_dim)
    ks = split_keys(key, 4)
    p: Dict[str, Any] = {"ln1": jnp.ones((D,), dt)}
    if cfg.family == "audio":
        p["ln1_b"] = jnp.zeros((D,), dt)
    if kind == "mamba":
        p["mixer"] = mb.init_mamba(ks[0], D, cfg.ssm, dt)
        if cfg.family == "ssm":      # pure mamba: no separate FFN
            return p
    elif kind in ("dense", "moe", "enc", "dec"):
        if cfg.mla is not None and kind not in ("enc",):
            p["mixer"] = init_mla(ks[0], D, H, cfg.mla, dt)
        else:
            p["mixer"] = init_attn(ks[0], D, H, Kh, Dh, cfg.qkv_bias, dt)
    p["ln2"] = jnp.ones((D,), dt)
    if cfg.family == "audio":
        p["ln2_b"] = jnp.zeros((D,), dt)
    if kind == "dec":                # whisper decoder: cross-attention
        p["cross"] = init_attn(ks[2], D, H, Kh, Dh, cfg.qkv_bias, dt)
        p["ln3"] = jnp.ones((D,), dt)
        p["ln3_b"] = jnp.zeros((D,), dt)
    if kind == "moe":
        p["ffn"] = init_moe(ks[1], D, cfg.moe, dt)
    elif kind != "mamba" or cfg.family != "ssm":
        p["ffn"] = init_mlp(ks[1], D, cfg.d_ff, cfg.act, dt)
    return p


def _cast_block(cfg, bp):
    """Mixed precision: bf16 compute against fp32 master params. The cast
    happens inside the scan body so the residual carry keeps compute dtype.
    The MoE router is re-cast to fp32 inside route()."""
    return cast_tree(bp, _cdt(cfg))


def _apply_mixer_full(cfg, bp, h, kind):
    bp = _cast_block(cfg, bp)
    x = _norm_in(cfg, bp, h, "ln1")
    if kind == "mamba":
        return h + mb.mamba_block(bp["mixer"], x, cfg.d_model, cfg.ssm)
    if cfg.mla is not None:
        out, _ = mla_full(bp["mixer"], x, n_heads=cfg.n_heads, mla=cfg.mla,
                          rope_theta=cfg.rope_theta, causal=(kind != "enc"),
                          chunk_q=cfg.attn_chunk_q)
        return h + out
    out = full_attention(bp["mixer"], x, n_heads=cfg.n_heads,
                         n_kv=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
                         rope_theta=cfg.rope_theta,
                         rope_fraction=cfg.rope_fraction,
                         causal=(kind != "enc"),
                         chunk_q=cfg.attn_chunk_q)
    return h + out


def _norm_in(cfg, bp, h, name):
    if cfg.family == "audio":
        return layer_norm(h, bp[name], bp[name + "_b"], cfg.norm_eps)
    return rms_norm(h, bp[name], cfg.norm_eps)


def _apply_ffn(cfg, bp, h, kind, aux):
    if "ffn" not in bp:
        return h, aux
    bp = _cast_block(cfg, bp)
    x = _norm_in(cfg, bp, h, "ln2")
    if kind == "moe":
        out, a = moe_apply(bp["ffn"], x, cfg.moe, act=cfg.act)
        return h + out, aux + a
    return h + mlp(bp["ffn"], x, cfg.act), aux


def _block_full(cfg, bp, h, aux, kind):
    h = shard(h, ("batch", None, None))
    h = _apply_mixer_full(cfg, bp, h, kind)
    h, aux = _apply_ffn(cfg, bp, h, kind, aux)
    return h, aux


# ---------------------------------------------------------------------------
# parameter init


def _stack_init(fn, key, n):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(cfg: ModelConfig, key, max_seq: int = 4096):
    dt = _pdt(cfg)
    ks = split_keys(key, 10)
    p: Dict[str, Any] = {
        "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size),
                                  dtype=dt)
    if cfg.family == "audio":
        p["final_norm_b"] = jnp.zeros((cfg.d_model,), dt)
        p["pos_emb"] = embed_init(ks[2], (max_seq, cfg.d_model), dt)
        p["enc_pos_emb"] = embed_init(ks[3], (cfg.encoder.n_frames,
                                              cfg.d_model), dt)
        p["enc_blocks"] = _stack_init(
            lambda k: _init_block(cfg, k, "enc"), ks[4], cfg.encoder.n_layers)
        p["enc_norm"] = jnp.ones((cfg.d_model,), dt)
        p["enc_norm_b"] = jnp.zeros((cfg.d_model,), dt)
        p["blocks"] = _stack_init(
            lambda k: _init_block(cfg, k, "dec"), ks[5], cfg.n_layers)
        return p
    if cfg.family == "ssm":
        p["blocks"] = _stack_init(
            lambda k: _init_block(cfg, k, "mamba"), ks[4], cfg.n_layers)
        return p
    if cfg.hybrid_attn_period:      # jamba: stack of unrolled periods
        per = cfg.hybrid_attn_period
        n_per = cfg.n_layers // per

        # mixer kind and ffn kind are orthogonal in jamba, so build blocks
        # explicitly: mixer from _init_block, then override the ffn.
        def init_period(k):
            kk = split_keys(k, per)
            out = {}
            for i in range(per):
                kind = "dense" if _jamba_is_attn(cfg, i) else "mamba"
                bp = _init_block(cfg, kk[i], kind)
                if _is_moe_layer(cfg, i):
                    bp["ffn"] = init_moe(jax.random.fold_in(kk[i], 7),
                                         cfg.d_model, cfg.moe, dt)
                else:
                    bp["ffn"] = init_mlp(jax.random.fold_in(kk[i], 7),
                                         cfg.d_model, cfg.d_ff, cfg.act, dt)
                bp["ln2"] = jnp.ones((cfg.d_model,), dt)
                out[f"l{i}"] = bp
            return out

        p["blocks"] = _stack_init(init_period, ks[4], n_per)
        return p
    if cfg.moe is not None and cfg.moe.dense_first_k:
        k_dense = cfg.moe.dense_first_k
        p["dense_blocks"] = _stack_init(
            lambda k: _init_block(cfg, k, "dense"), ks[4], k_dense)
        p["blocks"] = _stack_init(
            lambda k: _init_block(cfg, k, "moe"), ks[5],
            cfg.n_layers - k_dense)
    else:
        kind = "moe" if (cfg.moe is not None) else "dense"
        p["blocks"] = _stack_init(
            lambda k: _init_block(cfg, k, kind), ks[4], cfg.n_layers)
    if cfg.mtp:                      # deepseek-v3 multi-token-prediction
        p["mtp"] = {
            "proj": dense_init(ks[6], (2 * cfg.d_model, cfg.d_model),
                               dtype=dt),
            "block": _init_block(cfg, ks[7], "dense"),
            "norm_h": jnp.ones((cfg.d_model,), dt),
            "norm_e": jnp.ones((cfg.d_model,), dt),
        }
    return p


# ---------------------------------------------------------------------------
# forward (train / prefill)


def _scan_blocks(cfg, stack, h, kind, remat=False):
    def body(carry, bp):
        h, aux = carry
        h, aux = _block_full(cfg, bp, h, aux, kind)
        return (h, aux), None

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.float32(0.0)), stack)
    return h, aux


def _jamba_forward(cfg, params, h, remat=False):
    per = cfg.hybrid_attn_period

    def body(carry, pp):
        h, aux = carry
        for i in range(per):
            kind = "dense" if _jamba_is_attn(cfg, i) else "mamba"
            fkind = "moe" if _is_moe_layer(cfg, i) else kind
            bp = pp[f"l{i}"]
            h = _apply_mixer_full(cfg, bp, h, kind)
            h, aux = _apply_ffn(cfg, bp, h, fkind, aux)
        return (h, aux), None

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.float32(0.0)), params["blocks"])
    return h, aux


def _encode(cfg, params, frames):
    """Whisper encoder over precomputed frame embeddings [B,T,D]."""
    h = (frames.astype(_cdt(cfg))
         + params["enc_pos_emb"][None].astype(_cdt(cfg)))
    h, _ = _scan_blocks(cfg, params["enc_blocks"], h, "enc")
    return layer_norm(h, params["enc_norm"], params["enc_norm_b"],
                      cfg.norm_eps).astype(_cdt(cfg))


def _embed_tokens(cfg, params, tokens):
    return params["embed"][tokens].astype(_cdt(cfg))


def _unembed(cfg, params, h):
    w = params.get("lm_head")
    if w is None:
        w = params["embed"].T
    h = shard(h, ("batch", None, None))
    logits = jnp.einsum("bsd,dv->bsv", h, w.astype(_cdt(cfg)),
                        preferred_element_type=jnp.float32)
    return shard(logits, ("batch", None, "vocab"))


def backbone(cfg: ModelConfig, params, batch, remat=False):
    """Token embeddings -> final hidden states. batch is a dict with
    'tokens' [B,S] plus family extras ('frames', 'patch_embeds')."""
    tokens = batch["tokens"]
    h = _embed_tokens(cfg, params, tokens)
    if cfg.family == "vlm":
        pe = batch["patch_embeds"].astype(h.dtype)  # [B,P,D] stub frontend
        h = jax.lax.dynamic_update_slice(h, pe, (0, 0, 0))
    if cfg.family == "audio":
        S = tokens.shape[1]
        h = h + params["pos_emb"][None, :S].astype(h.dtype)
        enc = _encode(cfg, params, batch["frames"])
        h, aux = _whisper_decode_full(cfg, params, h, enc, remat)
        h = layer_norm(h, params["final_norm"], params["final_norm_b"],
                       cfg.norm_eps)
        return h, aux
    if cfg.family == "ssm":
        h, aux = _scan_blocks(cfg, params["blocks"], h, "mamba", remat)
    elif cfg.hybrid_attn_period:
        h, aux = _jamba_forward(cfg, params, h, remat)
    elif cfg.moe is not None and cfg.moe.dense_first_k:
        h, _ = _scan_blocks(cfg, params["dense_blocks"], h, "dense", remat)
        h, aux = _scan_blocks(cfg, params["blocks"], h, "moe", remat)
    elif cfg.moe is not None:
        h, aux = _scan_blocks(cfg, params["blocks"], h, "moe", remat)
    else:
        h, aux = _scan_blocks(cfg, params["blocks"], h, "dense", remat)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, aux


def _whisper_decode_full(cfg, params, h, enc, remat=False):
    def body(carry, bp):
        h, aux = carry
        bp = _cast_block(cfg, bp)
        x = layer_norm(h, bp["ln1"], bp["ln1_b"], cfg.norm_eps)
        h = h + full_attention(bp["mixer"], x, n_heads=cfg.n_heads,
                               n_kv=cfg.n_kv_heads,
                               head_dim=cfg.resolved_head_dim,
                               rope_fraction=0.0, causal=True,
                               chunk_q=cfg.attn_chunk_q)
        x = layer_norm(h, bp["ln3"], bp["ln3_b"], cfg.norm_eps)
        kv = cross_kv(bp["cross"], enc, n_kv=cfg.n_kv_heads,
                      head_dim=cfg.resolved_head_dim)
        h = h + cross_attention(bp["cross"], x, kv, n_heads=cfg.n_heads,
                                n_kv=cfg.n_kv_heads,
                                head_dim=cfg.resolved_head_dim)
        x = layer_norm(h, bp["ln2"], bp["ln2_b"], cfg.norm_eps)
        h = h + mlp(bp["ffn"], x, cfg.act)
        return (h, aux), None

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.float32(0.0)), params["blocks"])
    return h, aux


def train_logits(cfg: ModelConfig, params, batch, remat=True):
    h, aux = backbone(cfg, params, batch, remat)
    logits = _unembed(cfg, params, h)
    extras = {"aux_loss": aux}
    if cfg.mtp and "mtp" in params:
        mp = params["mtp"]
        # predict token t+2 from hidden t combined with embedding of t+1
        emb_next = jnp.roll(_embed_tokens(cfg, params, batch["tokens"]),
                            -1, axis=1)
        x = jnp.concatenate(
            [rms_norm(h, mp["norm_h"].astype(h.dtype), cfg.norm_eps),
             rms_norm(emb_next, mp["norm_e"].astype(h.dtype), cfg.norm_eps)],
            axis=-1) @ mp["proj"].astype(h.dtype)
        x, _ = _block_full(cfg, mp["block"], x, jnp.float32(0.0), "dense")
        extras["mtp_logits"] = _unembed(cfg, params, x)
    return logits, extras


# ---------------------------------------------------------------------------
# decode: cache init + one-token step

CACHE_DTYPE = jnp.bfloat16


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               mode: str = "zeros"):
    """Decode cache pytree; mode='specs' returns ShapeDtypeStructs."""
    mk = (jax.ShapeDtypeStruct if mode == "specs"
          else lambda s, d: jnp.zeros(s, d))
    Dh = cfg.resolved_head_dim

    def attn_cache(n_layers):
        return {"k": mk((n_layers, batch, max_seq, cfg.n_kv_heads, Dh),
                        CACHE_DTYPE),
                "v": mk((n_layers, batch, max_seq, cfg.n_kv_heads, Dh),
                        CACHE_DTYPE)}

    def mla_cache(n_layers):
        return {"ckv": mk((n_layers, batch, max_seq, cfg.mla.kv_lora_rank),
                          CACHE_DTYPE),
                "kr": mk((n_layers, batch, max_seq,
                          cfg.mla.qk_rope_head_dim), CACHE_DTYPE)}

    def mamba_cache(n_layers):
        d_inner, H, d_xbc = mb.dims(cfg.d_model, cfg.ssm)
        return {"conv": mk((n_layers, batch, cfg.ssm.d_conv - 1, d_xbc),
                           CACHE_DTYPE),
                "ssm": mk((n_layers, batch, H, cfg.ssm.head_dim,
                           cfg.ssm.d_state), jnp.float32)}

    if cfg.family == "audio":
        return {"self": attn_cache(cfg.n_layers),
                "cross_k": mk((cfg.n_layers, batch, cfg.encoder.n_frames,
                               cfg.n_kv_heads, Dh), CACHE_DTYPE),
                "cross_v": mk((cfg.n_layers, batch, cfg.encoder.n_frames,
                               cfg.n_kv_heads, Dh), CACHE_DTYPE)}
    if cfg.family == "ssm":
        return {"mamba": mamba_cache(cfg.n_layers)}
    if cfg.hybrid_attn_period:
        per = cfg.hybrid_attn_period
        n_per = cfg.n_layers // per
        d_inner, H, d_xbc = mb.dims(cfg.d_model, cfg.ssm)
        return {
            "attn": attn_cache(n_per),
            "conv": mk((n_per, per - 1, batch, cfg.ssm.d_conv - 1, d_xbc),
                       CACHE_DTYPE),
            "ssm": mk((n_per, per - 1, batch, H, cfg.ssm.head_dim,
                       cfg.ssm.d_state), jnp.float32),
        }
    if cfg.mla is not None:
        if cfg.moe is not None and cfg.moe.dense_first_k:
            return {"dense": mla_cache(cfg.moe.dense_first_k),
                    "moe": mla_cache(cfg.n_layers - cfg.moe.dense_first_k)}
        return {"moe": mla_cache(cfg.n_layers)}
    return {"attn": attn_cache(cfg.n_layers)}


def _decode_attn_block(cfg, bp, h, kc, vc, pos, kind="dense"):
    bp = _cast_block(cfg, bp)
    x = _norm_in(cfg, bp, h, "ln1")
    out, kc, vc = decode_attention(
        bp["mixer"], x, kc, vc, pos, n_heads=cfg.n_heads,
        n_kv=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
        rope_theta=cfg.rope_theta, rope_fraction=cfg.rope_fraction)
    h = h + out
    h, _ = _apply_ffn(cfg, bp, h, kind, jnp.float32(0.0))
    return h, kc, vc


def _decode_mla_block(cfg, bp, h, ckv, kr, pos, kind):
    bp = _cast_block(cfg, bp)
    x = _norm_in(cfg, bp, h, "ln1")
    out, ckv, kr = mla_decode(bp["mixer"], x, ckv, kr, pos,
                              n_heads=cfg.n_heads, mla=cfg.mla,
                              rope_theta=cfg.rope_theta)
    h = h + out
    h, _ = _apply_ffn(cfg, bp, h, kind, jnp.float32(0.0))
    return h, ckv, kr


def _decode_mamba_block(cfg, bp, h, cache, kind="mamba"):
    bp = _cast_block(cfg, bp)
    x = _norm_in(cfg, bp, h, "ln1")
    out, cache = mb.mamba_decode(
        bp["mixer"], x,
        {"conv": cache["conv"].astype(jnp.float32), "ssm": cache["ssm"]},
        cfg.d_model, cfg.ssm)
    h = h + out
    h, _ = _apply_ffn(cfg, bp, h, kind, jnp.float32(0.0))
    return h, {"conv": cache["conv"].astype(CACHE_DTYPE),
               "ssm": cache["ssm"]}


def decode_step(cfg: ModelConfig, params, cache, token, pos):
    """token [B,1] int32; pos scalar int32. Returns (logits [B,1,V], cache)."""
    h = _embed_tokens(cfg, params, token)
    new_cache = dict(cache)

    if cfg.family == "audio":
        h = h + jax.lax.dynamic_slice_in_dim(params["pos_emb"], pos, 1)[None]

        def body(hh, xs):
            bp, kc, vc, ck, cv = xs
            bp = _cast_block(cfg, bp)
            x = layer_norm(hh, bp["ln1"], bp["ln1_b"], cfg.norm_eps)
            out, kc, vc = decode_attention(
                bp["mixer"], x, kc, vc, pos, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
                rope_fraction=0.0)
            hh = hh + out
            x = layer_norm(hh, bp["ln3"], bp["ln3_b"], cfg.norm_eps)
            hh = hh + cross_attention(bp["cross"], x, (ck, cv),
                                      n_heads=cfg.n_heads,
                                      n_kv=cfg.n_kv_heads,
                                      head_dim=cfg.resolved_head_dim)
            x = layer_norm(hh, bp["ln2"], bp["ln2_b"], cfg.norm_eps)
            hh = hh + mlp(bp["ffn"], x, cfg.act)
            return hh, (kc, vc)

        h, (ks, vs) = jax.lax.scan(
            body, h, (params["blocks"], cache["self"]["k"],
                      cache["self"]["v"], cache["cross_k"],
                      cache["cross_v"]))
        new_cache["self"] = {"k": ks, "v": vs}
        h = layer_norm(h, params["final_norm"], params["final_norm_b"],
                       cfg.norm_eps)
        return _unembed(cfg, params, h), new_cache

    if cfg.family == "ssm":
        def body(hh, xs):
            bp, conv, ssm = xs
            hh, c = _decode_mamba_block(cfg, bp, hh,
                                        {"conv": conv, "ssm": ssm})
            return hh, (c["conv"], c["ssm"])

        h, (convs, ssms) = jax.lax.scan(
            body, h, (params["blocks"], cache["mamba"]["conv"],
                      cache["mamba"]["ssm"]))
        new_cache["mamba"] = {"conv": convs, "ssm": ssms}

    elif cfg.hybrid_attn_period:
        per = cfg.hybrid_attn_period

        def body(hh, xs):
            pp, kc, vc, convs, ssms = xs
            new_conv, new_ssm = [], []
            mi = 0
            for i in range(per):
                bp = pp[f"l{i}"]
                fkind = "moe" if _is_moe_layer(cfg, i) else "dense"
                if _jamba_is_attn(cfg, i):
                    hh, kc, vc = _decode_attn_block(cfg, bp, hh, kc, vc,
                                                    pos, fkind)
                else:
                    hh, c = _decode_mamba_block(
                        cfg, bp, hh, {"conv": convs[mi], "ssm": ssms[mi]},
                        fkind)
                    new_conv.append(c["conv"])
                    new_ssm.append(c["ssm"])
                    mi += 1
            return hh, (kc, vc, jnp.stack(new_conv), jnp.stack(new_ssm))

        h, (ks, vs, convs, ssms) = jax.lax.scan(
            body, h, (params["blocks"], cache["attn"]["k"],
                      cache["attn"]["v"], cache["conv"], cache["ssm"]))
        new_cache["attn"] = {"k": ks, "v": vs}
        new_cache["conv"], new_cache["ssm"] = convs, ssms

    elif cfg.mla is not None:
        def run(stack, cch, kind):
            def body(hh, xs):
                bp, ckv, kr = xs
                hh, ckv, kr = _decode_mla_block(cfg, bp, hh, ckv, kr, pos,
                                                kind)
                return hh, (ckv, kr)
            return jax.lax.scan(body, h, (stack, cch["ckv"], cch["kr"]))

        hh = h
        if "dense" in cache:
            hh, (ckvs, krs) = run(params["dense_blocks"], cache["dense"],
                                  "dense")
            new_cache["dense"] = {"ckv": ckvs, "kr": krs}

            def body(hhh, xs):
                bp, ckv, kr = xs
                hhh, ckv, kr = _decode_mla_block(cfg, bp, hhh, ckv, kr, pos,
                                                 "moe")
                return hhh, (ckv, kr)
            hh, (ckvs, krs) = jax.lax.scan(
                body, hh, (params["blocks"], cache["moe"]["ckv"],
                           cache["moe"]["kr"]))
        else:
            def body(hhh, xs):
                bp, ckv, kr = xs
                kind = "moe" if cfg.moe is not None else "dense"
                hhh, ckv, kr = _decode_mla_block(cfg, bp, hhh, ckv, kr, pos,
                                                 kind)
                return hhh, (ckv, kr)
            hh, (ckvs, krs) = jax.lax.scan(
                body, hh, (params["blocks"], cache["moe"]["ckv"],
                           cache["moe"]["kr"]))
        new_cache["moe"] = {"ckv": ckvs, "kr": krs}
        h = hh

    else:
        kind = "moe" if cfg.moe is not None else "dense"

        def body(hh, xs):
            bp, kc, vc = xs
            hh, kc, vc = _decode_attn_block(cfg, bp, hh, kc, vc, pos, kind)
            return hh, (kc, vc)

        h, (ks, vs) = jax.lax.scan(
            body, h, (params["blocks"], cache["attn"]["k"],
                      cache["attn"]["v"]))
        new_cache["attn"] = {"k": ks, "v": vs}

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return _unembed(cfg, params, h), new_cache


def prefill(cfg: ModelConfig, params, batch):
    """Full-sequence forward producing logits; used for the prefill shape
    cell. (Cache population during prefill is exercised at small scale in
    tests via decode_step loops; the 32k dry-run cell measures the
    dominant cost — the full forward.)"""
    h, _ = backbone(cfg, params, batch, remat=False)
    return _unembed(cfg, params, h)
