"""Adaptive Computation Kernel (ACK) — execution-mode dispatch (paper §4.2).

The paper's ACK is ONE hardware module whose datapath is muxed between
Systolic Mode (dense) and Scatter-Gather Mode (sparse) by control bits, with
one-cycle switch overhead. The TPU analogue: both modes are MXU programs
(kernels/fused_gnn.py and kernels/scatter_gather.py), and the "control
bits" become a *static per-(model, N, E) mode decision* made from arithmetic
intensity — chosen at trace time so the jitted program contains exactly one
datapath, the moral equivalent of setting the mux before kernel start.

Mode economics per layer (f features, N vertices, E edges):
    dense FA FLOPs  = 2 N^2 f        (adjacency densified -> MXU)
    sg    FA FLOPs  = 2 E f          (+ 4 N_blk E f one-hot routing matmuls)
Dense wins whenever N^2 <~ 3E; with the paper's receptive fields
(N in 64..256, E up to N*avg_deg) subgraphs are usually dense enough that
the densified path wins on TPU — the paper's own observation that a small
fixed receptive field makes everything MXU-friendly, taken to its limit.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class AckDecision:
    mode: str            # "dense" | "sg"
    dense_flops: float
    sg_flops: float
    reason: str


def choose_mode(n: int, avg_edges: float, f: int,
                force: str | None = None) -> AckDecision:
    """Static mode mux. ``avg_edges`` is the mean induced-subgraph edge
    count for the workload (host knows it after INI)."""
    dense = 2.0 * n * n * f
    # SG on TPU pays the one-hot routing matmuls: ~2 * EB-blocked matmuls
    # of [E,N]x[N,f] and [N,E]x[E,f] => 4*E*N*f, dominating 2*E*f.
    sg = 4.0 * avg_edges * n * f
    if force in ("dense", "sg"):
        return AckDecision(force, dense, sg, "forced")
    mode = "dense" if dense <= sg else "sg"
    # break-even: dense <= sg  <=>  2*N^2*f <= 4*E*N*f  <=>  N <= 2E —
    # report the quantities actually compared
    return AckDecision(mode, dense, sg,
                       f"N={n} vs 2E={2*avg_edges:.0f}")
