"""Coupled (recursive message-passing) GNN baseline — Algorithm 1.

Two roles:
  1. *Performance baseline* (Figs. 1/3/8): ``lhop_nodes`` materializes the
     exploding L-hop receptive field (optionally fanout-sampled like
     GraphSAGE / GraphACT) so benchmarks can measure the exponential
     compute/communication growth the paper argues against.
  2. *Correctness oracle*: ``coupled_reference_embedding`` is a literal,
     independent numpy implementation of Algorithm 1's recursion. For any
     target, decoupled inference over the FULL L-hop induced subgraph with
     readout='target' must equal it exactly — the paper's equivalence.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np

from repro.graphs.csr import CSRGraph, subgraph_edges


def lhop_nodes(g: CSRGraph, target: int, L: int,
               fanouts: Optional[Sequence[int]] = None,
               seed: int = 0) -> np.ndarray:
    """Vertices within L hops (target first). ``fanouts[l]`` caps sampled
    neighbors per vertex at hop l (GraphSAGE-style); None = full expansion."""
    rng = np.random.default_rng(seed + target)
    seen = {int(target)}
    frontier = np.array([target], dtype=np.int64)
    order = [int(target)]
    for hop in range(L):
        nxt = []
        for u in frontier:
            nbrs = g.neighbors(int(u))
            if fanouts is not None and len(nbrs) > fanouts[hop]:
                nbrs = rng.choice(nbrs, size=fanouts[hop], replace=False)
            nxt.append(nbrs)
        if not nxt:
            break
        cand = np.unique(np.concatenate(nxt))
        new = [int(v) for v in cand if int(v) not in seen]
        seen.update(new)
        order.extend(new)
        frontier = np.array(new, dtype=np.int64)
        if len(frontier) == 0:
            break
    return np.array(order, dtype=np.int64)


def receptive_field_size(g: CSRGraph, targets, L: int,
                         fanouts=None) -> float:
    """Average |L-hop receptive field| — the O(d^L) growth curve (Fig. 1)."""
    return float(np.mean([len(lhop_nodes(g, int(t), L, fanouts))
                          for t in targets]))


# ---------------------------------------------------------------------------
# Algorithm 1 oracle (independent implementation: per-vertex numpy loops)


def _gcn_norm_weights(nodes: np.ndarray, src: np.ndarray, dst: np.ndarray):
    """Same normalization convention as core.subgraph.build_subgraph:
    deg = in-degree within the induced subgraph + 1 (self loop)."""
    k = len(nodes)
    deg = np.ones(k, np.float64)
    np.add.at(deg, dst, 1.0)
    return 1.0 / np.sqrt(deg)


def coupled_reference_embedding(g: CSRGraph, target: int, L: int,
                                params: Dict, kind: str = "gcn"
                                ) -> np.ndarray:
    """h_target^L via the message-passing recursion of Algorithm 1 over the
    L-hop neighborhood, with layer math matching repro.gnn.layers (fp64
    numpy — an independent code path from the jitted engine).

    Supports kind in {gcn, sage}. GAT/GIN equivalence is exercised through
    the engine-level dense==sg property instead.
    """
    nodes = lhop_nodes(g, target, L)
    k = len(nodes)
    src, dst = subgraph_edges(g, nodes)
    inv_sqrt = _gcn_norm_weights(nodes, src, dst)
    indeg = np.zeros(k, np.float64)
    np.add.at(indeg, dst, 1.0)

    nbrs_in: list = [[] for _ in range(k)]   # incoming edges per dst
    for s, d in zip(src, dst):
        nbrs_in[d].append(s)

    h = g.features[nodes].astype(np.float64)
    for layer in range(L):
        p = params["layer0"] if layer == 0 else {
            key: np.asarray(v)[layer - 1] for key, v in
            params["layers"].items()}
        new_h = np.zeros((k, np.asarray(
            p["w" if kind == "gcn" else "w_self"]).shape[1]))
        for j in range(k):
            if kind == "gcn":
                z = inv_sqrt[j] * inv_sqrt[j] * h[j]          # self loop
                for s in nbrs_in[j]:
                    z = z + inv_sqrt[j] * inv_sqrt[s] * h[s]
                out = z @ np.asarray(p["w"]) + np.asarray(p["b"])
            else:                                             # sage-mean
                if nbrs_in[j]:
                    z = np.mean([h[s] for s in nbrs_in[j]], axis=0)
                else:
                    z = np.zeros_like(h[j])
                out = (h[j] @ np.asarray(p["w_self"])
                       + z @ np.asarray(p["w_neigh"])
                       + np.asarray(p["b"]))
            new_h[j] = np.maximum(out, 0.0)                   # relu
        h = new_h
    return h[0]   # target is nodes[0]


def coupled_cost_model(g: CSRGraph, targets, L: int, f: int,
                       fanouts=None) -> Dict[str, float]:
    """Computation / communication cost of the Coupled model (paper §3.2):
    compute O(N_rf * f^2), host->device bytes O(N_rf * f)."""
    n_rf = receptive_field_size(g, targets, L, fanouts)
    return {
        "receptive_field": n_rf,
        "flops_per_target": 2.0 * n_rf * f * f * L / max(L, 1) * L,
        "bytes_per_target": 4.0 * n_rf * f,
    }
