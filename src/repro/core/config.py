"""ServingConfig — the one typed knob surface for a serving deployment.

Engine construction used to take a growing pile of keyword arguments
(batch_size / impl / num_threads / store=... / depth buried in the
scheduler); the multi-host transport would have added five more. This
module folds them into ONE frozen config object covering the three knob
families a deployment has:

  * device program:  batch_size, mode, impl, e_pad, seed
  * host pipeline:   num_threads, depth (triple buffering),
                     max_inflight (backpressure), max_wait_s
                     (micro-batcher tail-latency deadline)
  * store + transport: ``StorePolicy``, and where Select/Build run —
      transport="local"   in-process stages (the default)
      transport="inproc"  a private GraphHostService behind the loopback
                          transport: full wire codec, one process
                          (hermetic bitwise check of the remote path)
      transport="socket"  TCP to ``endpoints`` graph hosts, routed
                          round-robin or partition-affine with per-call
                          timeout + bounded retry

``DecoupledEngine(graph, cfg, config=ServingConfig(...))`` and
``GNNServer.register(name, graph=..., cfg=..., config=...)`` are the new
spellings; the old per-kwarg spellings still work through
``ServingConfig.from_kwargs`` (DeprecationWarning — see
docs/API_MIGRATION.md for the mapping).
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass, field
from typing import Optional, Tuple

from repro.store.policy import StorePolicy

TRANSPORT_MODES = ("local", "inproc", "socket")
ROUTING_MODES = ("round_robin", "affine")


@dataclass(frozen=True)
class ServingConfig:
    """Per-deployment serving configuration (see module docstring)."""
    # device program
    batch_size: int = 64
    mode: str = "auto"                 # per-op mux: auto | dense | sg
    impl: str = "xla"                  # kernel substrate: xla | pallas
    seed: int = 0                      # param init when params=None
    e_pad: Optional[int] = None        # edge budget; None = derive
    # store
    store: StorePolicy = field(default_factory=StorePolicy)
    # host pipeline
    num_threads: int = 8
    depth: int = 3                     # paper's triple buffering
    max_inflight: Optional[int] = None  # backpressure; None = 2 * depth
    max_wait_s: float = 0.005          # micro-batcher deadline (server)
    # transport: where Select/Build run
    transport: str = "local"
    endpoints: Tuple[str, ...] = ()    # "host:port" graph hosts (socket)
    rpc_timeout_s: float = 30.0        # per-call deadline
    rpc_retries: int = 2               # extra attempts on OTHER hosts
    rpc_concurrency: int = 4           # in-flight calls per deployment
    routing: str = "round_robin"       # round_robin | affine
    # observability: None (default) = tracing off, zero-cost; a
    # TraceConfig enables per-ticket spans + histograms (obs package)
    trace: Optional[object] = None
    # precompute: None (default) = pure online serving; a
    # PrecomputeConfig enables the offline layer-major embedding tier
    # + hybrid routing (precompute package)
    precompute: Optional[object] = None
    # telemetry: None (default) = metrics off, zero-cost; a
    # TelemetryConfig enables windowed metrics + Prometheus exposition
    # + SLO burn rates + the regression watchdog (obs package)
    telemetry: Optional[object] = None
    # dispatch: None (default) = static mode selection at engine init;
    # a DispatchConfig enables per-batch measured-cost dense/sg dispatch
    # + the bounded variant cache + Pallas block autotune (core.dispatch).
    # Only meaningful with mode="auto" — a forced mode pins the mux.
    dispatch: Optional[object] = None

    def __post_init__(self):
        if self.trace is not None:
            from repro.obs.trace import TraceConfig
            if not isinstance(self.trace, TraceConfig):
                raise TypeError(
                    f"trace must be an obs.TraceConfig or None, got "
                    f"{type(self.trace).__name__}")
        if self.telemetry is not None:
            from repro.obs.metrics import TelemetryConfig
            if not isinstance(self.telemetry, TelemetryConfig):
                raise TypeError(
                    f"telemetry must be an obs.TelemetryConfig or None, "
                    f"got {type(self.telemetry).__name__}")
        if self.precompute is not None:
            from repro.precompute.config import PrecomputeConfig
            if not isinstance(self.precompute, PrecomputeConfig):
                raise TypeError(
                    f"precompute must be a precompute.PrecomputeConfig "
                    f"or None, got {type(self.precompute).__name__}")
        if self.dispatch is not None:
            from repro.core.dispatch import DispatchConfig
            if not isinstance(self.dispatch, DispatchConfig):
                raise TypeError(
                    f"dispatch must be a core.DispatchConfig or None, "
                    f"got {type(self.dispatch).__name__}")
        if not isinstance(self.store, StorePolicy):
            raise TypeError(
                f"store must be a StorePolicy, got "
                f"{type(self.store).__name__}")
        if self.batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if self.num_threads < 1:
            raise ValueError("num_threads must be >= 1")
        if self.depth < 1:
            raise ValueError("depth must be >= 1")
        if self.max_inflight is not None and self.max_inflight < 1:
            raise ValueError("max_inflight must be >= 1 (or None)")
        if self.max_wait_s < 0:
            raise ValueError("max_wait_s must be >= 0")
        if self.transport not in TRANSPORT_MODES:
            raise ValueError(f"transport={self.transport!r}, expected "
                             f"one of {TRANSPORT_MODES}")
        if self.routing not in ROUTING_MODES:
            raise ValueError(f"routing={self.routing!r}, expected one "
                             f"of {ROUTING_MODES}")
        if not isinstance(self.endpoints, tuple):
            object.__setattr__(self, "endpoints", tuple(self.endpoints))
        if self.transport == "socket" and not self.endpoints:
            raise ValueError(
                "transport='socket' needs at least one 'host:port' in "
                "endpoints")
        if self.endpoints and self.transport != "socket":
            raise ValueError(
                f"endpoints are only meaningful with transport='socket' "
                f"(got transport={self.transport!r})")
        if self.rpc_timeout_s <= 0:
            raise ValueError("rpc_timeout_s must be > 0")
        if self.rpc_retries < 0:
            raise ValueError("rpc_retries must be >= 0")
        if self.rpc_concurrency < 1:
            raise ValueError("rpc_concurrency must be >= 1")

    @property
    def remote(self) -> bool:
        """Whether Select/Build run behind a transport."""
        return self.transport != "local"

    @classmethod
    def from_kwargs(cls, base: Optional["ServingConfig"] = None,
                    _warn: bool = True, **kwargs) -> "ServingConfig":
        """Adapter from the legacy per-kwarg engine/server spellings.

        Accepts exactly the field names of ``ServingConfig`` (the legacy
        engine kwargs map 1:1 — see docs/API_MIGRATION.md); unknown
        names raise TypeError listing the valid set, and the removed
        ``dedup_features=`` names its replacement."""
        if "dedup_features" in kwargs:
            raise TypeError(
                "dedup_features= was removed; use ServingConfig(store="
                "StorePolicy(features='packed')) (or the equivalent "
                "store= argument) instead")
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(kwargs) - names)
        if unknown:
            raise TypeError(
                f"unknown serving option(s) {unknown}; valid options "
                f"are the ServingConfig fields: {sorted(names)}")
        if kwargs and kwargs.get("store") is None:
            kwargs.pop("store", None)   # legacy store=None means default
        if _warn and kwargs:
            warnings.warn(
                "per-keyword serving options are deprecated; pass "
                "config=ServingConfig(...) instead "
                "(see docs/API_MIGRATION.md)",
                DeprecationWarning, stacklevel=3)
        if base is not None:
            return dataclasses.replace(base, **kwargs) if kwargs else base
        return cls(**kwargs)

    def describe(self) -> dict:
        d = {"batch_size": self.batch_size, "mode": self.mode,
             "impl": self.impl, "depth": self.depth,
             "num_threads": self.num_threads,
             "transport": self.transport}
        if self.trace is not None:
            d["trace"] = self.trace.describe()
        if self.precompute is not None:
            d["precompute"] = self.precompute.describe()
        if self.telemetry is not None:
            d["telemetry"] = self.telemetry.describe()
        if self.dispatch is not None:
            d["dispatch"] = self.dispatch.describe()
        if self.remote:
            d.update(endpoints=list(self.endpoints) or ["inproc"],
                     rpc_timeout_s=self.rpc_timeout_s,
                     rpc_retries=self.rpc_retries,
                     routing=self.routing)
        return d


__all__ = ["ServingConfig", "TRANSPORT_MODES", "ROUTING_MODES"]
