"""Vertex-induced subgraph construction and fixed-shape padded batches.

The paper ships, per target vertex, the induced subgraph over its N
important neighbors: vertex features [N, f] plus edges. Shapes are FIXED by
the model's receptive-field size N (the decoupling property), which is what
lets the accelerator use static buffers — and here, what lets jit compile
once per (model, N, C) and never again.

Two device layouts are produced (the two ACK execution modes):
  * dense:  adj [C, N, N] float32 — normalized adjacency (+ self loops for
    GCN-style aggregation). TPU-preferred: aggregation runs on the MXU.
  * edges:  (src, dst, w) int32/float32 padded to E_max — the faithful
    scatter-gather layout for the sparse-mode kernel.

The per-target build artifact is ``SubgraphRows`` — every structure array
one target's subgraph contributes to the batch, and the unit the Build
stage caches (store.nbr_cache.SubgraphRowCache): a neighborhood-cache hit
whose rows are also cached skips induced-subgraph construction entirely.
The sg-mode edge extras (``self_w``, ``edge_w_mean``) are computed here
directly from the CSR edge lists — not recovered per batch by densifying
``adj`` — and carried on ``SubgraphBatch``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.core.ini import ini_batch
from repro.graphs.csr import CSRGraph, subgraph_edges


@dataclass(frozen=True)
class SubgraphRows:
    """One target's built subgraph structure, padded to (n_pad, e_pad):
    the Build stage's output (and cache value) — everything
    ``build_subgraph`` produces except features."""
    adj: np.ndarray          # [n, n]  float32, normalized, row=dst
    adj_mean: np.ndarray     # [n, n]  row-stochastic (no self loops)
    mask: np.ndarray         # [n]     float32 (1 = real vertex)
    edge_src: np.ndarray     # [e]     int32 (padded with -> dummy vertex)
    edge_dst: np.ndarray     # [e]     int32
    edge_w: np.ndarray       # [e]     float32 (0 on padding)
    self_w: np.ndarray       # [n]     float32 self-loop weight (adj diag)
    edge_w_mean: np.ndarray  # [e]     float32 row-stochastic edge weight
    n_vertices: int
    n_edges: int
    edges_dropped: int

    def freeze(self) -> "SubgraphRows":
        """Mark every array read-only (cache entries are shared across
        batches — assemble copies them into the batch tensors)."""
        for a in (self.adj, self.adj_mean, self.mask, self.edge_src,
                  self.edge_dst, self.edge_w, self.self_w,
                  self.edge_w_mean):
            a.flags.writeable = False
        return self

    @property
    def nbytes(self) -> int:
        return sum(a.nbytes for a in (
            self.adj, self.adj_mean, self.mask, self.edge_src,
            self.edge_dst, self.edge_w, self.self_w, self.edge_w_mean))


@dataclass(frozen=True)
class SubgraphBatch:
    """Host-side padded batch for C target vertices (all numpy)."""
    feats: np.ndarray        # [C, N, f]  float32
    adj: np.ndarray          # [C, N, N]  float32, normalized, row=dst
    adj_mean: np.ndarray     # [C, N, N]  row-stochastic (no self loops)
    mask: np.ndarray         # [C, N]     float32 (1 = real vertex)
    edge_src: np.ndarray     # [C, E]     int32 (padded with E -> dummy)
    edge_dst: np.ndarray     # [C, E]     int32
    edge_w: np.ndarray       # [C, E]     float32 (0 on padding)
    n_vertices: np.ndarray   # [C]        int32
    n_edges: np.ndarray      # [C]        int32
    targets: np.ndarray      # [C]        int64 global ids
    edges_dropped: int = 0   # edges beyond E budget (sg mode only)
    # sg-mode edge extras, carried from the Build stage (computed from the
    # CSR edge lists — None only for externally constructed batches, where
    # consumers fall back to recovering them from the dense adjacency)
    self_w: Optional[np.ndarray] = None       # [C, N] float32
    edge_w_mean: Optional[np.ndarray] = None  # [C, E] float32

    @property
    def batch_size(self) -> int:
        return self.feats.shape[0]

    @property
    def n(self) -> int:
        return self.feats.shape[1]

    def device_arrays(self, mode: str = "dense") -> Dict[str, np.ndarray]:
        """The arrays actually shipped host->device (PCIe analogue)."""
        if mode == "dense":
            return {"feats": self.feats, "adj": self.adj,
                    "adj_mean": self.adj_mean, "mask": self.mask}
        return {"feats": self.feats, "mask": self.mask,
                "edge_src": self.edge_src, "edge_dst": self.edge_dst,
                "edge_w": self.edge_w}

    def nbytes(self, mode: str = "dense") -> int:
        return sum(a.nbytes for a in self.device_arrays(mode).values())


def build_subgraph_rows(g: CSRGraph, nodes: np.ndarray, n_pad: int,
                        e_pad: Optional[int] = None) -> SubgraphRows:
    """One induced subgraph's structure arrays, padded to n_pad vertices
    (and e_pad edges) — no feature materialization (features are the
    store's concern, and caching built rows must not pin feature blocks).
    """
    k = len(nodes)
    assert k <= n_pad
    src, dst = subgraph_edges(g, nodes)
    # normalized GCN adjacency with self loops: A_hat[d, s] = 1/sqrt(dd*ds)
    deg = np.ones(k, np.float64)                    # self loop counts as 1
    np.add.at(deg, dst, 1.0)
    inv_sqrt = 1.0 / np.sqrt(deg)
    adj = np.zeros((n_pad, n_pad), np.float32)
    adj[dst, src] = (inv_sqrt[dst] * inv_sqrt[src]).astype(np.float32)
    idx = np.arange(k)
    self_w = np.zeros(n_pad, np.float32)
    self_w[:k] = (inv_sqrt * inv_sqrt).astype(np.float32)
    adj[idx, idx] = self_w[:k]
    # row-stochastic mean adjacency (neighbors only; SAGE-style)
    adj_mean = np.zeros((n_pad, n_pad), np.float32)
    indeg = np.zeros(k, np.float64)
    np.add.at(indeg, dst, 1.0)
    nz = indeg[dst] > 0
    adj_mean[dst[nz], src[nz]] = (1.0 / indeg[dst[nz]]).astype(np.float32)
    mask = np.zeros(n_pad, np.float32)
    mask[:k] = 1.0
    e = len(src)
    dropped = 0
    if e_pad is None:
        e_pad = max(1, e)
    if e > e_pad:                                   # cap: count the drop
        dropped = e - e_pad
        src, dst = src[:e_pad], dst[:e_pad]
        e = e_pad
    es = np.full(e_pad, n_pad - 1, np.int32)        # pad points at a padded
    ed = np.full(e_pad, n_pad - 1, np.int32)        # vertex with w=0
    ew = np.zeros(e_pad, np.float32)
    es[:e], ed[:e] = src, dst
    ew[:e] = adj[dst, src]
    # sg-mode mean weights straight from the in-degree counts: float32
    # division of exact integer counts, bitwise what densifying adj_mean
    # and re-counting nonzeros used to produce
    inv_indeg = 1.0 / np.maximum(indeg, 1.0).astype(np.float32)
    ew_mean = np.zeros(e_pad, np.float32)
    ew_mean[:e] = np.where(ew[:e] != 0, inv_indeg[dst], 0.0)
    return SubgraphRows(adj=adj, adj_mean=adj_mean, mask=mask,
                        edge_src=es, edge_dst=ed, edge_w=ew,
                        self_w=self_w, edge_w_mean=ew_mean,
                        n_vertices=k, n_edges=e, edges_dropped=dropped)


def build_subgraph(g: CSRGraph, nodes: np.ndarray, n_pad: int,
                   e_pad: Optional[int] = None, with_feats: bool = True):
    """One induced subgraph, padded to n_pad vertices (and e_pad edges) —
    the one-call back-compat spelling over ``build_subgraph_rows``.

    ``with_feats=False`` skips host-side feature materialization entirely
    (feats comes back [n_pad, 0]) — used when a feature-store strategy
    ships indices instead, so the dense block is never allocated."""
    r = build_subgraph_rows(g, nodes, n_pad, e_pad)
    feats = np.zeros((n_pad, g.feature_dim if with_feats else 0),
                     np.float32)
    if with_feats:
        feats[:len(nodes)] = g.features[nodes]
    return (feats, r.adj, r.adj_mean, r.mask, r.edge_src, r.edge_dst,
            r.edge_w, r.n_vertices, r.n_edges, r.edges_dropped)


def default_edge_pad(g: CSRGraph, n: int) -> int:
    """Fixed E budget per subgraph. PPR-selected neighborhoods are *dense*
    (hubs select hubs), so the budget is 4x N*avg_degree, capped at the
    complete graph. Overflow is counted per batch (``edges_dropped``) and
    only affects sg mode — dense mode always carries every edge."""
    e = int(4 * n * max(4.0, float(g.degrees.mean())))
    e = min(e, n * (n - 1))
    return max(128, e + (-e) % 128)


def packed_features(node_lists: List[np.ndarray], g: CSRGraph, n: int):
    """Cross-target feature dedup (beyond-paper): PPR favors hubs, so the
    same vertices recur across a batch's subgraphs. Ship each unique row
    ONCE (uniq [U, f]) plus an int32 index map [C, n]; the device
    reconstructs feats = uniq[idx]. Returns (uniq, idx, ratio) where ratio
    = packed bytes / dense bytes (< 1 means savings on the host->device
    link — the paper's t_load, Eq. 2)."""
    C = len(node_lists)
    idx = np.zeros((C, n), np.int32)
    all_ids = np.concatenate([nl[:n] for nl in node_lists])
    uniq_ids, inv = np.unique(all_ids, return_inverse=True)
    # row 0 of uniq is a zero pad row for masked slots
    uniq = np.zeros((len(uniq_ids) + 1, g.feature_dim), np.float32)
    uniq[1:] = g.features[uniq_ids]
    o = 0
    for i, nl in enumerate(node_lists):
        k = min(len(nl), n)
        idx[i, :k] = inv[o:o + k] + 1
        o += k
    dense_bytes = C * n * g.feature_dim * 4
    packed_bytes = uniq.nbytes + idx.nbytes
    return uniq, idx, packed_bytes / dense_bytes


def build_batch(g: CSRGraph, targets, n: int, e_pad: Optional[int] = None,
                num_threads: int = 8, alpha: float = 0.15,
                eps: float = 1e-4) -> SubgraphBatch:
    """INI + induced-subgraph build for a batch of targets (host side)."""
    e_pad = e_pad or default_edge_pad(g, n)
    node_lists = ini_batch(g, targets, n, alpha, eps, num_threads)
    return batch_from_node_lists(g, targets, node_lists, n, e_pad)


def assemble_batch(g: CSRGraph, targets, node_lists: List[np.ndarray],
                   rows: List[SubgraphRows], n: int, e_pad: int,
                   build_feats: bool = True) -> SubgraphBatch:
    """Pack per-target built rows into one fixed-shape SubgraphBatch
    (the Pack stage's structure half; features are materialized here only
    for strategies that ship the dense block)."""
    C = len(rows)
    f = g.feature_dim if build_feats else 0   # [C, n, 0]: shape carriers
    feats = np.zeros((C, n, f), np.float32)   # (n, batch_size) stay valid
    adj = np.zeros((C, n, n), np.float32)
    adj_mean = np.zeros((C, n, n), np.float32)
    mask = np.zeros((C, n), np.float32)
    es = np.zeros((C, e_pad), np.int32)
    ed = np.zeros((C, e_pad), np.int32)
    ew = np.zeros((C, e_pad), np.float32)
    self_w = np.zeros((C, n), np.float32)
    ew_mean = np.zeros((C, e_pad), np.float32)
    nv = np.zeros(C, np.int32)
    ne = np.zeros(C, np.int32)
    dropped = 0
    for i, r in enumerate(rows):
        adj[i], adj_mean[i], mask[i] = r.adj, r.adj_mean, r.mask
        es[i], ed[i], ew[i] = r.edge_src, r.edge_dst, r.edge_w
        self_w[i], ew_mean[i] = r.self_w, r.edge_w_mean
        nv[i], ne[i] = r.n_vertices, r.n_edges
        dropped += r.edges_dropped
        if build_feats:
            nodes = node_lists[i][:n]
            feats[i, :len(nodes)] = g.features[nodes]
    return SubgraphBatch(feats=feats, adj=adj, adj_mean=adj_mean, mask=mask,
                         edge_src=es, edge_dst=ed, edge_w=ew,
                         n_vertices=nv, n_edges=ne,
                         targets=np.asarray(targets, np.int64),
                         edges_dropped=dropped,
                         self_w=self_w, edge_w_mean=ew_mean)


def batch_from_node_lists(g: CSRGraph, targets, node_lists: List[np.ndarray],
                          n: int, e_pad: int,
                          build_feats: bool = True) -> SubgraphBatch:
    rows = [build_subgraph_rows(g, nodes[:n], n, e_pad)
            for nodes in node_lists]
    return assemble_batch(g, targets, node_lists, rows, n, e_pad,
                          build_feats=build_feats)
