"""The one versioned key schema behind every reporting surface.

Three surfaces grew three ad-hoc flat dicts — ``SchedulerStats.summary``,
``engine.store_report`` and ``GNNServer.report`` — and the RPC counters
would have made a fourth. This module pins ONE nested namespace that all
of them emit, stamped with ``SCHEMA_VERSION`` so downstream dashboards
can detect drift:

  latency.*   wall-clock: t_wall / t_host / t_device / t_init (paper
              Eq. 2 terms) and, per served model, the request
              percentiles p50/p90/p99/mean/batch_mean/n
  stages.*    host BatchPlan pipeline: per-stage wall totals ("times",
              the software Fig. 3 breakdown), achieved overlap
              fraction, batch count, Build-stage row-cache hit rate
  store.*     transfer + cache accounting (paper t_load / t_pre):
              bytes_shipped / bytes_dense / transfer_ratio /
              cache_hit_rate / dedup_ratio, plus the engine's store
              subsystem state (policy / features / nbr_cache /
              subgraph_cache / auto_repins)
  shards.*    sharded feature store only: per-shard link bytes +
              max/mean balance
  rpc.*       multi-host transport only: calls / bytes_out / bytes_in /
              retries / timeouts / errors and the wall vs remote vs
              wire time split of the remote stage
  trace.*     observability (ServingConfig(trace=...)): tracing config +
              span/ticket counters, per-span-name latency histograms,
              the flight recorder's slowest-batch summary, per-endpoint
              clock-sync estimates, and the per-op calibration table
  precompute.* offline embedding tier (ServingConfig(precompute=...)):
              residency / freshness / generation, tier hit + demotion +
              promotion counters, refresh backlog and chunk counts, and
              the tier's resident bytes
  telemetry.* live telemetry plane (ServingConfig(telemetry=...)):
              windowed metrics snapshot (counters / gauges / histogram
              quantiles over the sliding window), SLO burn-rate rows,
              watchdog state, and the structured event ring summary
  dispatch.*  per-batch adaptive dispatch (ServingConfig(dispatch=...)):
              policy identity + decision/source counters + warmup
              schedule state, the compiled-variant cache's bounded
              size / hit / eviction counters, the resolved Pallas
              block overrides, and the calibration table's cell count

Section builders take a ``SchedulerStats``-shaped object (duck-typed to
avoid an import cycle with core.scheduler) and return plain dicts;
absent subsystems return None and the section is omitted, never
half-filled.

Version history:
  1  initial five-section namespace (latency/stages/store/shards/rpc)
  2  observability: new optional ``trace`` section (emitted only on
     traced deployments), and ``latency.hist`` — the serialized
     log-bucketed request-latency histogram (obs.hist.LogHistogram
     .to_dict()) whose p50/p90/p99 now come from fixed-memory buckets
     instead of unbounded raw lists. Existing keys are unchanged, so
     v1 consumers keep working; the bump flags the additive keys.
  3  hybrid precompute serving: new optional ``precompute`` section
     (emitted only on deployments with an embedding tier). Existing
     keys unchanged — additive, like the v2 bump.
  4  live telemetry plane: new optional ``telemetry`` section (emitted
     only on deployments with ServingConfig(telemetry=...)) carrying
     the windowed metrics snapshot, SLO burn rates, watchdog summary,
     and event ring. Existing keys unchanged — additive again.
  5  per-batch adaptive dispatch: new optional ``dispatch`` section
     (emitted only on deployments with ServingConfig(dispatch=...)),
     and ``stages.batch_edges`` — the mean measured induced-subgraph
     edge count the Build stage reported (0.0 on pre-dispatch
     deployments and tier-only batches). Additive, like v2-v4.
"""
from __future__ import annotations

from typing import Optional

SCHEMA_VERSION = 5

# documented key map (stable contract; bump SCHEMA_VERSION on change)
SCHEMA = {
    "latency": ("t_wall", "t_host", "t_device", "t_init",
                "p50", "p90", "p99", "mean", "batch_mean", "n", "hist"),
    "stages": ("times", "overlap", "batches", "build_hit_rate",
               "batch_edges"),
    "store": ("bytes_shipped", "bytes_dense", "transfer_ratio",
              "cache_hit_rate", "dedup_ratio", "policy", "features",
              "nbr_cache", "subgraph_cache", "auto_repins",
              "graph_hosts"),
    "shards": ("bytes", "balance"),
    "rpc": ("calls", "bytes_out", "bytes_in", "retries", "timeouts",
            "errors", "wall_s", "remote_s", "wire_s"),
    "trace": ("enabled", "sample_every", "ring_capacity", "flight_k",
              "calibrate_every", "tickets_traced", "spans",
              "spans_dropped", "remote_spans", "host", "hists",
              "flight", "clock_sync", "calibration"),
    "precompute": ("enabled", "resident", "fresh", "hits", "misses",
                   "hit_rate", "demotions", "promotions",
                   "refresh_chunks", "refresh_backlog",
                   "refresh_errors", "tier_bytes", "generation",
                   "builds"),
    "telemetry": ("enabled", "host", "window_s", "windows", "series",
                  "counters", "gauges", "hists", "slo", "watchdog",
                  "evaluations", "events"),
    "dispatch": ("enabled", "policy", "impl", "mux_sites", "decisions",
                 "sources", "warmup", "variants", "blocks",
                 "table_cells", "table_passes", "artifact"),
}


def stages_section(stats) -> dict:
    return {"times": {k: round(v, 6)
                      for k, v in stats.stage_times.items()},
            "overlap": round(stats.overlap_fraction, 3),
            "batches": stats.n_batches,
            "build_hit_rate": round(stats.build_hit_rate, 4),
            "batch_edges": round(stats.batch_edges, 2)}


def store_section(stats) -> dict:
    """The scheduler-side transfer counters of ``store.*`` (the engine
    merges its store-subsystem state into the same namespace)."""
    return {"bytes_shipped": stats.bytes_shipped,
            "bytes_dense": stats.bytes_dense,
            "transfer_ratio": round(stats.transfer_ratio, 4),
            "cache_hit_rate": round(stats.cache_hit_rate, 4),
            "dedup_ratio": stats.last_dedup_ratio}


def shards_section(stats) -> Optional[dict]:
    if not stats.shard_bytes:
        return None
    return {"bytes": list(stats.shard_bytes),
            "balance": round(stats.shard_balance, 4)}


def rpc_section(stats) -> Optional[dict]:
    if not stats.rpc_calls:
        return None
    return {"calls": stats.rpc_calls,
            "bytes_out": stats.rpc_bytes_out,
            "bytes_in": stats.rpc_bytes_in,
            "retries": stats.rpc_retries,
            "timeouts": stats.rpc_timeouts,
            "errors": stats.rpc_errors,
            "wall_s": round(stats.t_rpc_wall, 6),
            "remote_s": round(stats.t_rpc_remote, 6),
            "wire_s": round(stats.t_rpc_wire, 6)}


def trace_section(tracer, calibration=None) -> Optional[dict]:
    """The ``trace.*`` section of a traced deployment (None when tracing
    is off — the section is omitted, keeping v1 consumers byte-stable)."""
    if tracer is None:
        return None
    d = tracer.report()
    if calibration is not None and len(calibration):
        d["calibration"] = calibration.to_dict()
    return d


def precompute_section(manager) -> dict:
    """The ``precompute.*`` section of a tiered deployment;
    ``{"enabled": False}`` when the deployment has no embedding tier."""
    if manager is None:
        return {"enabled": False}
    return manager.report()


def telemetry_section(telemetry) -> Optional[dict]:
    """The ``telemetry.*`` section of a metered deployment (None when
    telemetry is off — the section is omitted, like ``trace``)."""
    if telemetry is None:
        return None
    return telemetry.report()


def dispatch_section(engine) -> Optional[dict]:
    """The ``dispatch.*`` section of an adaptively-dispatched deployment
    (None when ServingConfig(dispatch=...) is unset — omitted, like
    ``trace``). Duck-typed on the engine's ``dispatch_report``."""
    rep = getattr(engine, "dispatch_report", None)
    if rep is None:
        return None
    return rep()


def scheduler_summary(stats) -> dict:
    """The full nested summary a ``SchedulerStats`` emits."""
    d = {"schema_version": SCHEMA_VERSION,
         "latency": {"t_wall": stats.t_wall,
                     "t_host": stats.t_host_total,
                     "t_device": stats.t_device_total,
                     "t_init": stats.t_initialization},
         "stages": stages_section(stats),
         "store": store_section(stats)}
    shards = shards_section(stats)
    if shards is not None:
        d["shards"] = shards
    rpc = rpc_section(stats)
    if rpc is not None:
        d["rpc"] = rpc
    return d


__all__ = ["SCHEMA_VERSION", "SCHEMA", "scheduler_summary",
           "stages_section", "store_section", "shards_section",
           "rpc_section", "trace_section", "precompute_section",
           "telemetry_section", "dispatch_section"]
