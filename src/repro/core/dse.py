"""Design Space Exploration (paper §4.5) adapted to TPU.

The paper's DSE picks, from a DSP budget, (1) N_ALU per ALU, (2) the ACK
array size p_sys, (3) the PE count N_pe — one bitstream for a SET of GNN
models. The TPU analogue picks, from the device spec, the kernel tiling and
batching for ONE compiled kernel family serving every model in the set:

  Step 1 (N_ALU): verify the ALU op set — every aggregate()/update()/
          attention op of every model must map to MXU/VPU primitives.
  Step 2 (p_sys): maximize the fused-kernel feature block BF (multiple of
          the 128-lane MXU width) subject to the worst-case VMEM working
          set over all models, double-buffered.
  Step 3 (N_pe): choose the per-core subgraph tile C_core from the modeled
          per-target latency so a batch of C saturates the chip; across
          chips targets are data-parallel (mesh 'data'/'pod' axes).

Outputs one ``DSEPlan``; ``modeled_utilization`` reports the roofline-style
compute fraction per model under that single plan (Eq. 1's load-balance
argument: ACK gives every kernel the whole chip).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence

from repro.core.program import lower, program_alu_ops
from repro.gnn.model import GNNConfig

MXU_LANE = 128

# scalar primitives the TPU's MXU (matmul) + VPU (elementwise) cover —
# the "N_ALU" feasibility vocabulary. The per-model REQUIRED set is no
# longer a hand-kept table: it is derived from the model's lowered
# AckProgram (core.program.program_alu_ops), so a kind registered at
# runtime is admissible with no DSE edit.
TPU_OPS = {"matmul", "add", "relu", "mul", "exp", "max", "leaky_relu",
           "min", "sub", "div"}


@dataclass(frozen=True)
class TPUSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16
    hbm_bw: float = 819e9               # bytes/s
    vmem_bytes: int = 16 * 2 ** 20      # per-core VMEM budget for the plan
    hbm_bytes: int = 16 * 2 ** 30
    ici_bw: float = 50e9                # per link
    mxu: int = MXU_LANE


@dataclass
class DSEPlan:
    block_f: int                        # p_sys analogue (MXU tile width)
    c_core: int                         # N_pe analogue (subgraphs/core)
    edge_block: int
    buffer_depth: int                   # double/triple buffering depth
    vmem_used: int
    ops_ok: bool
    per_model: Dict[str, dict] = field(default_factory=dict)


class PlanViolation(ValueError):
    """A model does not fit under the shared DSEPlan."""


def plan_covers(plan: DSEPlan, cfg: GNNConfig,
                spec: TPUSpec = TPUSpec()) -> List[str]:
    """Why ``cfg`` does NOT run under ``plan`` (empty list = covered).

    This is the serving-time admission check: a multi-model deployment
    keeps ONE plan (paper: one bitstream) and every registered model must
    (a) use only ops the plan's ALU set supports and (b) fit the plan's
    buffered VMEM working set at its own receptive field / feature dims.
    """
    reasons: List[str] = []
    try:
        ops = program_alu_ops(cfg)
    except KeyError as e:                 # no registered lowering: the
        reasons.append(str(e).strip('"'))  # message names the fix
    else:
        if not ops <= TPU_OPS:
            reasons.append(f"ops {sorted(ops - TPU_OPS)} unsupported")
    f = max(cfg.f_in, cfg.f_hidden)
    f_pad = f + (-f) % MXU_LANE
    vm = _vmem_layer(cfg.receptive_field, f_pad, plan.block_f,
                     plan.buffer_depth)
    if vm > spec.vmem_bytes:
        reasons.append(
            f"VMEM working set {vm} > budget {spec.vmem_bytes} "
            f"(N={cfg.receptive_field}, f_pad={f_pad}, BF={plan.block_f})")
    return reasons


def validate_models(plan: DSEPlan, models: Sequence[GNNConfig],
                    spec: TPUSpec = TPUSpec()) -> None:
    """Raise PlanViolation unless every model runs under the one plan."""
    if not plan.ops_ok:
        raise PlanViolation("plan was built over an unsupported op set")
    bad = {m.display: plan_covers(plan, m, spec) for m in models}
    bad = {k: v for k, v in bad.items() if v}
    if bad:
        raise PlanViolation(f"models outside the shared plan: {bad}")


def _vmem_layer(n: int, f_in: int, bf: int, depth: int = 2) -> int:
    """Working set of one fused-kernel grid step (fp32 bytes), times the
    pipeline buffering depth for the streamed operands."""
    a = n * n * 4
    h = n * f_in * 4
    w = f_in * bf * 4 * 2          # w_neigh + w_self
    acc = n * bf * 4 * 2           # accumulator + out block
    return depth * (a + h + w) + acc


def layer_costs(cfg: GNNConfig, n: int, f_in: int, f_out: int,
                spec: TPUSpec, *, section: str = "auto") -> dict:
    """Per-layer dense-mode compute/memory model for one subgraph, summed
    over the ops of the model's lowered layer template (per-op FLOP
    models live with the ops in core.program). The feature width is
    tracked through the op stream the same way specialize() does: each
    Transform re-widens to f_out, so later ops (a second GIN MLP, gat's
    attention) are costed at the width they actually see. ``section``
    picks the template explicitly ("layer0" | "inner"); "auto" infers it
    from the widths (layer0 iff f_in != f_out)."""
    from repro.core.program import Transform
    prog = lower(cfg)
    if section == "auto":
        section = "layer0" if f_in != f_out or cfg.n_layers == 1 \
            else "inner"
    ops_seq = prog.layer0 if section == "layer0" else prog.inner
    flops, f_cur = 0.0, f_in
    for op in ops_seq:
        flops += op.dense_flops(n, f_cur, f_out)
        if isinstance(op, Transform):
            f_cur = f_out
    # HBM traffic: H in/out + A once; weights amortized over C subgraphs
    bytes_hbm = 4.0 * (n * f_in + n * f_out + n * n)
    return {"flops": flops, "bytes": bytes_hbm,
            "t_compute": flops / spec.peak_flops,
            "t_memory": bytes_hbm / spec.hbm_bw}


def explore(models: Sequence[GNNConfig], spec: TPUSpec = TPUSpec(),
            buffer_depth: int = 2) -> DSEPlan:
    # Step 1 — op coverage, from each model's lowered instruction stream
    ops_ok = all(program_alu_ops(m) <= TPU_OPS for m in models)
    n_max = max(m.receptive_field for m in models)
    f_max = max(max(m.f_in, m.f_hidden) for m in models)
    f_pad = f_max + (-f_max) % MXU_LANE

    # Step 2 — maximize BF (power-of-two multiple of 128, paper: p_sys=2^k)
    bf = MXU_LANE
    while (_vmem_layer(n_max, f_pad, bf * 2, buffer_depth)
           <= spec.vmem_bytes and bf * 2 <= f_pad):
        bf *= 2

    # Step 3 — per-core subgraph tile: enough grid steps to amortize weight
    # streaming; modeled so device time per batch >= 2x weight-load time.
    per_model = {}
    c_core = 8
    for m in models:
        n = m.receptive_field
        costs = [layer_costs(m, n, m.f_in, m.f_hidden, spec,
                             section="layer0")] + \
            [layer_costs(m, n, m.f_hidden, m.f_hidden, spec,
                         section="inner")] * (m.n_layers - 1)
        t_comp = sum(c["t_compute"] for c in costs)
        t_mem = sum(c["t_memory"] for c in costs)
        w_bytes = 4.0 * (m.f_in * m.f_hidden
                         + (m.n_layers - 1) * m.f_hidden * m.f_hidden)
        t_weights = w_bytes / spec.hbm_bw
        # subgraphs per core so that compute hides one full weight sweep
        need = max(1, int(2 * t_weights / max(t_comp, 1e-12)))
        c_core = max(c_core, min(256, need))
        util = t_comp / max(t_comp, t_mem + t_weights / max(need, 1))
        per_model[m.display] = {
            "t_compute_per_target": t_comp, "t_memory_per_target": t_mem,
            "modeled_util": round(util, 3),
            "bound": "compute" if t_comp >= t_mem else "memory",
        }
    vm = _vmem_layer(n_max, f_pad, bf, buffer_depth)
    return DSEPlan(block_f=bf, c_core=c_core, edge_block=256,
                   buffer_depth=buffer_depth, vmem_used=vm, ops_ok=ops_ok,
                   per_model=per_model)
