"""AckProgram IR — every GNN compiles to a typed ACK instruction stream.

The paper's ACK is ONE datapath whose mux is set *per computation kernel*:
systolic mode for dense transforms, scatter-gather mode for sparse
aggregation, switched in one cycle between kernels (§4.2). GraphAGILE
(arXiv:2302.01769) generalizes the shape — a compiler lowers any GNN into
an instruction sequence executed by one overlay — and Dynasparse
(arXiv:2303.12901) makes the dense/sparse choice per kernel from its own
arithmetic intensity. This module is that compiler stack for the TPU
substrate:

  ``lower(cfg)``        GNNConfig -> AckProgram, via a model *registry*
                        (``@register_lowering("gat")``). Adding a GNN
                        variant is one registered lowering, not an edit to
                        engine/model/dse kind-chains.
  ``specialize(prog)``  sets the per-op mode mux: every ``Aggregate`` /
                        ``AttentionSoftmax`` gets its own dense/sg decision
                        from that kernel's FLOP model (core.ack.choose_mode)
                        while ``Transform`` is always systolic — so one
                        compiled program can mix sg aggregation with dense
                        transforms (the paper's one-cycle mode switch,
                        recovered at trace time).
  ``execute(prog)``     one executor runs any specialized program through
                        the existing XLA and Pallas kernels. Under
                        ``impl="pallas"`` a dense Aggregate[+Residual]
                        +Transform group is peephole-fused into ONE
                        ``kernels.ops.fused_gnn_layer`` call (A @ (H @ W)
                        never leaves VMEM); sg Aggregates run the Pallas
                        scatter-gather kernel; everything else falls back
                        to the jnp reference ops.

The op vocabulary (the "instruction set") is deliberately small — it is the
paper's kernel taxonomy: Aggregate (FA), Transform (FT), AttentionScore +
AttentionSoftmax (Attention), Residual, Readout, Classify.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.ack import choose_mode
from repro.gnn.layers import NEG_INF, _ft, agg_dense, agg_sg, readout

ACTS = {"none": lambda x: x, "relu": jax.nn.relu, "elu": jax.nn.elu}

# scalar ALU primitives each activation decomposes into (the DSE "N_ALU"
# feasibility vocabulary — see core.dse.TPU_OPS)
_ACT_ALU = {"none": frozenset(), "relu": frozenset({"relu"}),
            "elu": frozenset({"exp", "sub", "max"})}


# ---------------------------------------------------------------------------
# the instruction set


@dataclass(frozen=True)
class AckOp:
    """Base ACK instruction. ``mux`` marks ops with a dense/sg datapath
    choice; everything else executes in exactly one mode."""

    @property
    def mux(self) -> bool:
        return False

    @property
    def alu(self) -> frozenset:
        return frozenset()

    def dense_flops(self, n: int, f_in: int, f_out: int) -> float:
        return 0.0

    def describe(self) -> str:
        return type(self).__name__


@dataclass(frozen=True)
class Aggregate(AckOp):
    """Feature Aggregation kernel: z = A_norm @ h (dense/systolic) or an
    edge-list scatter-gather (sg). ``norm`` picks the adjacency:
    ``gcn`` (sym-normalized + self loops), ``mean`` (row-stochastic),
    ``binary`` (0/1 structure)."""
    norm: str = "gcn"
    src: str = "h"
    out: str = "z"
    mode: Optional[str] = None          # dense | sg | None = unspecialized

    @property
    def mux(self) -> bool:
        return True

    @property
    def alu(self) -> frozenset:
        return frozenset({"matmul", "add", "mul"})

    def dense_flops(self, n, f_in, f_out):
        return 2.0 * n * n * f_in

    def describe(self) -> str:
        return f"Aggregate[{self.norm}]"


@dataclass(frozen=True)
class Residual(AckOp):
    """into = (1 + p[eps_param]) * src + into_gain * into  (GIN's
    (1+eps)-weighted self term at the default into_gain=1; plain residual
    when ``eps_param`` is None). ``src`` may name the ``h0`` register —
    the propagation ENTRY state (the layer-0 prediction inside the inner
    scan), which is what APPNP's teleport term reads. ``into_gain`` is a
    compile-time constant (e.g. 1 - alpha), not a parameter."""
    src: str = "h_in"
    into: str = "z"
    eps_param: Optional[str] = None
    into_gain: float = 1.0

    @property
    def alu(self) -> frozenset:
        return frozenset({"add", "mul"})

    def dense_flops(self, n, f_in, f_out):
        return 2.0 * n * f_in


@dataclass(frozen=True)
class Transform(AckOp):
    """Feature Transformation kernel: out = act(src @ p[w] [+ h_in @
    p[w_self]] + p[b]). ALWAYS systolic — a dense matmul is the one case
    the paper never runs through the scatter-gather pipelines."""
    w: str = "w"
    b: Optional[str] = None
    act: str = "relu"                   # none | relu | elu
    src: str = "z"
    out: str = "h"
    w_self: Optional[str] = None        # applied to the layer input
    masked: bool = True
    mode: str = "dense"                 # fixed: systolic

    @property
    def alu(self) -> frozenset:
        return frozenset({"matmul", "add"}) | _ACT_ALU[self.act]

    def dense_flops(self, n, f_in, f_out):
        per = 2.0 * n * f_in * f_out
        return per * (2.0 if self.w_self else 1.0)

    def describe(self) -> str:
        return f"Transform[{self.w}]"


@dataclass(frozen=True)
class AttentionScore(AckOp):
    """Per-vertex attention score terms s_src/s_dst = <z_head, a_*> (GAT).
    Tiny per-head reductions — VPU work, no mode mux."""
    a_src: str = "a_src"
    a_dst: str = "a_dst"
    src: str = "z"
    n_heads: int = 1

    @property
    def alu(self) -> frozenset:
        return frozenset({"matmul", "add", "mul"})

    def dense_flops(self, n, f_in, f_out):
        return 4.0 * n * f_out


@dataclass(frozen=True)
class AttentionSoftmax(AckOp):
    """Edge-score LeakyReLU + masked softmax over incoming edges + weighted
    aggregation of z (the paper's Attention kernel). Dense mode builds the
    full [N, N] score matrix (MXU-friendly at decoupled N); sg mode is
    edge-parallel segment-max/sum."""
    b: Optional[str] = "b"
    act: str = "elu"
    negative_slope: float = 0.2
    src: str = "z"
    out: str = "h"
    n_heads: int = 1
    mode: Optional[str] = None

    @property
    def mux(self) -> bool:
        return True

    @property
    def alu(self) -> frozenset:
        return (frozenset({"leaky_relu", "exp", "max", "add", "mul", "div"})
                | _ACT_ALU[self.act])

    def dense_flops(self, n, f_in, f_out):
        return 2.0 * n * n * f_out + 8.0 * n * n * self.n_heads

    def describe(self) -> str:
        return f"AttentionSoftmax[h{self.n_heads}]"


@dataclass(frozen=True)
class Readout(AckOp):
    """Receptive-field readout (paper: elementwise Max over the subgraph)."""
    kind: str = "max"

    @property
    def alu(self) -> frozenset:
        return {"max": frozenset({"max"}),
                "mean": frozenset({"add", "mul", "div"}),
                "target": frozenset()}[self.kind]

    def describe(self) -> str:
        return f"Readout[{self.kind}]"


@dataclass(frozen=True)
class Classify(AckOp):
    """Final linear classifier over the readout embedding."""
    w: str = "cls_w"
    b: str = "cls_b"

    @property
    def alu(self) -> frozenset:
        return frozenset({"matmul", "add"})


@dataclass(frozen=True)
class AckProgram:
    """A compiled GNN: the layer-0 op stream (f_in -> f_hidden), the inner
    op stream (executed L-1 times under one ``lax.scan`` over stacked
    weights — bounded HLO at L=16), and the tail (Readout [+ Classify])."""
    kind: str
    layer0: Tuple[AckOp, ...]
    inner: Tuple[AckOp, ...]
    tail: Tuple[AckOp, ...]
    n_layers: int

    def layer_sections(self):
        yield "layer0", self.layer0
        if self.n_layers > 1:
            yield "inner", self.inner

    @property
    def ops(self) -> Tuple[Tuple[str, AckOp], ...]:
        """Every EXECUTED op with its site label — the inner section is
        excluded for 1-layer programs (execute() never runs it), so
        decisions, required_adjacency, and the ALU set all describe the
        datapath that actually runs."""
        out = []
        for sec, seq in (*self.layer_sections(), ("tail", self.tail)):
            out += [(f"{sec}[{i}]", op) for i, op in enumerate(seq)]
        return tuple(out)

    @property
    def specialized(self) -> bool:
        return all(op.mode is not None for _, op in self.ops
                   if op.mux)


# ---------------------------------------------------------------------------
# model registry: kind -> (lowering, per-layer param init)


@dataclass
class ModelLowering:
    kind: str
    lower: Callable
    layer_init: Callable        # (cfg, key, f_in, f_out) -> param dict


_REGISTRY: Dict[str, ModelLowering] = {}
_BUILTINS_LOADED = False


def register_lowering(kind: str, *, layer_init: Callable):
    """Decorator: register ``fn(cfg) -> AckProgram`` as the lowering for
    model kind ``kind``, together with the per-layer parameter initializer
    ``layer_init(cfg, key, f_in, f_out)``. Registering a kind makes it
    servable everywhere — engine, DSE admission, GNNServer — with no other
    code change."""
    def deco(fn):
        _REGISTRY[kind] = ModelLowering(kind, fn, layer_init)
        lower.cache_clear()     # re-registration must not serve a stale
        return fn               # cached program for this kind
    return deco


def _ensure_builtins():
    global _BUILTINS_LOADED
    if not _BUILTINS_LOADED:
        import repro.gnn.lowering   # noqa: F401 — registers gcn/sage/gin/gat
        _BUILTINS_LOADED = True


def lowering_for(kind: str) -> ModelLowering:
    _ensure_builtins()
    try:
        return _REGISTRY[kind]
    except KeyError:
        raise KeyError(
            f"no registered lowering for model kind {kind!r}; registered "
            f"kinds: {registered_kinds()}. Add one with "
            f"@register_lowering({kind!r}, layer_init=...) — see "
            f"repro/gnn/lowering.py for the builtin lowerings.") from None


def registered_kinds() -> Tuple[str, ...]:
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


def layer_init_for(kind: str) -> Callable:
    return lowering_for(kind).layer_init


@functools.lru_cache(maxsize=256)
def lower(cfg) -> AckProgram:
    """Compile ``cfg`` (a frozen GNNConfig) into its unspecialized
    AckProgram via the registry."""
    prog = lowering_for(cfg.kind).lower(cfg)
    if not any(isinstance(op, Readout) for op in prog.tail):
        raise ValueError(f"lowering for {cfg.kind!r} emitted no Readout")
    for sec, seq in prog.layer_sections():
        if not any(getattr(op, "out", None) == "h" for op in seq):
            # a layer that never writes the "h" register would silently
            # become the identity (execute returns regs["h"], pre-seeded
            # with the layer input) — a one-token out= mistake in a
            # custom lowering must fail loudly, not serve wrong numbers
            raise ValueError(
                f"lowering for {cfg.kind!r}: {sec} ops never write the "
                f"'h' register — the layer would be an identity. Set "
                f"out='h' on the final op.")
    return prog


def program_alu_ops(cfg) -> frozenset:
    """Union of scalar ALU primitives the lowered program requires — the
    DSE Step-1 ("N_ALU") feasibility set, derived from the instruction
    stream instead of a hand-kept table."""
    return frozenset().union(*(op.alu for _, op in lower(cfg).ops))


def input_width_params(prog: AckProgram) -> Tuple[str, ...]:
    """Names of layer0 weight params whose ROWS are sized by the layer
    input width f_in — the ones the engine must row-pad when it pads
    features for MXU alignment. Derived by tracking which registers still
    carry the input width through the op stream (Aggregate preserves its
    source's width; Transform re-widens its output to f_out)."""
    at_input = {"h", "h_in", "h0"}     # h0 == the layer input in layer0
    keys = []
    for op in prog.layer0:
        if isinstance(op, Aggregate):
            if op.src in at_input:
                at_input.add(op.out)
            else:
                at_input.discard(op.out)
        elif isinstance(op, Residual):
            if op.src not in at_input:
                at_input.discard(op.into)
        elif isinstance(op, Transform):
            if op.src in at_input:
                keys.append(op.w)
            if op.w_self:               # always reads h_in
                keys.append(op.w_self)
            at_input.discard(op.out)
        elif isinstance(op, AttentionSoftmax):
            at_input.discard(op.out)
    return tuple(dict.fromkeys(keys))


def required_adjacency(prog: AckProgram) -> Tuple[str, ...]:
    """Which dense [C,N,N] adjacency arrays the program reads — lets
    serving ship only what the compiled datapath touches. Ops already
    specialized to sg mode don't count (their data is the edge list);
    unspecialized ops count conservatively."""
    keys = set()
    for _, op in prog.ops:
        if getattr(op, "mode", None) == "sg":
            continue
        if isinstance(op, Aggregate):
            keys.add("adj" if op.norm == "gcn" else "adj_mean")
        elif isinstance(op, AttentionSoftmax):
            keys.add("adj_mean")            # structural mask source
    return tuple(sorted(keys))


# ---------------------------------------------------------------------------
# specialization: the per-op mode mux


@dataclass(frozen=True)
class OpDecision:
    site: str                   # e.g. "layer0[0]"
    op: str                     # e.g. "Aggregate[gcn]"
    mode: str                   # dense | sg
    mux: bool                   # had a real dense/sg choice
    dense_flops: float
    sg_flops: float
    reason: str


@dataclass(frozen=True)
class ProgramDecision:
    """Per-op mode decisions for one specialized program (the
    ``InferenceResult.decision`` payload): a sequence of OpDecisions plus
    summary views. Back-compat: ``.mode`` and ``.reason`` keep the old
    single-decision spelling."""
    kind: str
    ops: Tuple[OpDecision, ...]

    def __iter__(self):
        return iter(self.ops)

    def __len__(self):
        return len(self.ops)

    def __getitem__(self, i):
        return self.ops[i]

    @property
    def mode(self) -> str:
        """Aggregate view over the MUX'D ops: dense | sg | mixed."""
        muxed = {d.mode for d in self.ops if d.mux}
        if not muxed or muxed == {"dense"}:
            return "dense"
        if muxed == {"sg"}:
            return "sg"
        return "mixed"

    @property
    def modes(self) -> Tuple[str, ...]:
        return tuple(sorted({d.mode for d in self.ops}))

    @property
    def n_dense(self) -> int:
        return sum(d.mode == "dense" for d in self.ops)

    @property
    def n_sg(self) -> int:
        return sum(d.mode == "sg" for d in self.ops)

    @property
    def summary(self) -> str:
        return (f"{self.kind}: {len(self.ops)} ops, "
                f"{self.n_dense} dense + {self.n_sg} sg ({self.mode})")

    @property
    def reason(self) -> str:
        for d in self.ops:
            if d.mux:
                return d.reason
        return "no mux'd ops"


ForceSpec = Union[None, str, Dict[str, str]]

# Pallas kernel block-size overrides threaded through the executor:
# {"block_f": int|None, "block_e": int|None}. None / missing keys keep
# the kernels' defaults, so blocks=None is exactly the pre-autotune path.
BlockSpec = Optional[Dict[str, Optional[int]]]


def mux_sites(prog: AckProgram) -> Tuple[str, ...]:
    """Site labels of every EXECUTED op with a dense/sg mux — the keys a
    per-batch mode assignment must cover (tier/tail ops never mux)."""
    return tuple(site for site, op in prog.ops if op.mux)


def respecialize(prog: AckProgram, modes: Dict[str, str]) -> AckProgram:
    """Cheap per-batch re-specialization: return ``prog`` with the mux
    mode of each listed site replaced (``{"layer0[0]": "sg", ...}``).
    Sites not listed keep their existing mode, so re-specializing an
    already-specialized program always yields a fully specialized one —
    this is the variant builder behind measured-cost dispatch, where the
    mode vector changes per batch but the op stream never does."""
    unknown = set(modes) - {f"{sec}[{i}]"
                            for sec, seq in (("layer0", prog.layer0),
                                             ("inner", prog.inner),
                                             ("tail", prog.tail))
                            for i in range(len(seq))}
    if unknown:
        raise KeyError(f"unknown program sites {sorted(unknown)}")
    new_secs = {}
    for sec, seq in (("layer0", prog.layer0), ("inner", prog.inner),
                     ("tail", prog.tail)):
        ops = []
        for i, op in enumerate(seq):
            m = modes.get(f"{sec}[{i}]")
            if m is not None:
                if not op.mux:
                    raise ValueError(
                        f"{sec}[{i}] ({op.describe()}) has no dense/sg "
                        f"mux — only Aggregate/AttentionSoftmax modes "
                        f"can be re-specialized")
                if m not in ("dense", "sg"):
                    raise ValueError(f"mode {m!r} for {sec}[{i}]")
                op = replace(op, mode=m)
            ops.append(op)
        new_secs[sec] = tuple(ops)
    return replace(prog, layer0=new_secs["layer0"],
                   inner=new_secs["inner"], tail=new_secs["tail"])


def _forced(force: ForceSpec, site: str, opname: str) -> Optional[str]:
    if force is None:
        return None
    if isinstance(force, str):
        return force
    return force.get(site) or force.get(opname.split("[")[0])


def specialize(prog: AckProgram, *, n: int, avg_edges: float = 0.0,
               f_in: Optional[int] = None, f_hidden: int = 256,
               force: ForceSpec = None, measured=None,
               measured_impl: str = "xla",
               measured_bucket: Optional[int] = None
               ) -> Tuple[AckProgram, ProgramDecision]:
    """Set every op's mode mux. Mux'd ops (Aggregate, AttentionSoftmax)
    each get their own dense/sg decision from their kernel's FLOP model at
    that op's feature width; Transform and friends are recorded as dense.
    ``force`` is None (auto), "dense"/"sg" (all mux'd ops), or a dict keyed
    by site ("layer0[0]") or op class name ("Aggregate").

    ``measured`` is an optional ``obs.calib.CalibrationTable``: when BOTH
    the dense and sg cells for a mux'd op are populated (keyed by op
    class name, at ``measured_impl`` / ``measured_bucket``), their
    measured p50s override the static FLOP model for that op —
    measured-cost dispatch. Partially populated or absent cells fall
    back to the FLOP model per-op; an explicit ``force`` always wins."""
    f_in = f_in if f_in is not None else f_hidden

    def _measured_mode(op):
        """(mode, reason) from measured p50s, or None to use the FLOP
        model for this op."""
        if measured is None:
            return None
        cls = type(op).__name__
        td = measured.lookup(cls, f"{measured_impl}/dense",
                             measured_bucket)
        ts = measured.lookup(cls, f"{measured_impl}/sg", measured_bucket)
        if td is None or ts is None:
            return None
        mode = "dense" if td <= ts else "sg"
        return mode, (f"measured p50 {measured_impl} dense={td:.3e}s vs "
                      f"sg={ts:.3e}s -> {mode}")

    decisions = []
    new_secs: Dict[str, Tuple[AckOp, ...]] = {}
    for sec, seq in (("layer0", prog.layer0), ("inner", prog.inner),
                     ("tail", prog.tail)):
        # a 1-layer program's inner section never executes: its ops still
        # get modes (the stored program stays fully specialized) but no
        # decisions are recorded for them
        executed = sec != "inner" or prog.n_layers > 1
        # track the feature width flowing through the op stream: a
        # Transform re-widens to f_hidden, so ops after it (e.g. gat's
        # attention pair) see the transformed width in their FLOP models
        f_cur = f_in if sec == "layer0" else f_hidden
        new_ops = []
        for i, op in enumerate(seq):
            site = f"{sec}[{i}]"
            name = op.describe()
            if op.mux:
                d = choose_mode(n, avg_edges, f_cur,
                                force=_forced(force, site, name))
                mode, reason = d.mode, d.reason
                if _forced(force, site, name) is None:
                    m = _measured_mode(op)
                    if m is not None:
                        mode, reason = m
                op = replace(op, mode=mode)
                if executed:
                    decisions.append(OpDecision(
                        site, name, mode, True, d.dense_flops,
                        d.sg_flops, reason))
            elif executed:
                fl = op.dense_flops(n, f_cur, f_hidden)
                decisions.append(OpDecision(
                    site, name, "dense", False, fl, fl,
                    "systolic (FT and friends are always dense)"))
            if isinstance(op, Transform):
                f_cur = f_hidden
            new_ops.append(op)
        new_secs[sec] = tuple(new_ops)
    sprog = replace(prog, layer0=new_secs["layer0"],
                    inner=new_secs["inner"], tail=new_secs["tail"])
    return sprog, ProgramDecision(prog.kind, tuple(decisions))


# ---------------------------------------------------------------------------
# the executor: one interpreter over both kernel families


def _adjacency(norm: str, batch, dtype):
    if norm == "gcn":
        return batch["adj"]
    if norm == "mean":
        return batch["adj_mean"]
    if norm == "binary":
        return jnp.sign(batch["adj_mean"])
    raise ValueError(f"unknown aggregate norm {norm!r}")


def _dummy_adj(batch, h):
    """Operand for the fused kernel's (unused) adjacency slot when the
    batch ships only what required_adjacency() reports."""
    for k in ("adj", "adj_mean"):
        if k in batch:
            return batch[k]
    n = h.shape[1]
    return jnp.zeros((h.shape[0], n, n), h.dtype)


def _sg_weights(norm: str, batch):
    if norm == "gcn":
        return batch["edge_w"]
    if norm == "mean":
        return batch["edge_w_mean"]
    return jnp.ones_like(batch["edge_w"]) * (batch["edge_w"] != 0)


def _block_kw(blocks: BlockSpec, key: str) -> dict:
    """Static kernel kwargs for a tuned block size (empty = defaults)."""
    if blocks and blocks.get(key):
        return {key: int(blocks[key])}
    return {}


def _step_aggregate(op: Aggregate, impl: str, blocks: BlockSpec = None):
    from repro.kernels import ops as kops
    bkw = _block_kw(blocks, "block_e")

    def step(p, regs, batch):
        h = regs[op.src]
        if op.mode == "dense":
            regs[op.out] = agg_dense(_adjacency(op.norm, batch, h.dtype), h)
            return
        w = _sg_weights(op.norm, batch)
        if impl == "pallas":
            z = kops.scatter_gather_aggregate(batch["edge_src"],
                                              batch["edge_dst"], w, h,
                                              **bkw)
        else:
            z = agg_sg(batch["edge_src"], batch["edge_dst"], w, h,
                       h.shape[1])
        if op.norm == "gcn":
            # self-loop term is baked into adj in dense mode; the edge
            # list excludes it, so add explicitly
            z = z + h * batch["self_w"][..., None]
        regs[op.out] = z
    return step


def _step_residual(op: Residual):
    def step(p, regs, batch):
        scale = (1.0 + p[op.eps_param]) if op.eps_param else 1.0
        regs[op.into] = scale * regs[op.src] \
            + op.into_gain * regs[op.into]
    return step


def _step_transform(op: Transform, impl: str, blocks: BlockSpec = None):
    from repro.kernels import ops as kops
    bkw = _block_kw(blocks, "block_f")

    if impl == "pallas" and op.w_self is None:
        # pure single-input transform through the fused kernel's W_self
        # slot (the adjacency operand is unused when w_neigh is None —
        # any shipped [C,N,N] array serves). Note the kernel always
        # applies the structural mask; with masked=False this can differ
        # from the XLA path on PADDED rows only, which never reach the
        # embeddings (adjacency columns and the readout both mask them).
        def step(p, regs, batch):
            h = regs[op.src]
            regs[op.out] = kops.fused_gnn_layer(
                _dummy_adj(batch, h), h, None, p[op.w],
                p[op.b] if op.b else None, batch["mask"], act=op.act,
                **bkw)
        return step

    def step(p, regs, batch):
        src = regs[op.src]
        b = p[op.b] if op.b else jnp.zeros((), src.dtype)
        if op.w_self:
            out = _ft(regs["h_in"], p[op.w_self], b) \
                + _ft(src, p[op.w], jnp.zeros((), src.dtype))
        else:
            out = _ft(src, p[op.w], b)
        out = ACTS[op.act](out)
        if op.masked:
            out = out * batch["mask"][..., None]
        regs[op.out] = out
    return step


def _fused_step(agg: Aggregate, res: Optional[Residual], tf: Transform,
                blocks: BlockSpec = None):
    """Pallas peephole: dense Aggregate [+ Residual] + Transform as ONE
    fused MXU kernel call — the aggregated intermediate never leaves VMEM
    (A @ (H @ W) association, see kernels/fused_gnn.py)."""
    from repro.kernels import ops as kops
    bkw = _block_kw(blocks, "block_f")

    def step(p, regs, batch):
        h = regs[agg.src]
        a = _adjacency(agg.norm, batch, h.dtype)
        if res is not None:
            n = h.shape[1]
            scale = (1.0 + p[res.eps_param]) if res.eps_param else 1.0
            a = a + scale * jnp.eye(n, dtype=h.dtype)
        regs[tf.out] = kops.fused_gnn_layer(
            a, h, p[tf.w], p[tf.w_self] if tf.w_self else None,
            p[tf.b] if tf.b else None, batch["mask"], act=tf.act, **bkw)
    return step


def _step_attention_score(op: AttentionScore):
    def step(p, regs, batch):
        z = regs[op.src]
        C, N, F = z.shape
        z4 = z.reshape(C, N, op.n_heads, F // op.n_heads)
        regs["s_src"] = jnp.einsum("cnhf,hf->cnh", z4, p[op.a_src])
        regs["s_dst"] = jnp.einsum("cnhf,hf->cnh", z4, p[op.a_dst])
    return step


def _step_attention_softmax(op: AttentionSoftmax, impl: str):
    from repro.kernels import ops as kops

    def finish(out, p, batch):
        out = out + p[op.b] if op.b else out
        return ACTS[op.act](out) * batch["mask"][..., None]

    if op.mode == "dense" and impl == "pallas":
        def step(p, regs, batch):
            z, mask = regs[op.src], batch["mask"]
            n = z.shape[1]
            struct = (jnp.sign(batch["adj_mean"])
                      + jnp.eye(n, dtype=z.dtype)) * mask[:, None, :]
            out = kops.gat_attention(z, regs["s_src"], regs["s_dst"],
                                     struct, n_heads=op.n_heads)
            regs[op.out] = finish(out, p, batch)
        return step

    if op.mode == "dense":
        def step(p, regs, batch):
            z, mask = regs[op.src], batch["mask"]
            C, N, F = z.shape
            nh = op.n_heads
            z4 = z.reshape(C, N, nh, F // nh)
            s_src, s_dst = regs["s_src"], regs["s_dst"]
            e = s_dst.transpose(0, 2, 1)[:, :, :, None] \
                + s_src.transpose(0, 2, 1)[:, :, None, :]
            e = jax.nn.leaky_relu(e, op.negative_slope)
            struct = (jnp.sign(batch["adj_mean"])
                      + jnp.eye(N, dtype=z.dtype)) * mask[:, None, :]
            emask = struct[:, None, :, :] > 0
            e = jnp.where(emask, e, NEG_INF)
            attn = jax.nn.softmax(e, axis=-1)
            attn = jnp.where(emask, attn, 0.0)
            out = jnp.einsum("chij,cjhf->cihf", attn, z4)
            regs[op.out] = finish(out.reshape(C, N, F), p, batch)
        return step

    # sg mode: edge-parallel segment softmax (no Pallas kernel for this —
    # the XLA segment path is the sparse overlay on both impls)
    def step(p, regs, batch):
        z = regs[op.src]
        C, N, F = z.shape
        nh = op.n_heads
        z4 = z.reshape(C, N, nh, F // nh)
        src, dst = batch["edge_src"], batch["edge_dst"]
        valid = (batch["edge_w"] != 0).astype(z.dtype)

        def one(src_c, dst_c, val_c, z_c, ss_c, sd_c):
            # self-loop handled by appending implicit (i, i) edges
            iota = jnp.arange(N, dtype=src_c.dtype)
            s_all = jnp.concatenate([src_c, iota])
            d_all = jnp.concatenate([dst_c, iota])
            v_all = jnp.concatenate([val_c, jnp.ones(N, z.dtype)])
            e = jax.nn.leaky_relu(sd_c[d_all] + ss_c[s_all],
                                  op.negative_slope)
            e = jnp.where(v_all[:, None] > 0, e, NEG_INF)
            m = jax.ops.segment_max(e, d_all, num_segments=N)
            ex = jnp.exp(e - m[d_all]) * v_all[:, None]
            den = jax.ops.segment_sum(ex, d_all, num_segments=N)
            alpha = ex / jnp.maximum(den[d_all], 1e-20)
            upd = alpha[:, :, None] * z_c[s_all]
            return jax.ops.segment_sum(upd, d_all, num_segments=N)

        out = jax.vmap(one)(src, dst, valid, z4, regs["s_src"],
                            regs["s_dst"])
        regs[op.out] = finish(out.reshape(C, N, F), p, batch)
    return step


def compile_steps(seq: Sequence[AckOp], impl: str,
                  blocks: BlockSpec = None):
    """Lower an op stream to labeled step closures: a list of
    ``(ops, step)`` pairs where ``ops`` is the tuple of AckOps the step
    executes (a singleton, or the Aggregate[+Residual]+Transform group a
    Pallas peephole fused into one kernel call). ``_compile_section``
    strips the labels for the jitted execution path; ``obs.calib`` keeps
    them to time each step of a sampled eager pass — the per-op measured
    latencies the ROADMAP's measured-cost dispatch needs. ``blocks``
    threads autotuned Pallas block sizes into the kernel calls
    (``{"block_f": ..., "block_e": ...}``; None = kernel defaults)."""
    steps = []
    i = 0
    while i < len(seq):
        op = seq[i]
        if (impl == "pallas" and isinstance(op, Aggregate)
                and op.mode == "dense" and i == 0
                and op.src in ("h", "h_in")):
            # fusion is only sound when the group reads the LAYER INPUT:
            # the fused kernel feeds one H to the aggregation, the folded
            # residual (A + scale*I), and W_self alike. At i == 0 the
            # "h"/"h_in" registers still hold the layer input, so the
            # guard rules out custom lowerings where an earlier op
            # rewrote them (those fall through to per-op execution).
            j, res = i + 1, None
            if (j < len(seq) and isinstance(seq[j], Residual)
                    and seq[j].into == op.out
                    and seq[j].src in ("h", "h_in")
                    and seq[j].into_gain == 1.0):
                # the fused kernel folds the residual as A + scale*I,
                # which assumes the aggregate term is unscaled
                res, j = seq[j], j + 1
            if (j < len(seq) and isinstance(seq[j], Transform)
                    and seq[j].src == op.out):
                group = tuple(o for o in (op, res, seq[j])
                              if o is not None)
                steps.append((group, _fused_step(op, res, seq[j],
                                                 blocks)))
                i = j + 1
                continue
        if isinstance(op, Aggregate):
            steps.append(((op,), _step_aggregate(op, impl, blocks)))
        elif isinstance(op, Residual):
            steps.append(((op,), _step_residual(op)))
        elif isinstance(op, Transform):
            steps.append(((op,), _step_transform(op, impl, blocks)))
        elif isinstance(op, AttentionScore):
            steps.append(((op,), _step_attention_score(op)))
        elif isinstance(op, AttentionSoftmax):
            steps.append(((op,), _step_attention_softmax(op, impl)))
        else:
            raise TypeError(f"op {op!r} is not a layer op")
        i += 1
    return steps


def _compile_section(seq: Sequence[AckOp], impl: str,
                     blocks: BlockSpec = None):
    """Unlabeled section lowering for the jitted execution path."""
    steps = [step for _, step in compile_steps(seq, impl, blocks)]

    def apply(p, h, batch, h0=None):
        # "h0" is the propagation ENTRY state: the layer input for
        # layer0, the post-layer0 prediction (constant across the inner
        # scan) for inner layers — APPNP's teleport anchor
        regs = {"h": h, "h_in": h, "h0": h if h0 is None else h0}
        for s in steps:
            s(p, regs, batch)
        return regs["h"]
    return apply


def execute(prog: AckProgram, params, batch, impl: str = "xla",
            blocks: BlockSpec = None):
    """Run a specialized AckProgram: layer0, then L-1 inner layers under
    one ``lax.scan`` over the stacked weights, then the tail. Returns
    ``(embeddings [C, f], final h [C, N, f])`` — the same contract as the
    pre-IR ``gnn_forward``. ``blocks`` carries autotuned Pallas block
    sizes (see ``compile_steps``); None keeps the kernel defaults."""
    if not prog.specialized:
        raise ValueError(
            "program has unspecialized mux ops — call specialize() first")
    apply0 = _compile_section(prog.layer0, impl, blocks)
    h = apply0(params["layer0"], batch["feats"], batch)
    if prog.n_layers > 1:
        apply_i = _compile_section(prog.inner, impl, blocks)
        h0 = h                      # scan-entry prediction, teleport anchor

        def body(hh, lp):
            return apply_i(lp, hh, batch, h0=h0), None
        h, _ = jax.lax.scan(body, h, params["layers"])
    emb = h
    for op in prog.tail:
        if isinstance(op, Readout):
            emb = readout(h, batch["mask"], op.kind)
        elif isinstance(op, Classify):
            emb = emb @ params[op.w] + params[op.b]
        else:
            raise TypeError(f"op {op!r} is not a tail op")
    return emb, h


def lower_and_specialize(cfg, *, avg_edges: float = 0.0,
                         force: ForceSpec = None
                         ) -> Tuple[AckProgram, ProgramDecision]:
    """Convenience: lower ``cfg`` and specialize at its receptive field."""
    return specialize(lower(cfg), n=cfg.receptive_field,
                      avg_edges=avg_edges, f_in=cfg.f_in,
                      f_hidden=cfg.f_hidden, force=force)
