"""Task scheduling on the host/accelerator boundary (paper §4.4, Fig. 7).

The paper overlaps, per PE: (a) CPU-side INI + subgraph build, (b) PCIe
transfer into on-chip buffers (triple-buffered), (c) accelerator compute.
Here (a) runs on host threads ``depth`` batches ahead (the triple buffer),
(b) is ``jax.device_put`` async H2D, and (c) is the jitted engine program —
JAX's async dispatch naturally pipelines (b)/(c) while the host side
pipelines (a).

The host side is either ONE opaque ``host_fn`` (the back-compat one-stage
spelling, run on a ``depth``-worker pool) or a sequence of named STAGES
(``core.batchplan.PlanStage``): each stage gets its own worker station and
batches flow through them in order, so stage i of batch k overlaps stage
i+1 of batch k-1 — a slow Select (PPR miss) on one batch no longer stalls
the Build/Pack of the batches behind it, and every stage's wall time is
visible in ``SchedulerStats.stage_times`` (a software Fig. 3 breakdown).

``PipelineScheduler`` is a *persistent streaming* pipeline: construct it
once per deployment, then ``submit()`` micro-batches as they arrive (a
long-lived server) or ``run()`` a list of them (offline inference). Both
entry points share the same stage workers, dispatcher thread, and
cumulative ``SchedulerStats`` — nothing is rebuilt per call, which is the
paper's "single accelerator configuration, no reconfiguration between
batches" property at the software layer.

``SchedulerStats`` reports the paper's §5.4 quantities: t_initialization
(first-batch host latency, the un-hideable prologue), per-stage sums, and
the achieved overlap fraction.
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

import jax

from repro.core.report_schema import scheduler_summary

# per-batch raw-timing window: the newest RECENT_TIMES host/device times
# are kept verbatim (recent forensics); older ones roll off, so stats
# memory is O(1) in batch count (cumulative totals stay exact)
RECENT_TIMES = 512


@dataclass
class SchedulerStats:
    t_wall: float = 0.0
    t_host_total: float = 0.0        # sum of per-batch host prep times
    t_device_total: float = 0.0      # sum of per-batch device times
    t_initialization: float = 0.0    # host prep of the FIRST batch
    n_batches: int = 0
    host_times: "deque" = field(
        default_factory=lambda: deque(maxlen=RECENT_TIMES))
    device_times: "deque" = field(
        default_factory=lambda: deque(maxlen=RECENT_TIMES))
    # per-stage host wall time totals (staged pipelines only; the
    # one-stage host_fn spelling accumulates under "host") — the paper's
    # Fig. 3 breakdown of the host budget
    stage_times: Dict[str, float] = field(default_factory=dict)
    # host->device transfer accounting (the paper's t_load, Eq. 2): what
    # actually crossed the link vs. what the dense baseline would ship,
    # plus the store's neighborhood-cache outcome — fed by the host side
    # via ``PipelineScheduler.note_host_metrics``.
    bytes_shipped: int = 0
    bytes_dense: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    # Build-stage subgraph-row cache outcome (staged pipelines only)
    build_hits: int = 0
    build_misses: int = 0
    last_dedup_ratio: Optional[float] = None
    # induced-subgraph density seen by the host pipeline (sum of per-
    # batch mean edges/subgraph; divide by n_density for the mean) —
    # what per-batch adaptive dispatch keys its FLOP fallback on
    batch_edges_total: float = 0.0
    n_density: int = 0
    # sharded feature store only: cumulative host->device bytes PER SHARD
    # (empty for unsharded deployments)
    shard_bytes: List[int] = field(default_factory=list)
    # multi-host transport only (distributed.rpc): per-stage remote call
    # accounting — wall is what the device host observed end-to-end,
    # remote is the graph host's reported handler time, wire is local
    # encode/decode; the gap between them is the link
    rpc_calls: int = 0
    rpc_bytes_out: int = 0
    rpc_bytes_in: int = 0
    rpc_retries: int = 0
    rpc_timeouts: int = 0
    rpc_errors: int = 0
    t_rpc_wall: float = 0.0
    t_rpc_remote: float = 0.0
    t_rpc_wire: float = 0.0

    @property
    def overlap_fraction(self) -> float:
        """How much of the smaller stage was hidden under the larger one.
        1.0 = perfect pipelining, 0.0 = fully serial."""
        lo = min(self.t_host_total, self.t_device_total)
        serial = self.t_host_total + self.t_device_total
        if lo <= 0 or serial <= self.t_wall:
            return 0.0 if serial <= self.t_wall else 1.0
        return min(1.0, (serial - self.t_wall) / lo)

    @property
    def cache_hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    @property
    def build_hit_rate(self) -> float:
        """Subgraph-row cache hit rate (Build stage skipped on a hit)."""
        total = self.build_hits + self.build_misses
        return self.build_hits / total if total else 0.0

    @property
    def batch_edges(self) -> float:
        """Mean measured edges per induced subgraph across all batches
        (0.0 until the first Build stage reports density)."""
        return self.batch_edges_total / self.n_density \
            if self.n_density else 0.0

    @property
    def transfer_ratio(self) -> float:
        """Bytes actually shipped / dense-baseline bytes (< 1 = savings)."""
        return self.bytes_shipped / self.bytes_dense if self.bytes_dense \
            else 1.0

    @property
    def shard_balance(self) -> float:
        """max/mean of per-shard shipped bytes (1.0 = perfectly even;
        1.0 also when the deployment is unsharded)."""
        if not self.shard_bytes:
            return 1.0
        mean = sum(self.shard_bytes) / len(self.shard_bytes)
        return max(self.shard_bytes) / mean if mean > 0 else 1.0

    def summary(self) -> dict:
        """Nested ``latency.* / stages.* / store.* / shards.* / rpc.*``
        summary under the ONE versioned key schema every reporting
        surface shares (core.report_schema, SCHEMA_VERSION)."""
        return scheduler_summary(self)

    def record(self, t_host: float, t_device: float):
        if self.n_batches == 0:
            self.t_initialization = t_host
        self.host_times.append(t_host)
        self.device_times.append(t_device)
        self.t_host_total += t_host
        self.t_device_total += t_device
        self.n_batches += 1

    def merge_stage_times(self, stage_times: Dict[str, float]):
        for k, v in stage_times.items():
            self.stage_times[k] = self.stage_times.get(k, 0.0) + v


class StreamTicket:
    """Handle for one in-flight micro-batch: resolves to the device output.

    ``t_host``/``t_device`` carry the per-stage timings once done
    (``stage_times`` the named host-stage split); ``on_done(ticket)`` (if
    given) fires on the dispatcher thread — keep it light (recording
    latencies, handing results to waiters).
    """

    __slots__ = ("item", "seq", "on_done", "t_submit", "t_host", "t_device",
                 "stage_times", "output", "error", "trace", "_event",
                 "_host_future")

    def __init__(self, item: Any, seq: int,
                 on_done: Optional[Callable] = None):
        self.item = item
        self.seq = seq
        self.on_done = on_done
        self.t_submit = time.perf_counter()
        self.t_host = 0.0
        self.t_device = 0.0
        self.stage_times: Dict[str, float] = {}
        self.output: Any = None
        self.error: Optional[BaseException] = None
        self.trace = None            # obs.TraceContext when sampled
        self._event = threading.Event()
        self._host_future = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(f"batch {self.seq} not done in {timeout}s")
        if self.error is not None:
            raise self.error
        return self.output


_SHUTDOWN = object()


class PipelineScheduler:
    """Persistent double/triple-buffered host->device streaming pipeline.

    host            -> either ``host_fn(item) -> host batch`` (one-stage
                      back-compat spelling, run on a ``depth``-worker
                      pool) or a sequence of ``PlanStage`` objects, each
                      run on its own worker station so consecutive
                      batches pipeline through the stages
    device_fn(batch)-> device array(s); device work is async-dispatched
    depth           -> how many batches the host runs ahead (2 = double
                      buffering, 3 = the paper's triple buffering); in
                      staged mode the stage stations bound it instead
    max_inflight    -> bound on submitted-but-incomplete batches;
                      ``submit()`` blocks past it (backpressure), default
                      2 * depth.
    on_batch        -> optional ``on_batch(ticket)`` completion hook,
                      fired on the dispatcher thread after stats are
                      recorded (the engine's auto-repin trigger point);
                      exceptions are swallowed.
    tracer          -> optional ``obs.Tracer``; sampled tickets get a
                      TraceContext and every stage/device step runs
                      under a span. None (default) = tracing off —
                      each hot-path site pays one ``is None`` test.
    telemetry       -> optional ``obs.Telemetry`` hub; every completed
                      batch feeds its end-to-end latency + per-stage
                      wall split into the windowed metrics (same
                      zero-cost-when-off contract as tracer).

    Lifecycle: lazily started on first submit/run; ``close()`` drains and
    tears down threads (stage objects themselves are owned — and closed —
    by their engine). ``self.stats`` accumulates over the scheduler's
    whole lifetime; ``run()`` additionally returns call-local stats.
    """

    def __init__(self, host: Union[Callable, Sequence],
                 device_fn: Callable, depth: int = 3,
                 max_inflight: Optional[int] = None,
                 on_batch: Optional[Callable] = None,
                 tracer=None, telemetry=None):
        if callable(host):
            self.host_fn, self.stages = host, None
        else:
            self.host_fn, self.stages = None, list(host)
            if not self.stages:
                raise ValueError("empty stage sequence")
        self.device_fn = device_fn
        self.tracer = tracer
        self.telemetry = telemetry
        self.depth = max(1, depth)
        self.max_inflight = max_inflight or 2 * self.depth
        self.on_batch = on_batch
        self.stats = SchedulerStats()
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._order_q: "queue.Queue" = queue.Queue()
        self._slots = threading.BoundedSemaphore(self.max_inflight)
        self._inflight = 0
        self._active_since: Optional[float] = None
        self._seq = 0
        self._pool: Optional[ThreadPoolExecutor] = None
        self._stage_pools: Optional[List[ThreadPoolExecutor]] = None
        self._dispatcher: Optional[threading.Thread] = None
        self._closed = False

    # -- lifecycle -----------------------------------------------------------
    @property
    def started(self) -> bool:
        return self._dispatcher is not None

    @property
    def stage_names(self) -> List[str]:
        return [st.name for st in self.stages] if self.stages else ["host"]

    def start(self) -> "PipelineScheduler":
        with self._lock:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            if self._dispatcher is not None:
                return self
            if self.stages is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.depth, thread_name_prefix="sched-host")
            else:
                # one worker station per stage: batches flow through in
                # submission order, consecutive batches occupy adjacent
                # stages (the paper's Fig. 7 pipelining, host-side)
                self._stage_pools = [
                    ThreadPoolExecutor(
                        max_workers=max(1, getattr(st, "workers", 1)),
                        thread_name_prefix=f"sched-{st.name}")
                    for st in self.stages]
            self._dispatcher = threading.Thread(
                target=self._dispatch_loop, name="sched-dispatch",
                daemon=True)
            self._dispatcher.start()
        return self

    def close(self):
        if self._dispatcher is None or self._closed:
            self._closed = True
            return
        self.flush()
        self._closed = True
        self._order_q.put(_SHUTDOWN)
        self._dispatcher.join(timeout=10)
        if self._pool is not None:
            self._pool.shutdown(wait=True)
        for p in self._stage_pools or ():
            p.shutdown(wait=True)
        # a submit() that raced past the closed-check may have enqueued
        # after _SHUTDOWN; fail its ticket rather than hang its waiter
        while True:
            try:
                t = self._order_q.get_nowait()
            except queue.Empty:
                break
            if t is not _SHUTDOWN:
                t.error = RuntimeError("scheduler closed before dispatch")
                self._complete(t)

    # -- host execution ------------------------------------------------------
    def _traced(self, name: str, ticket: StreamTicket, fn, *args):
        """Run one pipeline step, under a span when the ticket is traced
        (the untraced path is a single attribute test + call)."""
        tr = self.tracer
        if tr is None or ticket.trace is None:
            return fn(*args)
        with tr.span(name, ctx=ticket.trace, seq=ticket.seq):
            return fn(*args)

    def _timed_host(self, ticket: StreamTicket):
        t = time.perf_counter()
        hb = self._traced("host", ticket, self.host_fn, ticket.item)
        dt = time.perf_counter() - t
        ticket.stage_times["host"] = dt
        return hb, dt

    def _host_serial(self, item, stage_times: Optional[Dict] = None):
        """Run the full host side inline (run()'s no-overlap path)."""
        if self.stages is None:
            t0 = time.perf_counter()
            v = self.host_fn(item)
            if stage_times is not None:
                stage_times["host"] = stage_times.get("host", 0.0) \
                    + time.perf_counter() - t0
            return v
        v = item
        for st in self.stages:
            t0 = time.perf_counter()
            v = st.run(v)
            if stage_times is not None:
                stage_times[st.name] = stage_times.get(st.name, 0.0) \
                    + time.perf_counter() - t0
        return v

    def _stage_step(self, ticket: StreamTicket, i: int, value):
        st = self.stages[i]
        t0 = time.perf_counter()
        try:
            out = self._traced(st.name, ticket, st.run, value)
        except BaseException as e:             # noqa: BLE001
            ticket.stage_times[st.name] = \
                ticket.stage_times.get(st.name, 0.0) \
                + time.perf_counter() - t0
            ticket._host_future.set_exception(e)
            return
        ticket.stage_times[st.name] = \
            ticket.stage_times.get(st.name, 0.0) + time.perf_counter() - t0
        if i + 1 < len(self.stages):
            try:
                self._stage_pools[i + 1].submit(self._stage_step, ticket,
                                                i + 1, out)
            except RuntimeError:               # racing close()
                ticket._host_future.set_exception(
                    RuntimeError("scheduler closed mid-pipeline"))
        else:
            ticket._host_future.set_result(
                (out, sum(ticket.stage_times.values())))

    def _submit_host(self, ticket: StreamTicket):
        if self.stages is None:
            ticket._host_future = self._pool.submit(self._timed_host,
                                                    ticket)
        else:
            ticket._host_future = Future()
            self._stage_pools[0].submit(self._stage_step, ticket, 0,
                                        ticket.item)

    # -- streaming interface -------------------------------------------------
    def submit(self, item, on_done: Optional[Callable] = None
               ) -> StreamTicket:
        """Enqueue one micro-batch; blocks when max_inflight is reached."""
        self.start()
        self._slots.acquire()
        if self._closed:             # close() ran while we were blocked
            self._slots.release()
            raise RuntimeError("scheduler is closed")
        with self._lock:
            t = StreamTicket(item, self._seq, on_done)
            self._seq += 1
            if self._inflight == 0:
                self._active_since = time.perf_counter()
            self._inflight += 1
        if self.tracer is not None:
            t.trace = self.tracer.maybe_trace(seq=t.seq)
        try:
            self._submit_host(t)
            self._order_q.put(t)
        except RuntimeError as e:    # pool shut down by a racing close()
            with self._idle:
                self._inflight -= 1
                if self._inflight == 0:
                    self._active_since = None
                self._idle.notify_all()
            self._slots.release()
            raise RuntimeError("scheduler is closed") from e
        return t

    def note_host_metrics(self, *, bytes_shipped: int = 0,
                          bytes_dense: int = 0, cache_hits: int = 0,
                          cache_misses: int = 0, build_hits: int = 0,
                          build_misses: int = 0,
                          dedup_ratio: Optional[float] = None,
                          shard_bytes: Optional[Sequence[int]] = None,
                          batch_edges: Optional[float] = None):
        """Accumulate transfer/cache counters for one prepared batch.

        Called by the host side itself (it alone knows what it shipped and
        what the dense baseline would have been); safe from the stage
        worker threads and from run()'s serial path alike. ``shard_bytes``
        (one entry per feature-store shard) accumulates elementwise."""
        with self._lock:
            s = self.stats
            s.bytes_shipped += int(bytes_shipped)
            s.bytes_dense += int(bytes_dense)
            s.cache_hits += int(cache_hits)
            s.cache_misses += int(cache_misses)
            s.build_hits += int(build_hits)
            s.build_misses += int(build_misses)
            if dedup_ratio is not None:
                s.last_dedup_ratio = float(dedup_ratio)
            if batch_edges is not None:
                s.batch_edges_total += float(batch_edges)
                s.n_density += 1
            if shard_bytes is not None:
                if len(s.shard_bytes) < len(shard_bytes):
                    s.shard_bytes += [0] * (len(shard_bytes)
                                            - len(s.shard_bytes))
                for i, b in enumerate(shard_bytes):
                    s.shard_bytes[i] += int(b)

    def note_rpc_metrics(self, *, calls: int = 0, bytes_out: int = 0,
                         bytes_in: int = 0, retries: int = 0,
                         timeouts: int = 0, errors: int = 0,
                         wall: float = 0.0, remote: float = 0.0,
                         wire: float = 0.0):
        """Accumulate one remote stage call's transport accounting
        (distributed.rpc.RemoteSelectBuildStage) — safe from concurrent
        stage workers, surfaced under ``rpc.*`` in summary()/report()."""
        with self._lock:
            s = self.stats
            s.rpc_calls += int(calls)
            s.rpc_bytes_out += int(bytes_out)
            s.rpc_bytes_in += int(bytes_in)
            s.rpc_retries += int(retries)
            s.rpc_timeouts += int(timeouts)
            s.rpc_errors += int(errors)
            s.t_rpc_wall += float(wall)
            s.t_rpc_remote += float(remote)
            s.t_rpc_wire += float(wire)

    def flush(self, timeout: Optional[float] = None):
        """Block until every submitted batch has completed."""
        with self._idle:
            if not self._idle.wait_for(lambda: self._inflight == 0,
                                       timeout=timeout):
                raise TimeoutError("scheduler flush timed out")

    def _complete(self, ticket: StreamTicket):
        with self._lock:             # same lock as run()'s serial recorder
            self.stats.record(ticket.t_host, ticket.t_device)
            self.stats.merge_stage_times(ticket.stage_times)
        if ticket.trace is not None:
            # close the batch's span tree before waiters wake, so a
            # result() immediately followed by export sees the full tree
            self.tracer.finish_ticket(
                ticket.trace, error=ticket.error is not None,
                t_host=round(ticket.t_host, 6),
                t_device=round(ticket.t_device, 6))
        if self.telemetry is not None:
            self.telemetry.observe_batch(
                time.perf_counter() - ticket.t_submit,
                ticket.stage_times, error=ticket.error is not None)
        ticket._event.set()          # resolve BEFORE on_done: callbacks may
        if ticket.on_done is not None:           # call ticket.result()
            try:
                ticket.on_done(ticket)
            except Exception:        # callback errors must not kill pipeline
                pass
        if self.on_batch is not None:
            try:                     # completion hook (e.g. auto-repin) —
                self.on_batch(ticket)            # never kills the pipeline
            except Exception:
                pass
        # in-flight accounting last, so flush() implies callbacks finished
        with self._idle:
            self._inflight -= 1
            if self._inflight == 0 and self._active_since is not None:
                self.stats.t_wall += time.perf_counter() - self._active_since
                self._active_since = None
            self._idle.notify_all()
        self._slots.release()

    def _dispatch_loop(self):
        pending: Optional[StreamTicket] = None
        while True:
            try:
                # only poll while a batch is pending drain; otherwise block
                # (an idle pipeline must not busy-wake — engines keep their
                # scheduler for life and many may be idle at once)
                if pending is None:
                    t = self._order_q.get()
                else:
                    t = self._order_q.get(timeout=0.05)
            except queue.Empty:
                self._drain(pending)
                pending = None
                continue
            if t is _SHUTDOWN:
                if pending is not None:
                    self._drain(pending)
                break
            td0 = time.perf_counter()
            try:
                hb, t.t_host = t._host_future.result()
                td0 = time.perf_counter()
                # "device" span = dispatch of the jitted program (async);
                # the sync wait shows up as the "drain" span in _drain
                t.output = self._traced("device", t, self.device_fn, hb)
            except BaseException as e:             # noqa: BLE001
                t.error = e
            if pending is not None:                # drain batch i-1 while
                self._drain(pending)               # batch i computes
                pending = None
            t.t_device = time.perf_counter() - td0
            if t.error is not None:
                self._complete(t)
            elif self._order_q.empty():
                # nothing behind us: finish now for lowest tail latency
                self._drain(t, extra_device_time=True)
            else:
                pending = t

    def _drain(self, ticket: StreamTicket, extra_device_time: bool = False):
        t0 = time.perf_counter()
        try:
            self._traced("drain", ticket, jax.block_until_ready,
                         ticket.output)
        except BaseException as e:                 # noqa: BLE001
            ticket.error = e
        if extra_device_time:
            ticket.t_device += time.perf_counter() - t0
        self._complete(ticket)

    # -- batch interface (offline inference) ---------------------------------
    def run(self, items: Sequence, overlap: bool = True):
        """Run a list of micro-batches; returns (outputs, call stats).

        overlap=False executes fully serially on the caller thread (the
        paper's no-pipelining baseline); both paths accumulate into the
        cumulative ``self.stats``.
        """
        call = SchedulerStats(n_batches=len(items))
        with self._lock:       # store-metric baseline for call-local delta
            base = (self.stats.bytes_shipped, self.stats.bytes_dense,
                    self.stats.cache_hits, self.stats.cache_misses,
                    self.stats.build_hits, self.stats.build_misses,
                    self.stats.batch_edges_total, self.stats.n_density)
        t0 = time.perf_counter()
        if not overlap or self.depth == 1:
            outs = []
            for it in items:
                st_times: Dict[str, float] = {}
                th = time.perf_counter()
                hb = self._host_serial(it, st_times)
                th = time.perf_counter() - th
                td = time.perf_counter()
                out = self.device_fn(hb)
                jax.block_until_ready(out)
                td = time.perf_counter() - td
                call.host_times.append(th)
                call.device_times.append(td)
                call.merge_stage_times(st_times)
                with self._lock:
                    self.stats.record(th, td)
                    self.stats.merge_stage_times(st_times)
                    self.stats.t_wall += th + td
                if self.telemetry is not None:
                    self.telemetry.observe_batch(th + td, st_times)
                if self.on_batch is not None:
                    try:             # completion hook fires on the serial
                        self.on_batch(None)      # path too (no ticket)
                    except Exception:
                        pass
                outs.append(out)
        else:
            tickets = [self.submit(it) for it in items]
            outs = [t.result() for t in tickets]
            call.host_times = [t.t_host for t in tickets]
            call.device_times = [t.t_device for t in tickets]
            for t in tickets:
                call.merge_stage_times(t.stage_times)
        call.t_wall = time.perf_counter() - t0
        call.t_host_total = sum(call.host_times)
        call.t_device_total = sum(call.device_times)
        call.t_initialization = call.host_times[0] if call.host_times \
            else 0.0
        with self._lock:
            # this call's share of the note_host_metrics counters (exact
            # when run() has the scheduler to itself; concurrent submit()
            # traffic from other threads folds into the same window)
            call.bytes_shipped = self.stats.bytes_shipped - base[0]
            call.bytes_dense = self.stats.bytes_dense - base[1]
            call.cache_hits = self.stats.cache_hits - base[2]
            call.cache_misses = self.stats.cache_misses - base[3]
            call.build_hits = self.stats.build_hits - base[4]
            call.build_misses = self.stats.build_misses - base[5]
            call.batch_edges_total = self.stats.batch_edges_total - base[6]
            call.n_density = self.stats.n_density - base[7]
            call.last_dedup_ratio = self.stats.last_dedup_ratio
        return outs, call
