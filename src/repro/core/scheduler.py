"""Task scheduling on the host/accelerator boundary (paper §4.4, Fig. 7).

The paper overlaps, per PE: (a) CPU-side INI + subgraph build, (b) PCIe
transfer into on-chip buffers (triple-buffered), (c) accelerator compute.
Here (a) runs on a host thread pool ``depth`` batches ahead (the triple
buffer), (b) is ``jax.device_put`` async H2D, and (c) is the jitted engine
program — JAX's async dispatch naturally pipelines (b)/(c) while the pool
pipelines (a).

``SchedulerStats`` reports the paper's §5.4 quantities: t_initialization
(first-batch host latency, the un-hideable prologue), per-stage sums, and
the achieved overlap fraction.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, List, Sequence

import jax


@dataclass
class SchedulerStats:
    t_wall: float = 0.0
    t_host_total: float = 0.0        # sum of per-batch host prep times
    t_device_total: float = 0.0      # sum of per-batch device times
    t_initialization: float = 0.0    # host prep of the FIRST batch
    n_batches: int = 0
    host_times: List[float] = field(default_factory=list)
    device_times: List[float] = field(default_factory=list)

    @property
    def overlap_fraction(self) -> float:
        """How much of the smaller stage was hidden under the larger one.
        1.0 = perfect pipelining, 0.0 = fully serial."""
        lo = min(self.t_host_total, self.t_device_total)
        serial = self.t_host_total + self.t_device_total
        if lo <= 0 or serial <= self.t_wall:
            return 0.0 if serial <= self.t_wall else 1.0
        return min(1.0, (serial - self.t_wall) / lo)

    def summary(self) -> dict:
        return {"t_wall": self.t_wall, "t_host": self.t_host_total,
                "t_device": self.t_device_total,
                "t_init": self.t_initialization,
                "overlap": round(self.overlap_fraction, 3),
                "batches": self.n_batches}


class PipelineScheduler:
    """Double/triple-buffered host->device pipeline.

    host_fn(item)   -> host batch (numpy dict), CPU-bound
    device_fn(batch)-> device array(s); device work is async-dispatched
    depth           -> how many batches the host runs ahead (2 = double
                      buffering, 3 = the paper's triple buffering)
    """

    def __init__(self, host_fn: Callable, device_fn: Callable,
                 depth: int = 3):
        self.host_fn, self.device_fn = host_fn, device_fn
        self.depth = max(1, depth)

    def run(self, items: Sequence, overlap: bool = True):
        stats = SchedulerStats(n_batches=len(items))
        outs = []
        t0 = time.perf_counter()
        if not overlap or self.depth == 1:
            for it in items:
                th = time.perf_counter()
                hb = self.host_fn(it)
                th = time.perf_counter() - th
                stats.host_times.append(th)
                td = time.perf_counter()
                out = self.device_fn(hb)
                jax.block_until_ready(out)
                stats.device_times.append(time.perf_counter() - td)
                outs.append(out)
        else:
            def timed_host(it):
                t = time.perf_counter()
                hb = self.host_fn(it)
                return hb, time.perf_counter() - t

            with ThreadPoolExecutor(max_workers=self.depth) as pool:
                futs = [pool.submit(timed_host, it) for it in items]
                pending = None
                for i, fut in enumerate(futs):
                    hb, th = fut.result()
                    stats.host_times.append(th)
                    td = time.perf_counter()
                    out = self.device_fn(hb)     # async dispatch
                    if pending is not None:      # drain previous batch
                        jax.block_until_ready(pending)
                    stats.device_times.append(time.perf_counter() - td)
                    outs.append(out)
                    pending = out
                if pending is not None:
                    jax.block_until_ready(pending)
        stats.t_wall = time.perf_counter() - t0
        stats.t_host_total = sum(stats.host_times)
        stats.t_device_total = sum(stats.device_times)
        stats.t_initialization = stats.host_times[0] if stats.host_times \
            else 0.0
        return outs, stats
