"""BatchPlan IR — the host side of prepare() as a typed, staged pipeline.

PR 3 turned the DEVICE side into an inspectable instruction stream
(core.program.AckProgram); this module is the mirrored move for the HOST
side. The paper's Fig. 3 shows INI + subgraph construction dominating the
non-compute budget, and its Fig. 7 scheduler hides that work under device
execution — but a monolithic ``host_fn`` can only be hidden as a whole.
Decomposing it into named stages makes each piece separately observable
(a software Fig. 3 breakdown), separately cacheable (the Build stage's
subgraph-row cache), and separately schedulable (the scheduler pipelines
stage i of batch k under stage i+1 of batch k-1).

The artifact each stage produces/consumes is a ``BatchPlan``:

  Select   targets            -> PPR node lists (+ push frontiers), via
                                the neighborhood cache when configured
  Build    node lists         -> per-target SubgraphRows (induced
                                adjacency/edge blocks), via the
                                subgraph-row cache when configured —
                                a hit skips construction entirely
  Pack     rows               -> fixed-shape SubgraphBatch + the store
                                strategy's device payload + transfer
                                accounting

``DecoupledEngine`` instantiates the three stages and hands them to
``PipelineScheduler``; running them back-to-back on one thread is exactly
the old monolithic ``prepare()`` (and remains its spelling), so the staged
pipeline is bitwise-identical to the monolithic path by construction.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.subgraph import (SubgraphBatch, SubgraphRows,
                                 assemble_batch, build_subgraph_rows)
from repro.store.nbr_cache import nbr_key


@dataclass
class BatchPlan:
    """The host-side compilation artifact for ONE micro-batch: every
    stage reads the fields of the previous stage and writes its own.
    ``device`` (the Pack stage's output) is what crosses to the device."""
    targets: np.ndarray
    # Select
    node_lists: Optional[List[np.ndarray]] = None
    frontiers: Dict[int, Optional[np.ndarray]] = field(default_factory=dict)
    nbr_hits: int = 0
    nbr_misses: int = 0
    row_gen: Optional[int] = None     # row-cache epoch at Select time
    # Build
    rows: Optional[List[SubgraphRows]] = None
    build_hits: int = 0
    build_misses: int = 0
    # induced-subgraph density stats (mean over the batch's rows) — the
    # inputs to per-batch adaptive dispatch. Build fills them locally;
    # Pack recomputes from the rows when Build ran behind a transport.
    n_vertices: Optional[float] = None   # mean real vertices / subgraph
    n_edges: Optional[float] = None      # mean real edges / subgraph
    # Pack
    sb: Optional[SubgraphBatch] = None
    device: Optional[Dict[str, np.ndarray]] = None
    # Tier (hybrid precompute routing; set by precompute.TierStage)
    tier_rows: Optional[np.ndarray] = None   # [C, f_out] (stale rows 0)
    tier_fresh: Optional[np.ndarray] = None  # [C] bool freshness mask
    tier_done: bool = False       # all-fresh: skip Select/Build/Pack
    online_index: Optional[np.ndarray] = None  # stale slot -> online row
    orig_targets: Optional[np.ndarray] = None  # pre-split target list


def _note_density(plan: BatchPlan) -> None:
    """Batch density stats from the built rows (mean real vertex/edge
    counts per subgraph — the per-batch analogue of the graph-global
    avg_edges the static FLOP mux uses)."""
    if not plan.rows:
        return
    plan.n_vertices = float(np.mean([r.n_vertices for r in plan.rows]))
    plan.n_edges = float(np.mean([r.n_edges for r in plan.rows]))


class PlanStage:
    """One named stage of the host pipeline: ``run`` consumes and returns
    a BatchPlan. ``workers`` is the stage's scheduler parallelism (1 =
    strictly pipelined station)."""

    name = "stage"
    workers = 1

    def run(self, plan: BatchPlan) -> BatchPlan:
        raise NotImplementedError

    def close(self):
        pass


class SelectStage(PlanStage):
    """INI: PPR neighborhoods for the batch's targets, via the
    neighborhood cache when the policy has one. Hit/miss counts cover the
    batch's UNIQUE targets — duplicates collapse into one count, so tail
    padding (pad_targets repeats the last target) cannot inflate the hit
    rate with synthetic traffic. Owns a persistent INI thread pool (the
    paper's 8 host threads) so no pool is constructed per batch."""

    name = "select"

    def __init__(self, engine):
        self.engine = engine
        self._pool = ThreadPoolExecutor(
            max_workers=engine.num_threads,
            thread_name_prefix="ini") if engine.num_threads > 1 else None

    def run(self, plan) -> BatchPlan:
        from repro.core.ini import ini_batch
        if not isinstance(plan, BatchPlan):   # pipeline entry: raw targets
            plan = BatchPlan(targets=np.asarray(plan))
        if plan.tier_done:       # all targets served from the tier —
            return plan          # nothing to select
        eng = self.engine
        cfg = eng.cfg
        n, a, e = cfg.receptive_field, cfg.ppr_alpha, cfg.ppr_eps
        targets = [int(t) for t in plan.targets]
        if eng.sg_cache is not None:
            # row-cache epoch BEFORE any graph read: a Build-stage insert
            # derived from this selection is dropped if an invalidate()
            # lands in between (same contract as the nbr cache put)
            plan.row_gen = eng.sg_cache.generation
        cache = eng.nbr_cache
        # the push frontier rides along whenever ANY cache will store the
        # result — it is both caches' exact invalidation footprint
        need_frontier = cache is not None or eng.sg_cache is not None
        if cache is None:
            computed = ini_batch(eng.graph, targets, n, a, e,
                                 eng.num_threads,
                                 with_frontier=need_frontier,
                                 executor=self._pool)
            if need_frontier:
                plan.node_lists = [nl for nl, _ in computed]
                plan.frontiers = {t: fr for t, (_, fr)
                                  in zip(targets, computed)}
            else:
                plan.node_lists = computed
            return plan
        found, missing = {}, []
        for t in dict.fromkeys(targets):          # unique, order-kept
            ent = cache.get_entry(nbr_key(t, n, a, e))
            if ent is None:
                missing.append(t)
            else:
                found[t] = ent[0]
                plan.frontiers[t] = ent[1]
        if missing:
            gen = cache.generation   # pre-computation epoch: an
            # invalidate() landing mid-push makes put() drop the result
            computed = ini_batch(eng.graph, missing, n, a, e,
                                 eng.num_threads, with_frontier=True,
                                 executor=self._pool)
            for t, (nl, frontier) in zip(missing, computed):
                # the full touched set rides along so invalidate() is
                # exact (an update below the top-N cutoff still drops us)
                cache.put(nbr_key(t, n, a, e), nl,
                          generation=gen, frontier=frontier)
                found[t] = nl
                plan.frontiers[t] = frontier
        plan.node_lists = [found[t] for t in targets]
        plan.nbr_hits = len(found) - len(missing)
        plan.nbr_misses = len(missing)
        tr = eng.tracer
        if tr is not None:           # annotate this batch's select span
            tr.annotate(nbr_hits=plan.nbr_hits,
                        nbr_misses=plan.nbr_misses,
                        n_targets=len(targets))
        return plan

    def close(self):
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None


class BuildStage(PlanStage):
    """Induced-subgraph construction: node lists -> per-target
    SubgraphRows, via the subgraph-row cache when the policy enables it.
    A cache hit skips the build entirely (the ROADMAP's subgraph-row
    caching); hit/miss counts cover unique targets, like Select."""

    name = "build"

    def __init__(self, engine):
        self.engine = engine

    def run(self, plan: BatchPlan) -> BatchPlan:
        if plan.tier_done:
            return plan
        eng = self.engine
        cfg = eng.cfg
        n, e_pad = cfg.receptive_field, eng.e_pad
        targets = [int(t) for t in plan.targets]
        cache = eng.sg_cache
        if cache is None:
            plan.rows = [build_subgraph_rows(eng.graph, nl[:n], n, e_pad)
                         for nl in plan.node_lists]
            _note_density(plan)
            return plan
        built: Dict[int, SubgraphRows] = {}
        hits = 0
        by_target = dict(zip(targets, plan.node_lists))
        for t in dict.fromkeys(targets):          # unique, order-kept
            key = nbr_key(t, n, cfg.ppr_alpha, cfg.ppr_eps)
            rows = cache.get(key)
            if rows is None or rows.adj.shape[0] != n \
                    or rows.edge_src.shape[0] != e_pad:
                rows = build_subgraph_rows(eng.graph, by_target[t][:n],
                                           n, e_pad)
                cache.put(key, rows, generation=plan.row_gen,
                          frontier=plan.frontiers.get(t))
            else:
                hits += 1
            built[t] = rows
        plan.rows = [built[t] for t in targets]
        plan.build_hits = hits
        plan.build_misses = len(built) - hits
        _note_density(plan)
        tr = eng.tracer
        if tr is not None:           # annotate this batch's build span
            tr.annotate(build_hits=hits,
                        build_misses=plan.build_misses)
        return plan


class PackStage(PlanStage):
    """Assemble the fixed-shape SubgraphBatch from the built rows, attach
    the feature-store payload, and account the transfer (what this
    strategy ships vs. what the dense baseline would)."""

    name = "pack"

    def __init__(self, engine):
        self.engine = engine

    def run(self, plan: BatchPlan) -> BatchPlan:
        if plan.tier_done:
            return plan
        if plan.n_edges is None:     # Build ran behind a transport; the
            _note_density(plan)      # rows' scalars crossed the wire
        eng = self.engine
        src = eng._fsource
        n = eng.cfg.receptive_field
        sb = assemble_batch(eng.graph, plan.targets, plan.node_lists,
                            plan.rows, n, eng.e_pad,
                            build_feats=src.needs_host_feats)
        plan.sb = sb
        d = eng.device_batch(sb, include_feats=False)
        payload, dedup = src.host_payload(
            plan.node_lists, n, sb.feats if src.needs_host_feats else None)
        if dedup is not None:
            eng.last_dedup_ratio = dedup
        # transfer accounting: what this strategy ships vs. what the dense
        # baseline would (non-feature arrays + a full [C, N, f_pad] block)
        other = sum(int(a.nbytes) for a in d.values())
        shipped = other + sum(int(a.nbytes) for a in payload.values())
        dense = other + len(plan.node_lists) * n * eng.f_pad * 4
        d.update(payload)
        # sharded store: per-shard share of this payload's bytes (pure
        # function of the payload — safe from concurrent stage threads)
        per_shard = getattr(src, "shard_metrics_for", None)
        eng.scheduler.note_host_metrics(
            bytes_shipped=shipped, bytes_dense=dense,
            cache_hits=plan.nbr_hits, cache_misses=plan.nbr_misses,
            build_hits=plan.build_hits, build_misses=plan.build_misses,
            dedup_ratio=dedup,
            shard_bytes=per_shard(payload) if per_shard else None,
            batch_edges=plan.n_edges)
        plan.device = d
        tr = eng.tracer
        if tr is not None:           # annotate this batch's pack span
            tr.annotate(bytes_shipped=shipped, bytes_dense=dense)
        return plan
