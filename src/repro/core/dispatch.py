"""Per-batch adaptive dense/sg dispatch with measured-cost calibration.

The static mode mux (``specialize(mode="auto")``) picks dense vs
scatter-gather ONCE at engine construction from a FLOP model fed a
graph-global average degree. Real mini-batches are not average: a
sampler that lands on a hub produces a dense induced subgraph while the
next batch is a sparse fringe, and the best mode flips batch to batch
(the paper's ACK mux exists precisely because neither mode wins
everywhere). This module makes the choice **per batch** and **per mux
op**, driven by *measured* step latencies instead of the FLOP model:

- ``DispatchPolicy.decide`` consults the ``CalibrationTable`` p50s at
  the batch's size bucket. Cost comparison is SECTION-level: for each
  program section it enumerates the 2^k mode assignments over that
  section's mux sites, prices each assignment as the sum of measured
  p50s over the steps ``compile_steps`` would actually emit (this is
  what makes it fusion-aware — the Pallas peephole collapses dense
  Aggregate+Residual+Transform into ONE fused step, so dense's measured
  cost includes the fusion win that a per-op comparison cannot see),
  and takes the argmin over assignments whose cells are all populated.
- Cold cells fall back to the FLOP model — fed THIS batch's measured
  density, not the graph-global prior — and consume a **warmup slot**:
  a deterministic seeded schedule (``WarmupSchedule``) that forces one
  instrumented eager pass per slot through all-dense / all-sg mode
  vectors so both columns of the table fill in. Warmup passes discard
  their outputs; serving stays on the fallback decision, so a
  dispatch-enabled run is bitwise-identical to its forced-mode twin.
- ``VariantCache`` bounds the set of live compiled variants: each
  distinct (mode vector, block overrides) pair is one jitted program,
  kept in an LRU of ``variant_capacity`` entries with hit/miss/evict
  counters. Eviction is safe while a batch is in flight because the
  caller holds its own reference to the returned callable.

Sources (telemetry label + report key):
  measured  — every mux site priced from populated table cells
  flop      — at least one site fell back to the FLOP model, and the
              exploration schedule was already exhausted
  warmup    — fallback decision, and this batch consumed a warmup slot
              (an instrumented pass in ``warm_mode`` should run)
  forced    — engine is in a forced mode; the policy never ran
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from itertools import product
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.program import (compile_steps, mux_sites, respecialize,
                                specialize)
from repro.obs.calib import (CalibrationTable, WarmupSchedule, best_block,
                             op_label, op_mode)

SOURCES = ("measured", "flop", "warmup", "forced")


@dataclass(frozen=True)
class DispatchConfig:
    """Per-batch adaptive dispatch knobs (``ServingConfig.dispatch``).

    ``warmup_passes``: instrumented exploration passes per mode side per
    size bucket (so ``2 * warmup_passes`` sampled batches run an extra
    eager pass before the table can go fully measured). 0 disables
    exploration — dispatch then stays on the FLOP fallback unless a
    persisted table supplies the cells.
    ``variant_capacity``: LRU bound on live compiled mode-vector
    variants; each entry is one jitted program (the compile cache grows
    with it), so the default is deliberately small — a k-mux-site
    program has at most 2^k useful variants x a few block choices.
    ``artifact``: directory for table persistence. When it holds a
    committed calibration checkpoint the engine loads it at init
    (stale stamps raise ``CalibrationArtifactError``) and dispatches
    measured from the first batch; with ``save_on_close`` the engine
    writes the table back on ``close()``.
    ``autotune_blocks``: let the calibration loop also time the Pallas
    block-size candidate grids and serve with the measured-best
    ``block_f``/``block_e`` (pallas impl only). Note ``block_e``
    changes fp32 accumulation order — allclose, not bit-identical —
    so bitwise-reproducibility setups should turn this off.
    """
    warmup_passes: int = 4
    seed: int = 0
    variant_capacity: int = 8
    autotune_blocks: bool = True
    artifact: Optional[str] = None
    save_on_close: bool = True

    def __post_init__(self):
        if self.warmup_passes < 0:
            raise ValueError("warmup_passes must be >= 0")
        if self.variant_capacity < 1:
            raise ValueError("variant_capacity must be >= 1 (the engine "
                             "always holds at least the current variant)")

    def describe(self) -> dict:
        return {"warmup_passes": self.warmup_passes, "seed": self.seed,
                "variant_capacity": self.variant_capacity,
                "autotune_blocks": self.autotune_blocks,
                "artifact": self.artifact,
                "save_on_close": self.save_on_close}


@dataclass(frozen=True)
class DispatchDecision:
    """One batch's dispatch outcome."""
    assignment: Dict[str, str]        # mux site -> dense|sg
    site_sources: Dict[str, str]      # mux site -> measured|flop|warmup
    source: str                       # batch-level: measured|flop|warmup
    warm_mode: Optional[str]          # forced mode for an instrumented
    #                                   pass this batch (None = no pass)
    blocks: Dict[str, int]            # kernel block overrides (may be {})
    bucket: int
    avg_edges: float


def variant_key(assignment: Dict[str, str],
                blocks: Dict[str, int]) -> Tuple:
    """Canonical hashable key for one compiled variant."""
    return (tuple(sorted(assignment.items())),
            tuple(sorted((k, v) for k, v in blocks.items()
                         if v is not None)))


class VariantCache:
    """Bounded LRU of compiled program variants.

    Keyed by ``variant_key``; values are the jitted callables. ``get``
    builds on miss OUTSIDE the lock (jit tracing can take hundreds of
    ms — serializing it behind the cache lock would stall concurrent
    device steps), so two threads racing the same cold key may both
    build; the second build is discarded and the cached one returned.
    Evicting an entry that a caller is still executing is safe: the
    caller holds its own reference, eviction only drops the cache's.
    """

    def __init__(self, capacity: int = 8):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self._entries: "OrderedDict[Tuple, Callable]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: Tuple, builder: Callable[[], Callable]) -> Callable:
        with self._lock:
            fn = self._entries.get(key)
            if fn is not None:
                self._entries.move_to_end(key)
                self.hits += 1
                return fn
            self.misses += 1
        fn = builder()
        with self._lock:
            if key not in self._entries:
                self._entries[key] = fn
                while len(self._entries) > self.capacity:
                    self._entries.popitem(last=False)
                    self.evictions += 1
            else:                      # lost the build race — reuse theirs
                self._entries.move_to_end(key)
            return self._entries[key]

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def keys(self) -> List[Tuple]:
        with self._lock:
            return list(self._entries)

    def stats(self) -> dict:
        with self._lock:
            return {"capacity": self.capacity, "size": len(self._entries),
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions}


class DispatchPolicy:
    """Measured-cost per-batch mode selection over one program.

    Holds the program's mux-site list, the live ``CalibrationTable``,
    and the warmup schedule. ``decide`` is cheap on the steady path:
    the section-level 2^k argmin is cached per ``(bucket,
    table.version)``, so once the table stops growing each batch costs
    one dict probe (plus the trivial FLOP fallback arithmetic while any
    section is still cold).
    """

    def __init__(self, program, impl: str, table: CalibrationTable, *,
                 n: int, f_in: int, f_hidden: int,
                 warmup_passes: int = 4, seed: int = 0,
                 autotune_blocks: bool = True):
        self.program = program
        self.impl = impl
        self.table = table
        self.n = int(n)
        self.f_in = int(f_in)
        self.f_hidden = int(f_hidden)
        self.autotune_blocks = bool(autotune_blocks)
        self.warmup = WarmupSchedule(passes=warmup_passes, seed=seed)
        self.sites: Tuple[str, ...] = mux_sites(program)
        self.decisions = 0
        self.source_counts: Dict[str, int] = {s: 0 for s in SOURCES}
        self._lock = threading.Lock()
        # (bucket, table.version) -> partial {site: mode}; measured
        # sections only, missing sites mean "fall back to FLOP"
        self._mcache: Dict[Tuple[int, int], Dict[str, str]] = {}
        self._bcache: Dict[Tuple[int, int], Dict[str, int]] = {}

    # -- section-level measured pricing -------------------------------

    def _section_cost(self, sec: str, assignment: Dict[str, str],
                      bucket: int) -> Optional[float]:
        """Sum of measured p50s over the steps this section compiles to
        under ``assignment``, or None if any step's cell is cold."""
        seq = getattr(respecialize(self.program, assignment), sec)
        total = 0.0
        for ops, _ in compile_steps(seq, self.impl):
            p50 = self.table.lookup(op_label(ops),
                                    op_mode(ops, self.impl), bucket)
            if p50 is None:
                return None
            total += p50
        return total

    def _measured_assignment(self, bucket: int) -> Dict[str, str]:
        """Per-section argmin over fully-priced mode assignments.

        A section joins the result only when >= 2 of its assignments
        price completely — a single priced candidate is not a
        comparison, it is whatever warmup happened to run first."""
        key = (bucket, self.table.version)
        with self._lock:
            hit = self._mcache.get(key)
        if hit is not None:
            return hit
        out: Dict[str, str] = {}
        for sec, _ in self.program.layer_sections():
            sites = [s for s in self.sites if s.startswith(sec)]
            if not sites:
                continue
            priced = []
            for modes in product(("dense", "sg"), repeat=len(sites)):
                asg = dict(zip(sites, modes))
                cost = self._section_cost(sec, asg, bucket)
                if cost is not None:
                    priced.append((cost, sorted(asg.items())))
            if len(priced) >= 2:
                out.update(dict(min(priced)[1]))
        with self._lock:
            self._mcache[key] = out
            # stale versions of the same bucket are dead weight
            for k in [k for k in self._mcache
                      if k[0] == bucket and k != key]:
                del self._mcache[k]
        return out

    def _flop_assignment(self, avg_edges: float) -> Dict[str, str]:
        """Static-model fallback, fed the BATCH's measured density."""
        _, dec = specialize(self.program, n=self.n, avg_edges=avg_edges,
                            f_in=self.f_in, f_hidden=self.f_hidden)
        return {d.site: d.mode for d in dec.ops if d.mux}

    # -- block autotune consumption -----------------------------------

    def _blocks(self, bucket: int) -> Dict[str, int]:
        if not (self.autotune_blocks and self.impl == "pallas"):
            return {}
        key = (bucket, self.table.version)
        with self._lock:
            hit = self._bcache.get(key)
        if hit is not None:
            return hit
        from repro.kernels.fused_gnn import BLOCK_F_CANDIDATES
        from repro.kernels.scatter_gather import BLOCK_E_CANDIDATES
        out = {}
        bf = best_block(self.table, "fused_gnn", "bf=",
                        BLOCK_F_CANDIDATES, bucket)
        if bf is not None:
            out["block_f"] = bf
        be = best_block(self.table, "scatter_gather", "be=",
                        BLOCK_E_CANDIDATES, bucket)
        if be is not None:
            out["block_e"] = be
        with self._lock:
            self._bcache[key] = out
            for k in [k for k in self._bcache
                      if k[0] == bucket and k != key]:
                del self._bcache[k]
        return out

    # -- the per-batch entry point ------------------------------------

    def decide(self, avg_edges: float, bucket: int) -> DispatchDecision:
        measured = self._measured_assignment(bucket)
        cold = [s for s in self.sites if s not in measured]
        warm = None
        if cold:
            flop = self._flop_assignment(avg_edges)
            warm = self.warmup.next_mode(bucket)
            fallback_src = "warmup" if warm is not None else "flop"
            assignment = {s: measured.get(s, flop[s]) for s in self.sites}
            site_sources = {s: ("measured" if s in measured
                                else fallback_src) for s in self.sites}
            source = fallback_src
        else:
            assignment = dict(measured)
            site_sources = {s: "measured" for s in self.sites}
            source = "measured"
        with self._lock:
            self.decisions += 1
            self.source_counts[source] += 1
        return DispatchDecision(
            assignment=assignment, site_sources=site_sources,
            source=source, warm_mode=warm,
            blocks=self._blocks(bucket), bucket=bucket,
            avg_edges=float(avg_edges))

    def report(self) -> dict:
        with self._lock:
            counts = dict(self.source_counts)
            decisions = self.decisions
        return {"policy": "measured-cost", "impl": self.impl,
                "mux_sites": list(self.sites), "decisions": decisions,
                "sources": counts, "warmup": self.warmup.state(),
                "table_cells": len(self.table),
                "table_passes": self.table.passes}


__all__ = ["DispatchConfig", "DispatchDecision", "DispatchPolicy",
           "VariantCache", "variant_key", "SOURCES"]
