"""Decoupled mini-batch GNN inference engine (paper Algorithm 2 + 3).

Host side: INI (PPR local push) + induced-subgraph construction into
fixed-shape padded batches. Device side: one jitted program per
(model, N, C) executing L layers through the ACK (dense or scatter-gather
mode; XLA or Pallas implementation) and the Readout. The fixed shapes are
the decoupling dividend: ONE compiled program serves every batch — the
paper's "single accelerator, no reconfiguration" property.

``DecoupledEngine.infer`` overlaps host preparation of batch i+1 with
device execution of batch i via core.scheduler (paper Fig. 7). The engine
owns ONE persistent ``PipelineScheduler`` for its whole lifetime — batch
and streaming calls share its host pool, dispatcher, and cumulative stats,
so serving never pays per-call pipeline construction.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ack import AckDecision, choose_mode
from repro.core.scheduler import (PipelineScheduler, SchedulerStats,
                                  StreamTicket)
from repro.core.subgraph import SubgraphBatch, default_edge_pad
from repro.gnn.layers import readout
from repro.gnn.model import GNNConfig, gnn_forward, init_gnn
from repro.graphs.csr import CSRGraph
from repro.kernels import ops


def _pad128(f: int) -> int:
    return f + (-f) % 128


def _pallas_layer(cfg: GNNConfig, kind_first: bool):
    """Build an inner-layer apply using the Pallas ACK kernels."""

    def apply(p, h, batch):
        adj, adj_mean, mask = batch["adj"], batch["adj_mean"], batch["mask"]
        if cfg.kind == "gcn":
            return ops.fused_gnn_layer(adj, h, p["w"], None, p["b"], mask,
                                       act="relu")
        if cfg.kind == "sage":
            return ops.fused_gnn_layer(adj_mean, h, p["w_neigh"],
                                       p["w_self"], p["b"], mask,
                                       act="relu")
        if cfg.kind == "gin":
            n = h.shape[1]
            a_gin = jnp.sign(adj_mean) + \
                (1.0 + p["eps"]) * jnp.eye(n, dtype=h.dtype)
            hid = ops.fused_gnn_layer(a_gin, h, p["w1"], None, p["b1"],
                                      mask, act="relu")
            return ops.fused_gnn_layer(adj, hid, None, p["w2"], p["b2"],
                                       mask, act="relu")
        if cfg.kind == "gat":
            nh = cfg.n_heads
            z = ops.fused_gnn_layer(adj, h, None, p["w"], None, mask,
                                    act="none")
            s_src = jnp.einsum("cnhf,hf->cnh",
                               z.reshape(*z.shape[:2], nh, -1), p["a_src"])
            s_dst = jnp.einsum("cnhf,hf->cnh",
                               z.reshape(*z.shape[:2], nh, -1), p["a_dst"])
            n = h.shape[1]
            struct = (jnp.sign(adj_mean) + jnp.eye(n, dtype=h.dtype)) \
                * mask[:, None, :]
            out = ops.gat_attention(z, s_src, s_dst, struct, n_heads=nh)
            return jax.nn.elu(out + p["b"]) * mask[..., None]
        raise ValueError(cfg.kind)

    return apply


@dataclass
class InferenceResult:
    embeddings: np.ndarray           # [num_targets, f]
    stats: Optional[SchedulerStats]
    decision: AckDecision


class DecoupledEngine:
    """One engine instance = one (graph, model, batch-size) deployment."""

    def __init__(self, graph: CSRGraph, cfg: GNNConfig, params=None, *,
                 batch_size: int = 64, mode: str = "auto",
                 impl: str = "xla", num_threads: int = 8, seed: int = 0,
                 e_pad: Optional[int] = None, dedup_features: bool = False):
        self.graph, self.cfg = graph, cfg
        self.batch_size = batch_size
        self.num_threads = num_threads
        self.impl = impl
        self.dedup_features = dedup_features
        self.last_dedup_ratio = None
        n = cfg.receptive_field
        self.e_pad = e_pad or default_edge_pad(graph, n)
        avg_edges = min(self.e_pad, n * float(graph.degrees.mean()))
        self.decision = choose_mode(n, avg_edges, cfg.f_hidden,
                                    None if mode == "auto" else mode)
        self.mode = self.decision.mode
        if params is None:
            params = init_gnn(cfg, jax.random.PRNGKey(seed))
        self.params = params
        self.f_pad = _pad128(cfg.f_in) if impl == "pallas" else cfg.f_in
        if self.f_pad != cfg.f_in:
            # MXU alignment: zero-pad layer0 input-rows to match the padded
            # feature columns (padded features are zero, so this is exact)
            pad = self.f_pad - cfg.f_in
            l0 = dict(params["layer0"])
            for k in ("w", "w_self", "w_neigh", "w1"):
                if k in l0:
                    l0[k] = jnp.pad(l0[k], ((0, pad), (0, 0)))
            self.params = dict(params, layer0=l0)
        self._infer = jax.jit(functools.partial(self._forward))
        # one pipeline per deployment (paper: one accelerator config, no
        # per-batch reconfiguration); lazily started on first use
        self.scheduler = PipelineScheduler(self.prepare, self.run_device,
                                           depth=3)

    # -- device program ----------------------------------------------------
    def _forward(self, params, batch: Dict[str, jax.Array]):
        cfg = self.cfg
        if self.impl == "pallas" and self.mode == "dense":
            apply = _pallas_layer(cfg, kind_first=True)
            h = apply(params["layer0"], batch["feats"], batch)
            if cfg.n_layers > 1:
                def body(hh, lp):
                    return apply(lp, hh, batch), None
                h, _ = jax.lax.scan(body, h, params["layers"])
            emb = readout(h, batch["mask"], cfg.readout)
            if cfg.num_classes:
                emb = emb @ params["cls_w"] + params["cls_b"]
            return emb
        emb, _ = gnn_forward(cfg, params, batch, mode=self.mode)
        return emb

    # -- host side ----------------------------------------------------------
    def prepare(self, targets) -> Dict[str, np.ndarray]:
        from repro.core.ini import ini_batch
        from repro.core.subgraph import (batch_from_node_lists,
                                         packed_features)
        node_lists = ini_batch(self.graph, targets,
                               self.cfg.receptive_field,
                               self.cfg.ppr_alpha, self.cfg.ppr_eps,
                               self.num_threads)
        sb = batch_from_node_lists(self.graph, targets, node_lists,
                                   self.cfg.receptive_field, self.e_pad)
        d = self.device_batch(sb)
        if self.dedup_features:
            uniq, idx, ratio = packed_features(
                node_lists, self.graph, self.cfg.receptive_field)
            self.last_dedup_ratio = ratio
            del d["feats"]               # ship packed form instead
            d["uniq_feats"], d["feat_idx"] = uniq, idx
        return d

    def device_batch(self, sb: SubgraphBatch) -> Dict[str, np.ndarray]:
        d = dict(feats=sb.feats, adj=sb.adj, adj_mean=sb.adj_mean,
                 mask=sb.mask)
        if self.f_pad != self.cfg.f_in:
            d["feats"] = np.pad(sb.feats,
                                ((0, 0), (0, 0),
                                 (0, self.f_pad - self.cfg.f_in)))
        if self.mode == "sg":
            n = sb.n
            self_w = sb.adj[:, np.arange(n), np.arange(n)]
            indeg = np.einsum("cij->ci", (sb.adj_mean > 0).astype(np.float32))
            d.update(edge_src=sb.edge_src, edge_dst=sb.edge_dst,
                     edge_w=sb.edge_w, self_w=self_w.astype(np.float32))
            valid = sb.edge_w != 0
            dst_deg = np.take_along_axis(
                np.maximum(indeg, 1.0), sb.edge_dst.astype(np.int64), axis=1)
            d["edge_w_mean"] = np.where(valid, 1.0 / dst_deg, 0.0
                                        ).astype(np.float32)
        return d

    def run_device(self, device_batch) -> jax.Array:
        if "uniq_feats" in device_batch:
            device_batch = dict(device_batch)
            uniq = jnp.asarray(device_batch.pop("uniq_feats"))
            idx = jnp.asarray(device_batch.pop("feat_idx"))
            feats = jnp.take(uniq, idx, axis=0)      # device-side gather
            if self.f_pad != self.cfg.f_in:
                feats = jnp.pad(feats, ((0, 0), (0, 0),
                                        (0, self.f_pad - self.cfg.f_in)))
            device_batch["feats"] = feats
        if self.f_pad != self.cfg.f_in and self.cfg.f_in == \
                device_batch["feats"].shape[-1]:
            device_batch = dict(device_batch)
            device_batch["feats"] = np.pad(
                device_batch["feats"],
                ((0, 0), (0, 0), (0, self.f_pad - self.cfg.f_in)))
        return self._infer(self.params, device_batch)

    # -- end-to-end ----------------------------------------------------------
    def pad_targets(self, targets: np.ndarray) -> np.ndarray:
        """Pad a tail chunk to the engine's fixed batch size C by repeating
        the last target (fixed shapes keep the one compiled program)."""
        C = self.batch_size
        targets = np.asarray(targets)
        if len(targets) == C:
            return targets
        if len(targets) > C or len(targets) == 0:
            raise ValueError(f"chunk size {len(targets)} vs C={C}")
        return np.concatenate(
            [targets, np.repeat(targets[-1:], C - len(targets))])

    def submit_chunk(self, targets, on_done=None) -> StreamTicket:
        """Streaming entry: enqueue ONE micro-batch (≤ C targets, tail is
        padded) on the persistent pipeline; returns a StreamTicket whose
        result is the [C, f] embedding block."""
        return self.scheduler.submit(self.pad_targets(np.asarray(targets)),
                                     on_done=on_done)

    def infer(self, targets, overlap: bool = True) -> InferenceResult:
        """Mini-batch inference for arbitrary #targets (chunks of C)."""
        targets = np.asarray(targets)
        C = self.batch_size
        chunks = [self.pad_targets(targets[i:i + C])
                  for i in range(0, len(targets), C)]
        outs, stats = self.scheduler.run(chunks, overlap=overlap)
        emb = np.concatenate([np.asarray(o) for o in outs], axis=0)
        return InferenceResult(embeddings=emb[:len(targets)], stats=stats,
                               decision=self.decision)

    def close(self):
        self.scheduler.close()

    def __enter__(self) -> "DecoupledEngine":
        return self

    def __exit__(self, *exc):
        self.close()
