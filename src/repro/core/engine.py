"""Decoupled mini-batch GNN inference engine (paper Algorithm 2 + 3).

Host side: a staged **BatchPlan pipeline** (core.batchplan) — Select (PPR
neighborhoods via the nbr cache), Build (induced-subgraph rows via the
subgraph-row cache), Pack (store payload + transfer accounting) — each a
named stage the scheduler pipelines across consecutive batches. Device
side: one jitted AckProgram per (model, N, C) — the model's registered
lowering (core.program) executed through the ACK kernels with a PER-OP
dense/scatter-gather mux (XLA or Pallas implementation) and the Readout.
The fixed shapes are the decoupling dividend: ONE compiled program serves
every batch — the paper's "single accelerator, no reconfiguration"
property.

``DecoupledEngine.infer`` overlaps host preparation of batch i+1 with
device execution of batch i via core.scheduler (paper Fig. 7). The engine
owns ONE persistent ``PipelineScheduler`` for its whole lifetime — batch
and streaming calls share its stage workers, dispatcher, and cumulative
stats, so serving never pays per-call pipeline construction.
"""
from __future__ import annotations

import functools
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batchplan import (BatchPlan, BuildStage, PackStage,
                                  SelectStage)
from repro.core.config import ServingConfig
from repro.core.program import (ProgramDecision, execute,
                                input_width_params, lower,
                                required_adjacency, specialize)
from repro.core.scheduler import (PipelineScheduler, SchedulerStats,
                                  StreamTicket)
from repro.core.subgraph import SubgraphBatch, default_edge_pad
from repro.gnn.model import GNNConfig, init_gnn
from repro.graphs.csr import CSRGraph
from repro.store import NeighborhoodCache, StorePolicy, build_feature_source
from repro.store.feature_store import pad_feature_dim
from repro.store.nbr_cache import SubgraphRowCache


def _pad128(f: int) -> int:
    return f + (-f) % 128


@dataclass
class InferenceResult:
    embeddings: np.ndarray           # [num_targets, f]
    stats: Optional[SchedulerStats]
    decision: ProgramDecision        # per-op mode decisions + summary


class DecoupledEngine:
    """One engine instance = one (graph, model, batch-size) deployment."""

    def __init__(self, graph: CSRGraph, cfg: GNNConfig, params=None,
                 config: Optional[ServingConfig] = None, **legacy):
        """``config=ServingConfig(...)`` is the constructor surface; the
        legacy per-kwarg spellings (batch_size=, impl=, store=, ...) are
        routed through ``ServingConfig.from_kwargs`` and deprecated."""
        if legacy:
            config = ServingConfig.from_kwargs(base=config, **legacy)
        elif config is None:
            config = ServingConfig()
        self.config = config
        self.graph, self.cfg = graph, cfg
        # observability (off by default, zero-cost when off: every site
        # downstream guards on ``tracer is None``)
        if config.trace is not None:
            from repro.obs.calib import CalibrationTable
            from repro.obs.trace import Tracer
            self.tracer = Tracer(config.trace)
            self._calib = CalibrationTable()
        else:
            self.tracer = None
            self._calib = None
        self._calib_count = 0
        # live telemetry plane (same contract: off by default, every
        # hot-path site guards on ``telemetry is None``)
        if config.telemetry is not None:
            from repro.obs.metrics import Telemetry
            self.telemetry = Telemetry(config.telemetry, host="client")
            self._h_gather = self.telemetry.whist(
                "repro_store_gather_seconds",
                help="device-side feature gather wall time")
        else:
            self.telemetry = None
            self._h_gather = None
        self.batch_size = config.batch_size
        self.num_threads = config.num_threads
        self.impl = config.impl
        mode = config.mode
        store = config.store
        self.store_policy = store
        self.dedup_features = store.features == "packed"
        self.last_dedup_ratio = None
        n = cfg.receptive_field
        self.e_pad = config.e_pad or default_edge_pad(graph, n)
        avg_edges = min(self.e_pad, n * float(graph.degrees.mean()))
        # graph-global degree estimate, re-seeded by the FIRST measured
        # batch density from the Build stage (run_device) — the measured
        # number is what per-batch dispatch and reports key on
        self.avg_edges_prior = avg_edges
        self._density_seeded = False
        # compile the model through the lowering registry, then set each
        # op's mode mux from ITS kernel's FLOP model (mode="auto") or the
        # caller's force — a single program may mix sg aggregation with
        # dense (systolic) transforms
        self.program, self.decision = specialize(
            lower(cfg), n=n, avg_edges=avg_edges, f_in=cfg.f_in,
            f_hidden=cfg.f_hidden,
            force=None if mode == "auto" else mode)
        self.mode = self.decision.mode
        self.needs_edges = any(d.mode == "sg" for d in self.decision)
        # ship only the adjacency arrays the specialized program reads
        # (an all-sg aggregation path ships none — just the edge list)
        self.adj_keys = required_adjacency(self.program)
        # per-batch adaptive dispatch (core.dispatch): only meaningful
        # with mode="auto" — a forced mode pins the mux, so the policy
        # never runs there (counters still label those batches "forced")
        dconf = config.dispatch
        self.dispatch = None
        self._variants = None
        self._disp_counters: Dict = {}
        self._forced_dispatch = 0
        self._last_blocks: Dict[str, int] = {}
        self._static_assignment = {d.site: d.mode
                                   for d in self.decision if d.mux}
        if dconf is not None and mode == "auto":
            from repro.core.dispatch import DispatchPolicy, VariantCache
            from repro.obs.calib import CalibrationTable
            table = self._calib if self._calib is not None \
                else CalibrationTable()
            if dconf.artifact is not None:
                from repro.ckpt.checkpoint import committed_steps
                from repro.obs.calib import load_calibration
                if committed_steps(dconf.artifact):
                    # a committed table dispatches MEASURED from the
                    # first batch (warmup is skipped — its cells are
                    # already populated); stale stamps raise here
                    table = load_calibration(dconf.artifact, graph=graph,
                                             cfg=cfg, impl=self.impl)
            self._calib = table
            self.dispatch = DispatchPolicy(
                self.program, self.impl, table, n=n, f_in=cfg.f_in,
                f_hidden=cfg.f_hidden,
                warmup_passes=dconf.warmup_passes, seed=dconf.seed,
                autotune_blocks=dconf.autotune_blocks)
            self._variants = VariantCache(dconf.variant_capacity)
            # adaptive payload union: ANY per-batch mode vector must
            # find its arrays in the device batch, so ship the
            # conservative (unspecialized) adjacency set + the edge
            # list. Extra unused keys do not change jit outputs.
            self.adj_keys = required_adjacency(lower(cfg))
            self.needs_edges = True
        if params is None:
            params = init_gnn(cfg, jax.random.PRNGKey(config.seed))
        self.params = params
        self.f_pad = _pad128(cfg.f_in) if self.impl == "pallas" \
            else cfg.f_in
        if self.f_pad != cfg.f_in:
            # MXU alignment: zero-pad layer0 input-rows to match the padded
            # feature columns (padded features are zero, so this is exact).
            # WHICH weights are f_in-sized is read off the lowered program
            # (registry contract: custom kinds need no engine edits)
            pad = self.f_pad - cfg.f_in
            l0 = dict(params["layer0"])
            for k in input_width_params(self.program):
                l0[k] = jnp.pad(l0[k], ((0, pad), (0, 0)))
            self.params = dict(params, layer0=l0)
        self._infer = jax.jit(functools.partial(self._forward))
        self._fsource = build_feature_source(graph, store, self.f_pad)
        if config.remote:
            # multi-host deployment: Select/Build run on graph hosts
            # behind the transport (distributed.rpc); the nbr/row caches
            # live WITH the graph over there, Pack + device execution
            # stay here where the feature store and compiled program are
            from repro.distributed.rpc import (RemoteSelectBuildStage,
                                               build_host_pool)
            self.nbr_cache = None
            self.sg_cache = None
            self._host_pool = build_host_pool(config, graph=graph)
            self.stages = [RemoteSelectBuildStage(
                self, self._host_pool,
                workers=config.rpc_concurrency), PackStage(self)]
            if self.tracer is not None:
                # ping-based clock-offset estimate per graph host, so
                # their spans stitch onto this process's timeline
                from repro.distributed.rpc import estimate_clock_offsets
                self.tracer.clock_sync = estimate_clock_offsets(
                    self._host_pool)
        else:
            self._host_pool = None
            self.nbr_cache = self._build_nbr_cache(store)
            # Build-stage subgraph-row cache ("auto": rows are cached
            # whenever neighborhoods are — hot traffic that re-selects
            # also re-builds). Unlike node lists, one entry is ~2N^2
            # floats + the edge arrays, so the default capacity is
            # BYTE-bounded (subgraph_budget_bytes), not inherited from
            # nbr_capacity alone.
            if store.cache_subgraph_rows:
                cap = store.subgraph_capacity
                if cap is None:
                    entry = 2 * n * n * 4 + 2 * n * 4 + 4 * self.e_pad * 4
                    cap = max(1, min(store.nbr_capacity,
                                     store.subgraph_budget_bytes // entry))
                self.sg_cache = SubgraphRowCache(cap)
            else:
                self.sg_cache = None
            # the host side as an explicit staged pipeline (Select ->
            # Build -> Pack, see core.batchplan); prepare() runs the same
            # stages serially, so the staged path is the monolithic one
            # by construction
            self.stages = [SelectStage(self), BuildStage(self),
                           PackStage(self)]
        # offline precompute tier (hybrid serving): build or load the
        # layer-major embedding table and prepend the TierStage router —
        # tier-fresh targets skip Select/Build/Pack entirely, stale/cold
        # targets ride the online pipeline above. Note ``params`` (the
        # local) is the UNPADDED parameter tree — offline propagation
        # runs on unpadded features
        pconf = config.precompute
        if pconf is not None and (pconf.models is None
                                  or cfg.kind in pconf.models):
            from repro.precompute.manager import (PrecomputeManager,
                                                  TierStage)
            self.precompute = PrecomputeManager(self, pconf, params)
            self.stages = [TierStage(self)] + self.stages
        else:
            self.precompute = None
        # auto-repin trigger state (StorePolicy.repin_every / _hit_floor)
        self._repin_auto = bool(store.repin_every or store.repin_hit_floor)
        self._repin_lock = threading.Lock()
        self._repin_batches = 0
        self._repin_base = (0, 0)       # (lookups, resident) at last repin
        # floor-trigger backoff: when the hit rate stays below the floor
        # even after a repin (working set > budget), checks space out
        # exponentially instead of rebuilding the table every batch
        self._floor_batches = 0
        self._floor_wait = 1
        # repins execute on their own single worker — NEVER on the
        # scheduler's dispatcher thread, where a table rebuild would
        # stall completion of every in-flight batch
        self._repin_pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="repin") \
            if self._repin_auto else None
        self.auto_repins = 0
        # one pipeline per deployment (paper: one accelerator config, no
        # per-batch reconfiguration); lazily started on first use
        self.scheduler = PipelineScheduler(
            self.stages, self.run_device, depth=config.depth,
            max_inflight=config.max_inflight,
            on_batch=self._on_batch_done if self._repin_auto else None,
            tracer=self.tracer, telemetry=self.telemetry)
        if self.telemetry is not None:
            self._register_metrics()
        # graph-update streaming: CSRGraph.apply_edge_updates notifies us
        # so cached neighborhoods / resident rows never serve stale state
        if hasattr(graph, "register_listener"):
            graph.register_listener(self.invalidate)

    def _build_nbr_cache(self, policy: StorePolicy
                         ) -> Optional[NeighborhoodCache]:
        if policy.nbr_cache == "none":
            return None
        pinned = None
        if policy.nbr_cache == "pinned":
            pinned = policy.pinned_targets
            if pinned is None:
                # default hot set: top-degree targets (hub-heavy traffic
                # hits them most under Zipf skew)
                k = min(self.graph.num_vertices,
                        policy.pinned_count or
                        max(1, policy.nbr_capacity // 4))
                pinned = np.argpartition(self.graph.degrees, -k)[-k:]
        return NeighborhoodCache(policy.nbr_capacity, pinned_targets=pinned)

    def _register_metrics(self):
        """Join the existing subsystem counters to the telemetry plane
        as collect-time callbacks: the hot path increments nothing
        twice — the registry samples each source at scrape/report time,
        so metered serving stays bitwise-identical to unmetered."""
        reg = self.telemetry.registry
        stats = self.scheduler.stats
        src = self._fsource
        if self.nbr_cache is not None:
            c = self.nbr_cache
            reg.counter_fn("repro_nbr_cache_hits_total",
                           lambda: c.hits, help="neighborhood cache hits")
            reg.counter_fn("repro_nbr_cache_misses_total",
                           lambda: c.misses,
                           help="neighborhood cache misses")
            reg.counter_fn("repro_nbr_cache_evictions_total",
                           lambda: c.evictions,
                           help="neighborhood cache evictions")
        if self.sg_cache is not None:
            rc = self.sg_cache
            reg.counter_fn("repro_row_cache_hits_total",
                           lambda: rc.hits,
                           help="subgraph-row cache hits")
            reg.counter_fn("repro_row_cache_misses_total",
                           lambda: rc.misses,
                           help="subgraph-row cache misses")
        if hasattr(src, "lookups"):
            reg.counter_fn("repro_store_lookups_total",
                           lambda: src.lookups,
                           help="feature rows resolved")
            reg.counter_fn("repro_store_resident_lookups_total",
                           lambda: src.resident_lookups,
                           help="feature rows served device-resident")
        reg.counter_fn("repro_store_bytes_shipped_total",
                       lambda: stats.bytes_shipped,
                       help="host->device bytes actually shipped")
        reg.counter_fn("repro_store_bytes_dense_total",
                       lambda: stats.bytes_dense,
                       help="dense-baseline host->device bytes")
        if self._repin_auto:
            reg.counter_fn("repro_auto_repins_total",
                           lambda: self.auto_repins,
                           help="automatic residency rebalances")
        if self.dispatch is not None:
            pol, vc = self.dispatch, self._variants
            reg.counter_fn("repro_dispatch_decisions_total",
                           lambda: pol.decisions,
                           help="per-batch dispatch decisions taken")
            reg.counter_fn("repro_variant_cache_hits_total",
                           lambda: vc.hits,
                           help="compiled-variant cache hits")
            reg.counter_fn("repro_variant_cache_misses_total",
                           lambda: vc.misses,
                           help="compiled-variant cache misses (builds)")
            reg.counter_fn("repro_variant_cache_evictions_total",
                           lambda: vc.evictions,
                           help="compiled variants evicted (LRU bound)")
            reg.gauge_fn("repro_variant_cache_size", lambda: len(vc),
                         help="live compiled variants (<= capacity)")
        if self.precompute is not None:
            tier, mgr = self.precompute.tier, self.precompute
            reg.counter_fn("repro_tier_hits_total", lambda: tier.hits,
                           help="embedding-tier fresh hits")
            reg.counter_fn("repro_tier_misses_total",
                           lambda: tier.misses,
                           help="embedding-tier misses (online path)")
            reg.counter_fn("repro_tier_demotions_total",
                           lambda: tier.demotions,
                           help="tier rows demoted by invalidation")
            reg.counter_fn("repro_tier_promotions_total",
                           lambda: tier.promotions,
                           help="tier rows re-promoted by refresh")
            reg.counter_fn("repro_refresh_chunks_total",
                           lambda: mgr.refresh_chunks,
                           help="background refresh chunks completed")
            reg.counter_fn("repro_refresh_errors_total",
                           lambda: mgr.refresh_errors,
                           help="background refresh chunk failures")
            reg.gauge_fn("repro_refresh_backlog",
                         lambda: len(mgr._backlog),
                         help="vertices awaiting tier refresh")
        if self._host_pool is not None:
            reg.counter_fn("repro_rpc_calls_total",
                           lambda: stats.rpc_calls,
                           help="remote stage calls")
            reg.counter_fn("repro_rpc_retries_total",
                           lambda: stats.rpc_retries,
                           help="remote stage call retries")
            reg.counter_fn("repro_rpc_timeouts_total",
                           lambda: stats.rpc_timeouts,
                           help="remote stage call timeouts")
            reg.counter_fn("repro_rpc_errors_total",
                           lambda: stats.rpc_errors,
                           help="remote stage call errors")
            reg.counter_fn("repro_rpc_bytes_out_total",
                           lambda: stats.rpc_bytes_out,
                           help="bytes sent to graph hosts")
            reg.counter_fn("repro_rpc_bytes_in_total",
                           lambda: stats.rpc_bytes_in,
                           help="bytes received from graph hosts")
            quarantines = self.telemetry.counter(
                "repro_host_quarantines_total",
                help="graph-host quarantine episodes")
            events = self.telemetry.events

            def _on_quarantine(endpoint: str):
                quarantines.inc()
                events.emit("host_quarantine", severity="warn",
                            message=f"graph host {endpoint} quarantined",
                            endpoint=endpoint)

            self._host_pool.on_quarantine = _on_quarantine

    # -- device program ----------------------------------------------------
    def _forward(self, params, batch: Dict[str, jax.Array]):
        emb, _ = execute(self.program, params, batch, impl=self.impl)
        return emb

    # -- host side ----------------------------------------------------------
    def _pad_feature_dim(self, feats):
        """Engine-facing entry to the single padding implementation
        (store.feature_store.pad_feature_dim) bound to this engine's
        f_pad — prepare/device_batch/run_device all route through it."""
        return pad_feature_dim(feats, self.f_pad)

    def _node_lists(self, targets):
        """PPR neighborhoods for a batch, via the neighborhood cache when
        the policy has one — the Select stage's back-compat spelling.
        Returns (node_lists, hits, misses) counted over the batch's
        UNIQUE targets."""
        plan = self.stages[0].run(BatchPlan(targets=np.asarray(targets)))
        return plan.node_lists, plan.nbr_hits, plan.nbr_misses

    def plan(self, targets) -> BatchPlan:
        """Run the host pipeline's stages back-to-back on the caller
        thread and return the full BatchPlan artifact (the staged
        decomposition of the old monolithic prepare()).

        Note: for resident/sharded stores the packed payload PINS the
        store's current residency generation until it is consumed by
        ``run_device`` (that is what keeps in-flight batches coherent
        across ``repin()``) — feed ``plan.device`` to ``run_device`` or
        avoid repinning while holding abandoned plans."""
        plan = BatchPlan(targets=np.asarray(targets))
        for stage in self.stages:
            plan = stage.run(plan)
        return plan

    def prepare(self, targets) -> Dict[str, np.ndarray]:
        """Monolithic host prep (all stages serially): the one-call
        spelling of the staged pipeline, bitwise-identical to it."""
        return self.plan(targets).device

    def device_batch(self, sb: SubgraphBatch,
                     include_feats: bool = True) -> Dict[str, np.ndarray]:
        d = {"mask": sb.mask}
        for k in self.adj_keys:     # only what the compiled program reads
            d[k] = sb.adj if k == "adj" else sb.adj_mean
        if include_feats:
            d["feats"] = self._pad_feature_dim(sb.feats)
        if self.needs_edges:
            if sb.self_w is not None and sb.edge_w_mean is not None:
                # Build-stage extras, computed from the CSR edge lists
                d.update(edge_src=sb.edge_src, edge_dst=sb.edge_dst,
                         edge_w=sb.edge_w, self_w=sb.self_w,
                         edge_w_mean=sb.edge_w_mean)
            else:
                # externally constructed batch without the carried
                # extras: recover them from the dense adjacency
                n = sb.n
                self_w = sb.adj[:, np.arange(n), np.arange(n)]
                indeg = np.einsum("cij->ci",
                                  (sb.adj_mean > 0).astype(np.float32))
                d.update(edge_src=sb.edge_src, edge_dst=sb.edge_dst,
                         edge_w=sb.edge_w,
                         self_w=self_w.astype(np.float32))
                valid = sb.edge_w != 0
                dst_deg = np.take_along_axis(
                    np.maximum(indeg, 1.0), sb.edge_dst.astype(np.int64),
                    axis=1)
                d["edge_w_mean"] = np.where(valid, 1.0 / dst_deg, 0.0
                                            ).astype(np.float32)
        return d

    def run_device(self, device_batch) -> jax.Array:
        plan = device_batch if isinstance(device_batch, BatchPlan) \
            else None                             # staged pipeline output
        if plan is not None:
            if plan.tier_done:
                # all-fresh fast path: the tier row gather IS the
                # answer — no device program runs for this batch
                return plan.tier_rows
            device_batch = plan.device
        db = dict(device_batch)
        src = self._fsource
        tr = self.tracer
        if all(k in db for k in src.payload_keys):
            payload = {k: db.pop(k) for k in src.payload_keys}
            tg = time.perf_counter() if self._h_gather is not None \
                else 0.0
            if tr is None:
                feats = src.device_feats(payload)
            else:
                # child of the scheduler's "device" span (thread-local
                # parent); no-ops when this batch is untraced
                with tr.span("store.gather", cat="store",
                             store=src.name):
                    feats = src.device_feats(payload)
            if self._h_gather is not None:
                self._h_gather.record(time.perf_counter() - tg)
        else:       # externally built dense batch (e.g. device_batch())
            feats = db["feats"]
        db["feats"] = self._pad_feature_dim(feats)
        if tr is not None and tr.config.calibrate_every \
                and tr.current() is not None:
            # sampled instrumented eager per-op pass (obs.calib): its
            # outputs are DISCARDED — the jitted result below is what
            # gets served, so outputs stay bitwise-identical
            self._calib_count += 1
            if self._calib_count % tr.config.calibrate_every == 0:
                from repro.obs.calib import run_instrumented
                try:
                    with tr.span("calibrate", cat="calib"):
                        run_instrumented(self.program, self.params, db,
                                         self.impl, self._calib)
                except Exception:    # calibration must never break
                    pass             # serving
        if plan is not None and not self._density_seeded \
                and plan.n_edges is not None:
            # first measured batch density replaces the degree-based
            # construction-time estimate as the engine's prior
            self._density_seeded = True
            self.avg_edges_prior = min(float(plan.n_edges),
                                       float(self.e_pad))
        if self.dispatch is not None and plan is not None \
                and plan.n_edges is not None:
            out = self._dispatch_infer(plan, db)
        else:
            if self.config.dispatch is not None \
                    and self.dispatch is None:
                # forced mode with dispatch telemetry requested: the
                # policy never runs, but the mode counters still tell
                # the operator WHAT served and WHY ("forced")
                self._forced_dispatch += 1
                self._count_dispatch(self._static_assignment,
                                     {s: "forced"
                                      for s in self._static_assignment})
            out = self._infer(self.params, db)
        if plan is not None and plan.online_index is not None:
            # mixed batch: the online program ran on the stale targets
            # only (padded) — rejoin with the tier rows on the original
            # slot order. Stays a lazy jax expression: dispatch remains
            # async, the scheduler's device station is not stalled.
            out = jnp.where(jnp.asarray(plan.tier_fresh)[:, None],
                            jnp.asarray(plan.tier_rows),
                            out[jnp.asarray(plan.online_index)])
        return out

    # -- per-batch adaptive dispatch ----------------------------------------
    def _count_dispatch(self, assignment: Dict[str, str],
                        sources: Dict[str, str]) -> None:
        """Per-mux-op dispatch counters:
        ``repro_dispatch_total{op,mode,source}``. Counter handles are
        cached per label set so the hot path pays one dict probe."""
        if self.telemetry is None:
            return
        for site, m in assignment.items():
            key = (site, m, sources[site])
            c = self._disp_counters.get(key)
            if c is None:
                c = self._disp_counters[key] = self.telemetry.counter(
                    "repro_dispatch_total",
                    help="mux-op dispatch outcomes per batch",
                    op=site, mode=m, source=sources[site])
            c.inc()

    def _build_variant(self, assignment, blocks):
        """Jit one compiled variant: the engine's program re-specialized
        to this mode vector (+ Pallas block overrides). The op stream
        never changes — only the per-site dense/sg mux — so every
        variant serves from the same fixed shapes."""
        from repro.core.program import respecialize
        prog = respecialize(self.program, dict(assignment))
        blk = dict(blocks) or None

        def fwd(params, batch):
            emb, _ = execute(prog, params, batch, impl=self.impl,
                             blocks=blk)
            return emb

        return jax.jit(fwd)

    def _dispatch_infer(self, plan: BatchPlan, db) -> jax.Array:
        """The adaptive device step: consult the policy with THIS
        batch's measured density, run the warmup/autotune exploration
        pass when scheduled (outputs discarded), then serve through the
        bounded variant cache."""
        from repro.core.dispatch import variant_key
        from repro.core.program import respecialize
        from repro.obs.calib import (run_block_autotune, run_instrumented,
                                     size_bucket)
        pol = self.dispatch
        bucket = size_bucket(db)
        avg_e = min(float(plan.n_edges), float(self.e_pad))
        dec = pol.decide(avg_e, bucket)
        if dec.blocks:
            self._last_blocks = dict(dec.blocks)
        if dec.warm_mode is not None:
            # instrumented exploration pass in the scheduled forced mode
            # — its outputs are DISCARDED (serving stays on
            # dec.assignment below), so warmup batches remain bitwise-
            # identical to an engine with dispatch off
            try:
                warm = {s: dec.warm_mode for s in pol.sites}
                run_instrumented(respecialize(self.program, warm),
                                 self.params, db, self.impl, pol.table)
                if pol.autotune_blocks and self.impl == "pallas":
                    run_block_autotune(self.program, self.params, db,
                                       pol.table)
            except Exception:        # exploration must never break
                pass                 # serving
        self._count_dispatch(dec.assignment, dec.site_sources)
        tr = self.tracer
        if tr is not None and tr.current() is not None:
            tr.annotate(dispatch_source=dec.source,
                        dispatch_bucket=dec.bucket,
                        dispatch_modes=",".join(
                            f"{s}={m}" for s, m
                            in sorted(dec.assignment.items())),
                        batch_avg_edges=round(dec.avg_edges, 1))
        fn = self._variants.get(
            variant_key(dec.assignment, dec.blocks),
            lambda: self._build_variant(dec.assignment, dec.blocks))
        return fn(self.params, db)

    def dispatch_report(self) -> Optional[dict]:
        """Adaptive-dispatch state (the ``dispatch.*`` schema section):
        decision/source counters, warmup schedule, variant-cache bounds
        and hit/evict counters, resolved block overrides. None when the
        deployment was built without ``ServingConfig(dispatch=...)`` —
        the section is omitted, like ``trace``."""
        dconf = self.config.dispatch
        if dconf is None:
            return None
        if self.dispatch is None:    # forced mode: policy inert
            return {"enabled": True, "policy": "forced",
                    "impl": self.impl,
                    "mux_sites": sorted(self._static_assignment),
                    "decisions": self._forced_dispatch,
                    "sources": {"forced": self._forced_dispatch},
                    "artifact": dconf.artifact}
        d = self.dispatch.report()
        d.update(enabled=True, variants=self._variants.stats(),
                 blocks=dict(self._last_blocks),
                 artifact=dconf.artifact)
        return d

    def save_calibration(self, path: Optional[str] = None) -> str:
        """Persist the live calibration table (per-op p50 cells + block
        autotune cells) as a committed artifact at ``path`` (default:
        ``DispatchConfig.artifact``); a later engine with the same
        graph/model/impl loads it and dispatches measured from the
        first batch."""
        from repro.obs.calib import save_calibration
        dconf = self.config.dispatch
        path = path or (dconf.artifact if dconf is not None else None)
        if path is None:
            raise ValueError(
                "no artifact path: pass save_calibration(path=...) or "
                "set DispatchConfig(artifact=...)")
        if self._calib is None:
            raise ValueError(
                "no calibration table on this engine; enable "
                "ServingConfig(dispatch=...) or trace calibration")
        return save_calibration(path, self._calib, graph=self.graph,
                                cfg=self.cfg, impl=self.impl)

    # -- end-to-end ----------------------------------------------------------
    def pad_targets(self, targets: np.ndarray) -> np.ndarray:
        """Pad a tail chunk to the engine's fixed batch size C by repeating
        the last target (fixed shapes keep the one compiled program)."""
        C = self.batch_size
        targets = np.asarray(targets)
        if len(targets) == C:
            return targets
        if len(targets) > C or len(targets) == 0:
            raise ValueError(f"chunk size {len(targets)} vs C={C}")
        return np.concatenate(
            [targets, np.repeat(targets[-1:], C - len(targets))])

    def submit_chunk(self, targets, on_done=None) -> StreamTicket:
        """Streaming entry: enqueue ONE micro-batch (≤ C targets, tail is
        padded) on the persistent pipeline; returns a StreamTicket whose
        result is the [C, f] embedding block."""
        return self.scheduler.submit(self.pad_targets(np.asarray(targets)),
                                     on_done=on_done)

    def infer(self, targets, overlap: bool = True) -> InferenceResult:
        """Mini-batch inference for arbitrary #targets (chunks of C)."""
        targets = np.asarray(targets)
        C = self.batch_size
        chunks = [self.pad_targets(targets[i:i + C])
                  for i in range(0, len(targets), C)]
        outs, stats = self.scheduler.run(chunks, overlap=overlap)
        emb = np.concatenate([np.asarray(o) for o in outs], axis=0)
        return InferenceResult(embeddings=emb[:len(targets)], stats=stats,
                               decision=self.decision)

    # -- store hooks ---------------------------------------------------------
    def invalidate(self, vertices) -> int:
        """Graph-update hook, every cache level: drop every cached
        neighborhood AND every cached subgraph row whose push FRONTIER
        contains any of ``vertices`` (exact — the miss path caches each
        push's full touched set, see FrontierCache.invalidate), and
        re-upload those vertices' device-resident feature rows from
        ``graph.features`` (so feature mutations take effect without an
        engine rebuild). Returns the number of NEIGHBORHOOD entries
        dropped (row-cache drops are visible in store_report())."""
        if hasattr(self._fsource, "refresh_features"):
            self._fsource.refresh_features(vertices)
        if self.precompute is not None:
            # demote the dependency ball in the embedding tier (those
            # vertices fall back to the online path until refreshed)
            self.precompute.on_invalidate(vertices)
        if self._host_pool is not None:
            # multi-host: the caches live on the graph hosts — broadcast
            # the drop (best-effort; a dead host holds no live state)
            from repro.store.nbr_cache import as_vertex_ids
            results = self._host_pool.broadcast(
                "invalidate", {"vertices": as_vertex_ids(vertices)})
            return sum(r["dropped"] for r in results if r is not None)
        if self.sg_cache is not None:
            self.sg_cache.invalidate(vertices)
        if self.nbr_cache is None:
            return 0
        return self.nbr_cache.invalidate(vertices)

    def _on_batch_done(self, ticket=None):
        """Pipeline completion hook: evaluate the policy's automatic
        repin triggers and hand the rebalance to the engine's single
        repin worker — the completion path itself stays light (the
        scheduler's contract), and in-flight batches keep their residency
        snapshot (the payload carries its generation), so a repin landing
        mid-stream never corrupts them.

        The hit-floor trigger backs off exponentially while the rate
        stays below the floor (a working set larger than the budget can
        NEVER satisfy it — without backoff every batch would pay a full
        table rebuild) and re-arms as soon as a check passes."""
        pol = self.store_policy
        src = self._fsource
        with self._repin_lock:
            self._repin_batches += 1
            self._floor_batches += 1
            due = bool(pol.repin_every
                       and self._repin_batches >= pol.repin_every)
            if not due and pol.repin_hit_floor \
                    and self._floor_batches >= self._floor_wait:
                lk = getattr(src, "lookups", 0) - self._repin_base[0]
                res = getattr(src, "resident_lookups", 0) \
                    - self._repin_base[1]
                self._floor_batches = 0
                if lk > 0 and (res / lk) < pol.repin_hit_floor:
                    due = True
                    self._floor_wait = min(64, self._floor_wait * 2)
                else:
                    self._floor_wait = 1
            if not due:
                return
            self._repin_batches = 0
            self._repin_base = (getattr(src, "lookups", 0),
                                getattr(src, "resident_lookups", 0))
            self.auto_repins += 1
        self._repin_pool.submit(self._auto_repin_job)

    def _auto_repin_job(self):
        try:
            self.repin()
        except Exception:            # a failed rebalance must not kill
            pass                     # the worker (serving is unaffected)

    def drain_repins(self, timeout: Optional[float] = 60.0):
        """Block until every triggered auto-repin has executed (tests /
        orderly shutdown; serving never needs this)."""
        if self._repin_pool is not None:
            self._repin_pool.submit(lambda: None).result(timeout)

    def repin(self, **kwargs) -> dict:
        """Online residency rebalance (resident + sharded stores):
        re-derive the device-resident set from the PPR mass observed
        since start — hot cold-rows promote, dead resident rows demote
        (and, sharded, skewed shards even out). In-flight batches keep
        their residency snapshot (the payload carries its generation), so
        serving never pauses."""
        if not hasattr(self._fsource, "repin"):
            raise ValueError(
                f"store strategy {self._fsource.name!r} has no repin(); "
                "use StorePolicy(features='resident' | 'sharded', ...)")
        return self._fsource.repin(**kwargs)

    def store_report(self) -> dict:
        """Cache/transfer state of this deployment's store subsystem."""
        pol = self.store_policy.describe()
        if self.nbr_cache is not None:
            # resolve the policy's "auto" pin set to what is actually
            # evict-exempt in this deployment
            pol["pinned_count"] = self.nbr_cache.num_pinned_targets
        r = {"policy": pol, "features": self._fsource.report()}
        if self.nbr_cache is not None:
            r["nbr_cache"] = self.nbr_cache.stats()
        if self.sg_cache is not None:
            r["subgraph_cache"] = self.sg_cache.stats()
        if self._repin_auto:
            r["auto_repins"] = self.auto_repins
        if self._host_pool is not None:
            # multi-host: per-host health + the graph hosts' own cache
            # stats (best-effort — a down host reports health only)
            health = self._host_pool.report()
            remote = self._host_pool.broadcast("report", None)
            for h, rep in zip(health, remote):
                if rep is not None:
                    h["report"] = rep
            r["graph_hosts"] = health
        return r

    def trace_report(self) -> dict:
        """Observability state of this deployment: tracing counters,
        per-span-name latency histograms, flight-recorder summary,
        clock-sync estimates, and the per-op calibration table (the
        ``trace.*`` schema section). ``{"enabled": False}`` when the
        deployment was built without ``ServingConfig(trace=...)``."""
        if self.tracer is None:
            return {"enabled": False}
        from repro.core.report_schema import trace_section
        return trace_section(self.tracer, self._calib)

    def export_trace(self, path: str) -> dict:
        """Write this deployment's finished spans (export ring + flight
        recorder trees) as a Perfetto-loadable chrome trace."""
        if self.tracer is None:
            raise ValueError(
                "tracing is off; construct the engine with "
                "ServingConfig(trace=TraceConfig(...)) to record spans")
        from repro.obs.export import write_chrome_trace
        return write_chrome_trace(path, self.tracer.export_spans(),
                                  metadata={"config":
                                            self.config.describe()})

    def telemetry_report(self) -> dict:
        """Live telemetry state of this deployment (the ``telemetry.*``
        schema section): windowed metric snapshot, SLO burn-rate rows,
        watchdog state, and the event ring. ``{"enabled": False}`` when
        the deployment was built without ``ServingConfig(telemetry=...)``.
        """
        if self.telemetry is None:
            return {"enabled": False}
        from repro.core.report_schema import telemetry_section
        return telemetry_section(self.telemetry)

    def metrics_wire(self, cluster: bool = True) -> dict:
        """This deployment's metrics in wire form. With ``cluster=True``
        on a multi-host deployment, every graph host's registry is
        scraped over the ``metrics`` RPC (best-effort broadcast) and
        merged losslessly into one cluster view — per-host histograms
        fold bucket-by-bucket, so the merged count is exactly the sum of
        the per-host counts."""
        if self.telemetry is None:
            raise ValueError(
                "telemetry is off; construct the engine with "
                "ServingConfig(telemetry=TelemetryConfig(...))")
        local = self.telemetry.to_wire()
        if not cluster or self._host_pool is None:
            return local
        from repro.obs.metrics import merge_wire
        remote = self._host_pool.broadcast("metrics", None)
        return merge_wire([local] + [r for r in remote if r])

    def metrics_text(self, cluster: bool = True) -> str:
        """Prometheus text exposition of ``metrics_wire()`` (what an
        HTTP ``/metrics`` endpoint serves for this deployment)."""
        from repro.obs.promexp import render_wire
        return render_wire(self.metrics_wire(cluster=cluster))

    def precompute_report(self) -> dict:
        """Embedding-tier state of this deployment (the ``precompute.*``
        schema section): residency, freshness, hit/demotion counters and
        refresh backlog. ``{"enabled": False}`` when the deployment was
        built without ``ServingConfig(precompute=...)`` (or this model
        kind is excluded from ``PrecomputeConfig.models``)."""
        from repro.core.report_schema import precompute_section
        return precompute_section(self.precompute)

    def close(self):
        dconf = self.config.dispatch
        if self.dispatch is not None and dconf.save_on_close \
                and dconf.artifact:
            try:                     # best-effort: a failed save must
                self.save_calibration()   # not block shutdown
            except Exception as e:
                import warnings
                warnings.warn(f"calibration save failed: {e}",
                              RuntimeWarning, stacklevel=2)
        if hasattr(self.graph, "unregister_listener"):
            self.graph.unregister_listener(self.invalidate)
        if self.precompute is not None:
            self.precompute.close()
        self.scheduler.close()
        if self.telemetry is not None:
            self.telemetry.close()
        if self._repin_pool is not None:
            self._repin_pool.shutdown(wait=True)
        for stage in self.stages:
            stage.close()
        if self._host_pool is not None:
            self._host_pool.close()

    def __enter__(self) -> "DecoupledEngine":
        return self

    def __exit__(self, *exc):
        self.close()
