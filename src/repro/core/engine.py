"""Decoupled mini-batch GNN inference engine (paper Algorithm 2 + 3).

Host side: INI (PPR local push) + induced-subgraph construction into
fixed-shape padded batches. Device side: one jitted AckProgram per
(model, N, C) — the model's registered lowering (core.program) executed
through the ACK kernels with a PER-OP dense/scatter-gather mux (XLA or
Pallas implementation) and the Readout. The fixed shapes are the
decoupling dividend: ONE compiled program serves every batch — the
paper's "single accelerator, no reconfiguration" property.

``DecoupledEngine.infer`` overlaps host preparation of batch i+1 with
device execution of batch i via core.scheduler (paper Fig. 7). The engine
owns ONE persistent ``PipelineScheduler`` for its whole lifetime — batch
and streaming calls share its host pool, dispatcher, and cumulative stats,
so serving never pays per-call pipeline construction.
"""
from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.program import (ProgramDecision, execute,
                                input_width_params, lower,
                                required_adjacency, specialize)
from repro.core.scheduler import (PipelineScheduler, SchedulerStats,
                                  StreamTicket)
from repro.core.subgraph import SubgraphBatch, default_edge_pad
from repro.gnn.model import GNNConfig, init_gnn
from repro.graphs.csr import CSRGraph
from repro.store import NeighborhoodCache, StorePolicy, build_feature_source
from repro.store.feature_store import pad_feature_dim
from repro.store.nbr_cache import nbr_key


def _pad128(f: int) -> int:
    return f + (-f) % 128


@dataclass
class InferenceResult:
    embeddings: np.ndarray           # [num_targets, f]
    stats: Optional[SchedulerStats]
    decision: ProgramDecision        # per-op mode decisions + summary


class DecoupledEngine:
    """One engine instance = one (graph, model, batch-size) deployment."""

    def __init__(self, graph: CSRGraph, cfg: GNNConfig, params=None, *,
                 batch_size: int = 64, mode: str = "auto",
                 impl: str = "xla", num_threads: int = 8, seed: int = 0,
                 e_pad: Optional[int] = None,
                 dedup_features: Optional[bool] = None,
                 store: Optional[StorePolicy] = None):
        self.graph, self.cfg = graph, cfg
        self.batch_size = batch_size
        self.num_threads = num_threads
        self.impl = impl
        if dedup_features is not None:
            warnings.warn(
                "dedup_features= is deprecated; pass "
                "store=StorePolicy(features='packed') instead",
                DeprecationWarning, stacklevel=2)
        else:
            dedup_features = False
        if store is None:
            # back-compat: dedup_features=True was the pre-store spelling
            # of the packed shipping strategy
            store = StorePolicy(features="packed") if dedup_features \
                else StorePolicy()
        elif dedup_features and store.features != "packed":
            raise ValueError(
                "dedup_features=True conflicts with store.features="
                f"{store.features!r}; use StorePolicy(features='packed')")
        self.store_policy = store
        self.dedup_features = store.features == "packed"
        self.last_dedup_ratio = None
        n = cfg.receptive_field
        self.e_pad = e_pad or default_edge_pad(graph, n)
        avg_edges = min(self.e_pad, n * float(graph.degrees.mean()))
        # compile the model through the lowering registry, then set each
        # op's mode mux from ITS kernel's FLOP model (mode="auto") or the
        # caller's force — a single program may mix sg aggregation with
        # dense (systolic) transforms
        self.program, self.decision = specialize(
            lower(cfg), n=n, avg_edges=avg_edges, f_in=cfg.f_in,
            f_hidden=cfg.f_hidden,
            force=None if mode == "auto" else mode)
        self.mode = self.decision.mode
        self.needs_edges = any(d.mode == "sg" for d in self.decision)
        # ship only the adjacency arrays the specialized program reads
        # (an all-sg aggregation path ships none — just the edge list)
        self.adj_keys = required_adjacency(self.program)
        if params is None:
            params = init_gnn(cfg, jax.random.PRNGKey(seed))
        self.params = params
        self.f_pad = _pad128(cfg.f_in) if impl == "pallas" else cfg.f_in
        if self.f_pad != cfg.f_in:
            # MXU alignment: zero-pad layer0 input-rows to match the padded
            # feature columns (padded features are zero, so this is exact).
            # WHICH weights are f_in-sized is read off the lowered program
            # (registry contract: custom kinds need no engine edits)
            pad = self.f_pad - cfg.f_in
            l0 = dict(params["layer0"])
            for k in input_width_params(self.program):
                l0[k] = jnp.pad(l0[k], ((0, pad), (0, 0)))
            self.params = dict(params, layer0=l0)
        self._infer = jax.jit(functools.partial(self._forward))
        self._fsource = build_feature_source(graph, store, self.f_pad)
        self.nbr_cache = self._build_nbr_cache(store)
        # one pipeline per deployment (paper: one accelerator config, no
        # per-batch reconfiguration); lazily started on first use
        self.scheduler = PipelineScheduler(self.prepare, self.run_device,
                                           depth=3)
        # graph-update streaming: CSRGraph.apply_edge_updates notifies us
        # so cached neighborhoods / resident rows never serve stale state
        if hasattr(graph, "register_listener"):
            graph.register_listener(self.invalidate)

    def _build_nbr_cache(self, policy: StorePolicy
                         ) -> Optional[NeighborhoodCache]:
        if policy.nbr_cache == "none":
            return None
        pinned = None
        if policy.nbr_cache == "pinned":
            pinned = policy.pinned_targets
            if pinned is None:
                # default hot set: top-degree targets (hub-heavy traffic
                # hits them most under Zipf skew)
                k = min(self.graph.num_vertices,
                        policy.pinned_count or
                        max(1, policy.nbr_capacity // 4))
                pinned = np.argpartition(self.graph.degrees, -k)[-k:]
        return NeighborhoodCache(policy.nbr_capacity, pinned_targets=pinned)

    # -- device program ----------------------------------------------------
    def _forward(self, params, batch: Dict[str, jax.Array]):
        emb, _ = execute(self.program, params, batch, impl=self.impl)
        return emb

    # -- host side ----------------------------------------------------------
    def _pad_feature_dim(self, feats):
        """Engine-facing entry to the single padding implementation
        (store.feature_store.pad_feature_dim) bound to this engine's
        f_pad — prepare/device_batch/run_device all route through it."""
        return pad_feature_dim(feats, self.f_pad)

    def _node_lists(self, targets):
        """PPR neighborhoods for a batch, via the neighborhood cache when
        the policy has one. Returns (node_lists, hits, misses) counted
        over the batch's UNIQUE targets — duplicates collapse into one
        count, so tail padding (pad_targets repeats the last target)
        cannot inflate the hit rate with synthetic traffic."""
        from repro.core.ini import ini_batch
        cfg = self.cfg
        n, a, e = cfg.receptive_field, cfg.ppr_alpha, cfg.ppr_eps
        targets = [int(t) for t in targets]
        if self.nbr_cache is None:
            return (ini_batch(self.graph, targets, n, a, e,
                              self.num_threads), 0, 0)
        found, missing = {}, []
        for t in dict.fromkeys(targets):          # unique, order-kept
            nl = self.nbr_cache.get(nbr_key(t, n, a, e))
            if nl is None:
                missing.append(t)
            else:
                found[t] = nl
        if missing:
            gen = self.nbr_cache.generation   # pre-computation epoch: an
            # invalidate() landing mid-push makes put() drop the result
            computed = ini_batch(self.graph, missing, n, a, e,
                                 self.num_threads, with_frontier=True)
            for t, (nl, frontier) in zip(missing, computed):
                # the full touched set rides along so invalidate() is
                # exact (an update below the top-N cutoff still drops us)
                self.nbr_cache.put(nbr_key(t, n, a, e), nl,
                                   generation=gen, frontier=frontier)
                found[t] = nl
        return ([found[t] for t in targets],
                len(found) - len(missing), len(missing))

    def prepare(self, targets) -> Dict[str, np.ndarray]:
        from repro.core.subgraph import batch_from_node_lists
        node_lists, hits, misses = self._node_lists(targets)
        src = self._fsource
        sb = batch_from_node_lists(self.graph, targets, node_lists,
                                   self.cfg.receptive_field, self.e_pad,
                                   build_feats=src.needs_host_feats)
        d = self.device_batch(sb, include_feats=False)
        payload, dedup = src.host_payload(
            node_lists, self.cfg.receptive_field,
            sb.feats if src.needs_host_feats else None)
        if dedup is not None:
            self.last_dedup_ratio = dedup
        # transfer accounting: what this strategy ships vs. what the dense
        # baseline would (non-feature arrays + a full [C, N, f_pad] block)
        other = sum(int(a.nbytes) for a in d.values())
        shipped = other + sum(int(a.nbytes) for a in payload.values())
        dense = other + len(node_lists) * self.cfg.receptive_field \
            * self.f_pad * 4
        d.update(payload)
        # sharded store: per-shard share of this payload's bytes (pure
        # function of the payload — safe from concurrent prepare threads)
        per_shard = getattr(src, "shard_metrics_for", None)
        self.scheduler.note_host_metrics(
            bytes_shipped=shipped, bytes_dense=dense, cache_hits=hits,
            cache_misses=misses, dedup_ratio=dedup,
            shard_bytes=per_shard(payload) if per_shard else None)
        return d

    def device_batch(self, sb: SubgraphBatch,
                     include_feats: bool = True) -> Dict[str, np.ndarray]:
        d = {"mask": sb.mask}
        for k in self.adj_keys:     # only what the compiled program reads
            d[k] = sb.adj if k == "adj" else sb.adj_mean
        if include_feats:
            d["feats"] = self._pad_feature_dim(sb.feats)
        if self.needs_edges:
            n = sb.n
            self_w = sb.adj[:, np.arange(n), np.arange(n)]
            indeg = np.einsum("cij->ci", (sb.adj_mean > 0).astype(np.float32))
            d.update(edge_src=sb.edge_src, edge_dst=sb.edge_dst,
                     edge_w=sb.edge_w, self_w=self_w.astype(np.float32))
            valid = sb.edge_w != 0
            dst_deg = np.take_along_axis(
                np.maximum(indeg, 1.0), sb.edge_dst.astype(np.int64), axis=1)
            d["edge_w_mean"] = np.where(valid, 1.0 / dst_deg, 0.0
                                        ).astype(np.float32)
        return d

    def run_device(self, device_batch) -> jax.Array:
        db = dict(device_batch)
        src = self._fsource
        if all(k in db for k in src.payload_keys):
            feats = src.device_feats({k: db.pop(k)
                                      for k in src.payload_keys})
        else:       # externally built dense batch (e.g. device_batch())
            feats = db["feats"]
        db["feats"] = self._pad_feature_dim(feats)
        return self._infer(self.params, db)

    # -- end-to-end ----------------------------------------------------------
    def pad_targets(self, targets: np.ndarray) -> np.ndarray:
        """Pad a tail chunk to the engine's fixed batch size C by repeating
        the last target (fixed shapes keep the one compiled program)."""
        C = self.batch_size
        targets = np.asarray(targets)
        if len(targets) == C:
            return targets
        if len(targets) > C or len(targets) == 0:
            raise ValueError(f"chunk size {len(targets)} vs C={C}")
        return np.concatenate(
            [targets, np.repeat(targets[-1:], C - len(targets))])

    def submit_chunk(self, targets, on_done=None) -> StreamTicket:
        """Streaming entry: enqueue ONE micro-batch (≤ C targets, tail is
        padded) on the persistent pipeline; returns a StreamTicket whose
        result is the [C, f] embedding block."""
        return self.scheduler.submit(self.pad_targets(np.asarray(targets)),
                                     on_done=on_done)

    def infer(self, targets, overlap: bool = True) -> InferenceResult:
        """Mini-batch inference for arbitrary #targets (chunks of C)."""
        targets = np.asarray(targets)
        C = self.batch_size
        chunks = [self.pad_targets(targets[i:i + C])
                  for i in range(0, len(targets), C)]
        outs, stats = self.scheduler.run(chunks, overlap=overlap)
        emb = np.concatenate([np.asarray(o) for o in outs], axis=0)
        return InferenceResult(embeddings=emb[:len(targets)], stats=stats,
                               decision=self.decision)

    # -- store hooks ---------------------------------------------------------
    def invalidate(self, vertices) -> int:
        """Graph-update hook, both store levels: drop every cached
        neighborhood whose push FRONTIER contains any of ``vertices``
        (exact — the miss path caches each push's full touched set, see
        NeighborhoodCache.invalidate), and re-upload those vertices'
        device-resident feature rows from ``graph.features`` (so feature
        mutations take effect without an engine rebuild). Returns the
        number of cache entries dropped."""
        if hasattr(self._fsource, "refresh_features"):
            self._fsource.refresh_features(vertices)
        if self.nbr_cache is None:
            return 0
        return self.nbr_cache.invalidate(vertices)

    def repin(self, **kwargs) -> dict:
        """Online residency rebalance (sharded store only): re-derive the
        shard-resident set from the PPR mass observed since start — hot
        cold-rows promote, dead resident rows demote, skewed shards even
        out. In-flight batches keep their placement snapshot (the payload
        carries its generation), so serving never pauses."""
        if not hasattr(self._fsource, "repin"):
            raise ValueError(
                f"store strategy {self._fsource.name!r} has no repin(); "
                "use StorePolicy(features='sharded', ...)")
        return self._fsource.repin(**kwargs)

    def store_report(self) -> dict:
        """Cache/transfer state of this deployment's store subsystem."""
        pol = self.store_policy.describe()
        if self.nbr_cache is not None:
            # resolve the policy's "auto" pin set to what is actually
            # evict-exempt in this deployment
            pol["pinned_count"] = self.nbr_cache.num_pinned_targets
        r = {"policy": pol, "features": self._fsource.report()}
        if self.nbr_cache is not None:
            r["nbr_cache"] = self.nbr_cache.stats()
        return r

    def close(self):
        if hasattr(self.graph, "unregister_listener"):
            self.graph.unregister_listener(self.invalidate)
        self.scheduler.close()

    def __enter__(self) -> "DecoupledEngine":
        return self

    def __exit__(self, *exc):
        self.close()
