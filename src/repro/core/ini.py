"""Important Neighbor Identification (INI) — Personalized PageRank local
push (Andersen-Chung-Lang forward push), the paper's host-side subroutine
(Algorithm 2 line 2, §3.2).

The push loop is frontier-vectorized numpy: each iteration pushes the whole
above-threshold frontier at once with ``np.add.at`` instead of a per-vertex
deque, which is the multi-core-friendly formulation of [Aggarwal et al.,
HiPC'21] that the paper parallelizes over CPU threads. ``ini_batch`` runs
targets on a thread pool (the paper uses 8 host threads).

Also provides the dense power-iteration PPR oracle used by tests.
"""
from __future__ import annotations

from concurrent.futures import Executor, ThreadPoolExecutor
from typing import List, Optional, Tuple

import numpy as np

from repro.graphs.csr import CSRGraph, _gather_ranges


def ppr_local_push(g: CSRGraph, target: int, alpha: float = 0.15,
                   eps: float = 1e-4, max_iters: int = 1000
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Approximate PPR vector for ``target`` via forward local push.

    Invariant maintained:  p + alpha * r  ==  ppr  (up to push residue);
    push rule: while r[u] >= eps * deg(u):
        p[u] += alpha * r[u];  r[neighbors] += (1-alpha) * r[u] / deg(u)

    Returns (touched_vertices [k], scores [k]) with scores = p estimates,
    target always included.
    """
    deg = g.degrees
    # sparse p/r held as dense float arrays over touched region only would
    # need hashing; at these graph scales dense [V] float32 is cheap and the
    # frontier ops stay O(touched).
    p = np.zeros(g.num_vertices, np.float64)
    r = np.zeros(g.num_vertices, np.float64)
    r[target] = 1.0
    # touched bookkeeping is a boolean mask + a growing id array: the mask
    # answers "seen before?" in O(1) numpy and tarr enumerates the touched
    # set without per-iteration Python-object traffic (set / np.fromiter)
    touched = np.zeros(g.num_vertices, bool)
    touched[target] = True
    tarr = np.array([target], dtype=np.int64)
    thresh = np.maximum(deg, 1) * eps
    frontier = tarr
    for _ in range(max_iters):
        mask = r[frontier] >= thresh[frontier]
        active = frontier[mask]
        if len(active) == 0:
            break
        r_act = r[active]
        p[active] += alpha * r_act
        r[active] = 0.0
        # distribute (1-alpha)*r_u evenly over out-neighbors
        counts = (g.indptr[active + 1] - g.indptr[active]).astype(np.int64)
        has_nbrs = counts > 0
        act = active[has_nbrs]
        if len(act) == 0:
            frontier = active[:0]
            continue
        counts = counts[has_nbrs]
        shares = ((1.0 - alpha) * r_act[has_nbrs]) / counts
        nbrs = _gather_ranges(g.indices, g.indptr[act], g.indptr[act + 1],
                              int(counts.sum()))
        np.add.at(r, nbrs, np.repeat(shares, counts))
        uniq = np.unique(nbrs)
        new = uniq[~touched[uniq]]
        if len(new):
            touched[new] = True
            tarr = np.concatenate([tarr, new])
        # next frontier = all touched vertices above threshold
        frontier = tarr[r[tarr] >= thresh[tarr]]
        if len(frontier) == 0:
            break
    scores = p[tarr] + alpha * r[tarr]   # fold residual for a tighter est.
    return tarr, scores


def select_important(g: CSRGraph, target: int, n: int, alpha: float = 0.15,
                     eps: float = 1e-4,
                     with_frontier: bool = False) -> np.ndarray:
    """Top-(n-1) PPR neighbors plus the target itself (target first).

    ``with_frontier=True`` additionally returns the push's full touched
    set (every vertex the local push reached, sorted) — the exact
    invalidation footprint: a graph update at ANY touched vertex can
    shift the target's PPR scores and therefore its top-N selection,
    even when that vertex fell below the top-N cutoff."""
    verts, scores = ppr_local_push(g, target, alpha, eps)
    frontier = np.sort(verts) if with_frontier else None
    keep = verts != target
    verts, scores = verts[keep], scores[keep]
    if len(verts) > n - 1:
        top = np.argpartition(scores, -(n - 1))[-(n - 1):]
        verts = verts[top[np.argsort(-scores[top])]]
    else:
        verts = verts[np.argsort(-scores)]
    sel = np.concatenate([[target], verts]).astype(np.int64)
    return (sel, frontier) if with_frontier else sel


def ini_batch(g: CSRGraph, targets, n: int, alpha: float = 0.15,
              eps: float = 1e-4, num_threads: int = 8,
              with_frontier: bool = False,
              executor: Optional[Executor] = None) -> List[np.ndarray]:
    """INI for a batch of targets on a host thread pool (paper: 8 threads).

    ``with_frontier=True`` returns ``(node_list, touched_set)`` pairs —
    see ``select_important``. Pass a persistent ``executor`` to amortize
    pool construction across batches (the Select stage owns one for its
    engine's lifetime); without one, a pool is built per call."""
    def one(t):
        return select_important(g, int(t), n, alpha, eps, with_frontier)
    if executor is not None and len(targets) > 1:
        return list(executor.map(one, targets))
    if num_threads <= 1 or len(targets) <= 1:
        return [one(t) for t in targets]
    with ThreadPoolExecutor(max_workers=num_threads) as ex:
        return list(ex.map(one, targets))


def ppr_power_iteration(g: CSRGraph, target: int, alpha: float = 0.15,
                        iters: int = 200) -> np.ndarray:
    """Dense PPR oracle (tests only, graphs <= a few thousand vertices).

    ppr = alpha * e_t + (1-alpha) * ppr @ D^-1 A  (row-stochastic walk)."""
    V = g.num_vertices
    deg = np.maximum(g.degrees, 1).astype(np.float64)
    pi = np.zeros(V)
    pi[target] = 1.0
    e = pi.copy()
    for _ in range(iters):
        nxt = np.zeros(V)
        # one step of the walk: mass/deg to each out-neighbor
        contrib = pi / deg
        np.add.at(nxt, g.indices, np.repeat(contrib, np.diff(g.indptr)))
        pi = alpha * e + (1.0 - alpha) * nxt
    return pi
