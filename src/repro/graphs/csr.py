"""CSR graph store (numpy, host-side — the paper keeps the graph in host
memory and only ships per-target induced subgraphs to the accelerator).

The store is directed CSR over out-edges; GNN datasets are symmetrized at
construction. Features live alongside as a dense [V, f] float32 matrix.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass
class CSRGraph:
    indptr: np.ndarray            # [V+1] int64
    indices: np.ndarray           # [E] int32
    features: np.ndarray          # [V, f] float32
    labels: Optional[np.ndarray] = None   # [V] int32
    name: str = "graph"

    @property
    def num_vertices(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    @property
    def feature_dim(self) -> int:
        return int(self.features.shape[1])

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def validate(self):
        assert self.indptr[0] == 0 and self.indptr[-1] == self.num_edges
        assert np.all(np.diff(self.indptr) >= 0)
        if self.num_edges:
            assert self.indices.min() >= 0
            assert self.indices.max() < self.num_vertices
        assert self.features.shape[0] == self.num_vertices
        return self


def from_edge_list(src: np.ndarray, dst: np.ndarray, num_vertices: int,
                   features: np.ndarray, symmetrize: bool = True,
                   labels=None, name: str = "graph") -> CSRGraph:
    """Build CSR from (src, dst) arrays; dedups; optionally symmetrizes."""
    if symmetrize:
        src, dst = (np.concatenate([src, dst]), np.concatenate([dst, src]))
    # drop self loops (GNN layers add their own normalized self terms)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    # dedup via sort on (src, dst)
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    if len(src):
        uniq = np.concatenate([[True], (np.diff(src) != 0)
                               | (np.diff(dst) != 0)])
        src, dst = src[uniq], dst[uniq]
    counts = np.bincount(src, minlength=num_vertices)
    indptr = np.zeros(num_vertices + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(indptr=indptr, indices=dst.astype(np.int32),
                    features=features, labels=labels, name=name).validate()


def subgraph_edges(g: CSRGraph, nodes: np.ndarray):
    """Induced-subgraph edge list in *local* indices.

    nodes: [n] unique global vertex ids; local id = position in ``nodes``.
    Returns (src_local [e], dst_local [e]) int32.
    """
    n = len(nodes)
    local = {}
    # vectorized mapping: global -> local via searchsorted on sorted nodes
    order = np.argsort(nodes)
    sorted_nodes = nodes[order]
    starts = g.indptr[nodes]
    ends = g.indptr[nodes + 1]
    counts = (ends - starts).astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return (np.zeros(0, np.int32), np.zeros(0, np.int32))
    # gather all out-edges of `nodes`
    src_rep = np.repeat(np.arange(n, dtype=np.int32), counts)
    idx = np.concatenate([g.indices[s:e] for s, e in zip(starts, ends)]) \
        if n < 4096 else _gather_ranges(g.indices, starts, ends, total)
    # keep edges whose head is inside the node set
    pos = np.searchsorted(sorted_nodes, idx)
    pos = np.clip(pos, 0, n - 1)
    inside = sorted_nodes[pos] == idx
    dst_local = order[pos[inside]].astype(np.int32)
    src_local = src_rep[inside]
    del local
    return src_local, dst_local


def _gather_ranges(arr, starts, ends, total):
    out = np.empty(total, arr.dtype)
    o = 0
    for s, e in zip(starts, ends):
        ln = e - s
        out[o:o + ln] = arr[s:e]
        o += ln
    return out
