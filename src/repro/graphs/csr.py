"""CSR graph store (numpy, host-side — the paper keeps the graph in host
memory and only ships per-target induced subgraphs to the accelerator).

The store is directed CSR over out-edges; GNN datasets are symmetrized at
construction. Features live alongside as a dense [V, f] float32 matrix.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np


@dataclass
class CSRGraph:
    indptr: np.ndarray            # [V+1] int64
    indices: np.ndarray           # [E] int32
    features: np.ndarray          # [V, f] float32
    labels: Optional[np.ndarray] = None   # [V] int32
    name: str = "graph"
    # update listeners: called with the affected vertex ids after every
    # apply_edge_updates (DecoupledEngine registers its invalidate hook
    # here, so cached neighborhoods / resident feature rows stay coherent
    # with the mutating graph)
    _listeners: List[Callable] = field(default_factory=list, repr=False)

    @property
    def num_vertices(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    @property
    def feature_dim(self) -> int:
        return int(self.features.shape[1])

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def validate(self):
        assert self.indptr[0] == 0 and self.indptr[-1] == self.num_edges
        assert np.all(np.diff(self.indptr) >= 0)
        if self.num_edges:
            assert self.indices.min() >= 0
            assert self.indices.max() < self.num_vertices
        assert self.features.shape[0] == self.num_vertices
        return self

    def __deepcopy__(self, memo):
        """Listeners are deployment wiring (live engines holding locks),
        not graph data — a copied graph starts with none."""
        import copy
        return CSRGraph(indptr=copy.deepcopy(self.indptr, memo),
                        indices=copy.deepcopy(self.indices, memo),
                        features=copy.deepcopy(self.features, memo),
                        labels=copy.deepcopy(self.labels, memo),
                        name=self.name)

    # -- graph-update streaming (ROADMAP: edge insert/delete batches) -------
    def register_listener(self, fn: Callable) -> None:
        """``fn(affected_vertices)`` runs after every apply_edge_updates.
        Holds a strong reference — pair with unregister_listener (the
        engine does both in __init__/close)."""
        if fn not in self._listeners:
            self._listeners.append(fn)

    def unregister_listener(self, fn: Callable) -> None:
        if fn in self._listeners:
            self._listeners.remove(fn)

    def apply_edge_updates(self, insert=None, delete=None,
                           symmetrize: bool = True) -> np.ndarray:
        """Apply a batch of edge inserts/deletes in place and notify
        listeners (e.g. ``DecoupledEngine.invalidate``) with the affected
        vertex ids.

        ``insert``/``delete``: an iterable of ``(u, v)`` pairs, or a
        ``(src_array, dst_array)`` tuple of numpy arrays, in GLOBAL
        vertex ids. With ``symmetrize`` (the
        dataset default) each update applies in both directions; self
        loops are dropped (layers add their own normalized self terms),
        duplicates dedup. Vertices cannot be added — ids must be < V.
        Rebuilds ``indptr``/``indices`` (degrees update with them) and
        returns the sorted unique affected vertex ids.

        Concurrency: the two CSR arrays swap in one C-level dict.update,
        so a concurrent reader never sees the torn new-indptr/old-indices
        state; a reader that loaded one array before the swap and the
        other after can still pair mismatched snapshots. Batches already
        in flight were prepared against the pre-update graph either way —
        the cache generation mechanism (NeighborhoodCache.put) keeps
        their stale results out of the caches, and the next lookup
        recomputes on the mutated CSR."""
        def _pairs(x):
            if x is None:
                return (np.zeros(0, np.int64),) * 2
            # the array form is recognized ONLY by ndarray elements —
            # a tuple of two (u, v) pairs must parse as two edges, not
            # as (src, dst) columns
            if isinstance(x, tuple) and len(x) == 2 \
                    and isinstance(x[0], np.ndarray):
                s, d = (np.asarray(x[0], np.int64),
                        np.asarray(x[1], np.int64))
            else:
                arr = np.asarray(list(x), np.int64).reshape(-1, 2)
                s, d = arr[:, 0], arr[:, 1]
            if len(s) and (min(s.min(), d.min()) < 0
                           or max(s.max(), d.max()) >= self.num_vertices):
                raise ValueError("edge update references vertex id outside "
                                 f"[0, {self.num_vertices})")
            return s, d

        ins_s, ins_d = _pairs(insert)
        del_s, del_d = _pairs(delete)
        if symmetrize:
            ins_s, ins_d = (np.concatenate([ins_s, ins_d]),
                            np.concatenate([ins_d, ins_s]))
            del_s, del_d = (np.concatenate([del_s, del_d]),
                            np.concatenate([del_d, del_s]))
        keep = ins_s != ins_d                          # no self loops
        ins_s, ins_d = ins_s[keep], ins_d[keep]

        v = self.num_vertices
        cur_s = np.repeat(np.arange(v, dtype=np.int64), self.degrees)
        cur_d = self.indices.astype(np.int64)
        cur_key = cur_s * v + cur_d
        if len(del_s):
            cur_key = cur_key[~np.isin(cur_key, del_s * v + del_d)]
        if len(ins_s):
            cur_key = np.concatenate([cur_key, ins_s * v + ins_d])
        cur_key = np.unique(cur_key)                   # dedup + sort
        new_s, new_d = cur_key // v, cur_key % v
        counts = np.bincount(new_s, minlength=v)
        indptr = np.zeros(v + 1, np.int64)
        np.cumsum(counts, out=indptr[1:])
        # single C-level update: no window where a reader can observe the
        # new indptr paired with the old (shorter) indices array
        self.__dict__.update(indptr=indptr,
                             indices=new_d.astype(np.int32))
        self.validate()
        affected = np.unique(np.concatenate([ins_s, ins_d, del_s, del_d]))
        for fn in list(self._listeners):
            fn(affected)
        return affected


def from_edge_list(src: np.ndarray, dst: np.ndarray, num_vertices: int,
                   features: np.ndarray, symmetrize: bool = True,
                   labels=None, name: str = "graph") -> CSRGraph:
    """Build CSR from (src, dst) arrays; dedups; optionally symmetrizes."""
    if symmetrize:
        src, dst = (np.concatenate([src, dst]), np.concatenate([dst, src]))
    # drop self loops (GNN layers add their own normalized self terms)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    # dedup via sort on (src, dst)
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    if len(src):
        uniq = np.concatenate([[True], (np.diff(src) != 0)
                               | (np.diff(dst) != 0)])
        src, dst = src[uniq], dst[uniq]
    counts = np.bincount(src, minlength=num_vertices)
    indptr = np.zeros(num_vertices + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(indptr=indptr, indices=dst.astype(np.int32),
                    features=features, labels=labels, name=name).validate()


def subgraph_edges(g: CSRGraph, nodes: np.ndarray):
    """Induced-subgraph edge list in *local* indices.

    nodes: [n] unique global vertex ids; local id = position in ``nodes``.
    Returns (src_local [e], dst_local [e]) int32.
    """
    n = len(nodes)
    local = {}
    # vectorized mapping: global -> local via searchsorted on sorted nodes
    order = np.argsort(nodes)
    sorted_nodes = nodes[order]
    starts = g.indptr[nodes]
    ends = g.indptr[nodes + 1]
    counts = (ends - starts).astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return (np.zeros(0, np.int32), np.zeros(0, np.int32))
    # gather all out-edges of `nodes`
    src_rep = np.repeat(np.arange(n, dtype=np.int32), counts)
    idx = np.concatenate([g.indices[s:e] for s, e in zip(starts, ends)]) \
        if n < 4096 else _gather_ranges(g.indices, starts, ends, total)
    # keep edges whose head is inside the node set
    pos = np.searchsorted(sorted_nodes, idx)
    pos = np.clip(pos, 0, n - 1)
    inside = sorted_nodes[pos] == idx
    dst_local = order[pos[inside]].astype(np.int32)
    src_local = src_rep[inside]
    del local
    return src_local, dst_local


def _gather_ranges(arr, starts, ends, total):
    out = np.empty(total, arr.dtype)
    o = 0
    for s, e in zip(starts, ends):
        ln = e - s
        out[o:o + ln] = arr[s:e]
        o += ln
    return out
