"""Synthetic graph generators statistically matched to the paper's datasets.

The container is offline, so Flickr / Reddit / ogbn-arxiv (Table 4) are
replaced by power-law graphs matching their vertex count, average degree,
feature dim and class count. A ``scale`` knob shrinks vertex count for unit
tests while preserving degree structure. Generation is vectorized numpy
(configuration-model with preferential weights, symmetrized, deduped).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.graphs.csr import CSRGraph, from_edge_list


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    num_vertices: int
    avg_degree: float        # directed out-degree before symmetrization
    feature_dim: int
    num_classes: int
    power: float = 2.2       # degree power-law exponent


# Paper Table 4 statistics. Reddit's 116M edges (~500 eff. degree) exceed
# this container's memory at full scale; its spec keeps the paper's stated
# degree-50 figure and benchmarks use scale<=0.5.
FLICKR = DatasetSpec("flickr", 89_250, 10.0, 500, 7)
REDDIT = DatasetSpec("reddit", 232_965, 50.0, 602, 41)
OGBN_ARXIV = DatasetSpec("ogbn-arxiv", 169_343, 7.0, 128, 40)

DATASETS = {d.name: d for d in (FLICKR, REDDIT, OGBN_ARXIV)}


def powerlaw_degrees(n: int, avg: float, power: float,
                     rng: np.random.Generator) -> np.ndarray:
    """Degree sequence ~ Pareto(power-1) scaled to the requested mean."""
    raw = (1.0 / rng.power(power - 1.0, size=n))  # pareto >= 1
    raw = np.clip(raw, 1.0, n / 4)
    deg = raw * (avg / raw.mean())
    return np.maximum(1, deg.round().astype(np.int64))


def make_graph(spec: DatasetSpec, scale: float = 1.0,
               seed: int = 0) -> CSRGraph:
    rng = np.random.default_rng(seed)
    n = max(64, int(spec.num_vertices * scale))
    deg = powerlaw_degrees(n, spec.avg_degree, spec.power, rng)
    m = int(deg.sum())
    src = np.repeat(np.arange(n, dtype=np.int64), deg)
    # preferential endpoint choice: weight by degree (power-law assortative)
    w = deg.astype(np.float64)
    p = w / w.sum()
    dst = rng.choice(n, size=m, p=p).astype(np.int64)
    # homophilous labels (like real GNN benchmarks): seed random labels,
    # then a few majority-propagation rounds over the edges so neighbors
    # correlate — aggregation then genuinely helps classification
    labels = rng.integers(0, spec.num_classes, size=n).astype(np.int32)
    for _ in range(3):
        onehot = np.zeros((n, spec.num_classes), np.float32)
        onehot[np.arange(n), labels] = 1.0
        votes = np.zeros_like(onehot)
        np.add.at(votes, dst, onehot[src])
        np.add.at(votes, src, onehot[dst])
        votes += 0.5 * onehot                    # self-weight breaks ties
        labels = votes.argmax(1).astype(np.int32)
    centers = rng.standard_normal((spec.num_classes, spec.feature_dim))
    feats = (centers[labels] +
             0.5 * rng.standard_normal((n, spec.feature_dim))
             ).astype(np.float32)
    return from_edge_list(src, dst, n, feats, symmetrize=True,
                          labels=labels, name=spec.name)


_CACHE: dict = {}


def get_graph(name: str, scale: float = 1.0, seed: int = 0) -> CSRGraph:
    key = (name, scale, seed)
    if key not in _CACHE:
        _CACHE[key] = make_graph(DATASETS[name], scale, seed)
    return _CACHE[key]


def zipf_traffic(g: CSRGraph, n_requests: int, a: float = 1.1,
                 seed: int = 0) -> np.ndarray:
    """Zipf(a) popularity-skewed request targets over a finite support,
    with popularity rank following vertex degree (hubs are hot — the
    realistic and cacheable serving regime the store subsystem targets).
    Exact finite-support sampling via inverse-CDF weights. THE one traffic
    model shared by bench_store, examples, and cache tests."""
    rng = np.random.default_rng(seed)
    v = g.num_vertices
    probs = 1.0 / np.arange(1, v + 1, dtype=np.float64) ** a
    probs /= probs.sum()
    ranks = rng.choice(v, size=n_requests, p=probs)
    by_degree = np.argsort(-g.degrees.astype(np.int64), kind="stable")
    return by_degree[ranks]
