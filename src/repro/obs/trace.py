"""Per-batch distributed tracing for the serving stack.

The paper's latency story is a per-stage breakdown (Fig. 3) plus a
scheduler that *hides* the CPU<->accelerator hop (Fig. 7) — claims that
aggregate counters can only support by arithmetic on averages. This
module records what actually happened to individual batches:

* every ``StreamTicket`` can carry a ``TraceContext``; the scheduler
  opens one span per pipeline station (select / build / pack / device),
  the engine adds child spans for the store gather and (sampled)
  per-ACK-op calibration runs, and the RPC layer stitches in the graph
  hosts' remote spans with a ping-based clock-offset correction — a true
  cross-host timeline of the overlap the scheduler claims;
* finished spans land in a bounded ring (export) and the K slowest
  batches keep their FULL span trees in a flight recorder (forensics);
* per-span durations also feed fixed-memory ``LogHistogram``s, so the
  report surfaces exact-from-buckets p50/p90/p99 without unbounded
  lists.

Tracing is **opt-in and zero-cost when off**: with
``ServingConfig(trace=None)`` (the default) no tracer object exists and
every instrumentation site is a single ``is None`` test; traced and
untraced runs produce bitwise-identical outputs because spans only
*time* the existing calls — they never reorder or replace them.
"""
from __future__ import annotations

import itertools
import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.obs.flight import FlightRecorder
from repro.obs.hist import LogHistogram

# One wall-clock anchor per process: span timestamps are
# ``time.time()``-anchored ``perf_counter`` deltas, so they are monotonic
# within the process at microsecond resolution while staying comparable
# across processes (after the ping-based offset correction).
_T0_WALL = time.time()
_T0_PERF = time.perf_counter()


def now() -> float:
    """Monotonic wall-clock seconds (see module anchor note)."""
    return _T0_WALL + (time.perf_counter() - _T0_PERF)


@dataclass(frozen=True)
class TraceConfig:
    """Knobs of the tracing subsystem (``ServingConfig(trace=...)``).

    sample_every     trace every Nth submitted batch (1 = all; the
                     default — span overhead is ~µs against ~ms batches)
    ring_capacity    finished spans retained for export (bounded; the
                     flight recorder keeps its own copies, so the K
                     slowest batches survive ring eviction)
    flight_k         slowest batches kept with full span trees
    calibrate_every  every Nth *traced* batch additionally runs the
                     instrumented per-ACK-op pass (obs.calib) to feed
                     the op x mode x size-bucket calibration table.
                     0 = off (the default: the pass re-executes the
                     program eagerly, roughly doubling that batch's
                     device work; its output is discarded, so serving
                     results stay bitwise-identical either way)
    """
    sample_every: int = 1
    ring_capacity: int = 8192
    flight_k: int = 8
    calibrate_every: int = 0

    def __post_init__(self):
        if self.sample_every < 1:
            raise ValueError("sample_every must be >= 1")
        if self.ring_capacity < 1:
            raise ValueError("ring_capacity must be >= 1")
        if self.flight_k < 0:
            raise ValueError("flight_k must be >= 0")
        if self.calibrate_every < 0:
            raise ValueError("calibrate_every must be >= 0 (0 = off)")

    def describe(self) -> dict:
        return {"sample_every": self.sample_every,
                "ring_capacity": self.ring_capacity,
                "flight_k": self.flight_k,
                "calibrate_every": self.calibrate_every}


@dataclass
class TraceContext:
    """Identity of one traced batch: rides on the StreamTicket and (as
    two ints) in the RPC wire meta."""
    trace_id: int
    root_id: int
    seq: int = -1
    t_start: float = field(default_factory=now)


class _SpanHandle:
    """Mutable in-flight span; becomes an immutable dict when closed."""

    __slots__ = ("name", "cat", "trace_id", "span_id", "parent_id",
                 "track", "t0", "args")

    def __init__(self, name, cat, trace_id, span_id, parent_id, track):
        self.name = name
        self.cat = cat
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.track = track
        self.t0 = now()
        self.args: Dict[str, Any] = {}

    def annotate(self, **kw) -> None:
        self.args.update(kw)


def span_dict(*, name: str, cat: str, trace_id: int, span_id: int,
              parent_id: Optional[int], t0: float, dur: float,
              host: str, track: str,
              args: Optional[dict] = None) -> dict:
    """The one span serialization every surface shares: plain JSON
    scalars only, so spans cross the wire codec and land in exported
    traces unchanged."""
    return {"name": name, "cat": cat, "trace_id": int(trace_id),
            "span_id": int(span_id),
            "parent_id": None if parent_id is None else int(parent_id),
            "t0": float(t0), "dur": float(dur), "host": host,
            "track": track, "args": dict(args or {})}


def _id_base() -> int:
    """Per-process span-id namespace: remote hosts allocate ids in their
    own range, so stitched trees never collide with local span ids."""
    return (os.getpid() & 0xFFFFF) << 40


class SpanAllocator:
    """Process-unique span-id source (used by Tracer and the graph host
    service, which emits spans without a full Tracer). The counter is
    class-level: with the inproc transport, client tracer and graph-host
    service live in ONE process and share the pid prefix, so separate
    counters would hand out colliding ids."""

    _counter = itertools.count(1)

    def __init__(self):
        self._base = _id_base()

    def next_id(self) -> int:
        return self._base | next(SpanAllocator._counter)


class Tracer:
    """Per-deployment trace collector (one per DecoupledEngine).

    Thread model: spans open/close on whatever thread runs the work
    (stage stations, the scheduler dispatcher, RPC workers). A
    thread-local stack carries the *current* span so nested
    instrumentation sites (store gather inside the device span, RPC
    annotations inside the stage span) need no context plumbing; the
    per-ticket ``TraceContext`` hops threads on the ticket itself.
    """

    def __init__(self, config: Optional[TraceConfig] = None,
                 host: str = "client"):
        self.config = config or TraceConfig()
        self.host = host
        self._ids = SpanAllocator()
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=self.config.ring_capacity)
        self._live: Dict[int, List[dict]] = {}   # trace_id -> spans
        self._tls = threading.local()
        self._submitted = 0
        self.tickets_traced = 0
        self.spans_recorded = 0
        self.spans_dropped = 0          # ring evictions
        self.remote_spans = 0
        self.flight = FlightRecorder(self.config.flight_k)
        self.hists: Dict[str, LogHistogram] = {}
        # endpoint -> {"offset_s", "rtt_s"}: remote wall clock minus
        # local, estimated from ping round-trips (rpc.estimate_clock_
        # offsets); remote span timestamps subtract the offset
        self.clock_sync: Dict[str, dict] = {}

    # -- sampling ------------------------------------------------------------
    def maybe_trace(self, seq: int = -1) -> Optional[TraceContext]:
        """Per-submitted-batch sampling decision; returns a context for
        every ``sample_every``-th batch, else None (untraced batches pay
        exactly one None check everywhere downstream)."""
        with self._lock:
            n = self._submitted
            self._submitted += 1
            if n % self.config.sample_every:
                return None
            self.tickets_traced += 1
            ctx = TraceContext(trace_id=self._ids.next_id(),
                               root_id=self._ids.next_id(), seq=seq)
            self._live[ctx.trace_id] = []
        return ctx

    # -- thread-local current span -------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def current(self) -> Optional[_SpanHandle]:
        st = getattr(self._tls, "stack", None)
        return st[-1] if st else None

    def current_ids(self) -> Optional[Tuple[int, int]]:
        """(trace_id, span_id) of the innermost open span on this
        thread, or None — what the RPC layer puts in the wire meta."""
        cur = self.current()
        return None if cur is None else (cur.trace_id, cur.span_id)

    def annotate(self, **kw) -> None:
        """Attach args to the innermost open span (no-op without one)."""
        cur = self.current()
        if cur is not None:
            cur.annotate(**kw)

    # -- spans ---------------------------------------------------------------
    @contextmanager
    def span(self, name: str, *, ctx: Optional[TraceContext] = None,
             cat: str = "stage", track: Optional[str] = None, **args):
        """Open a span. Parenting: explicit ``ctx`` makes this a child
        of the batch's root; otherwise the innermost open span on this
        thread is the parent. With neither, the site is running an
        untraced batch — yield a no-op handle and record nothing."""
        cur = self.current()
        if ctx is not None:
            trace_id, parent = ctx.trace_id, ctx.root_id
            if cur is not None and cur.trace_id == trace_id:
                parent = cur.span_id
        elif cur is not None:
            trace_id, parent = cur.trace_id, cur.span_id
        else:
            yield None
            return
        h = _SpanHandle(name, cat, trace_id, self._ids.next_id(),
                        parent, track or name)
        # the recording OS thread keys the exporter's lane split: spans
        # from one thread form a stack, so B/E nesting per lane is exact
        h.args["tid"] = threading.get_ident() & 0xFFFFFF
        if args:
            h.args.update(args)
        stack = self._stack()
        stack.append(h)
        try:
            yield h
        finally:
            stack.pop()
            self._record(span_dict(
                name=h.name, cat=h.cat, trace_id=h.trace_id,
                span_id=h.span_id, parent_id=h.parent_id, t0=h.t0,
                dur=now() - h.t0, host=self.host, track=h.track,
                args=h.args))
            self.hist(h.name).record(now() - h.t0)

    @contextmanager
    def root_span(self, name: str, *, cat: str = "stage",
                  track: Optional[str] = None, **args):
        """Open a PARENTLESS span in its own fresh trace — for
        background work (e.g. precompute refresh chunks) that runs
        outside any ticket context, where ``span()`` would record
        nothing. The span lands straight in the export ring and feeds
        the per-name histogram; child ``span()`` calls on the same
        thread nest under it as usual."""
        h = _SpanHandle(name, cat, self._ids.next_id(),
                        self._ids.next_id(), None, track or name)
        h.args["tid"] = threading.get_ident() & 0xFFFFFF
        if args:
            h.args.update(args)
        stack = self._stack()
        stack.append(h)
        try:
            yield h
        finally:
            stack.pop()
            self._record(span_dict(
                name=h.name, cat=h.cat, trace_id=h.trace_id,
                span_id=h.span_id, parent_id=None, t0=h.t0,
                dur=now() - h.t0, host=self.host, track=h.track,
                args=h.args))
            self.hist(h.name).record(now() - h.t0)

    def _record(self, sp: dict) -> None:
        with self._lock:
            self.spans_recorded += 1
            live = self._live.get(sp["trace_id"])
            if live is not None:
                live.append(sp)
            else:                       # ticket already finished (late
                self._ring_append(sp)   # drain span) — straight to ring

    def _ring_append(self, sp: dict) -> None:
        if len(self._ring) == self._ring.maxlen:
            self.spans_dropped += 1
        self._ring.append(sp)

    def ingest_remote(self, spans: Sequence[dict],
                      endpoint: str) -> None:
        """Stitch a graph host's spans into their batch's tree: shift
        timestamps by the endpoint's estimated clock offset (remote
        clock minus local — subtracting maps them onto THIS process's
        timeline) and tag the source endpoint."""
        off = self.clock_sync.get(endpoint, {}).get("offset_s", 0.0)
        with self._lock:
            for sp in spans:
                sp = dict(sp, t0=float(sp["t0"]) - off,
                          args=dict(sp.get("args") or {},
                                    endpoint=endpoint,
                                    clock_offset_s=round(off, 6)))
                self.remote_spans += 1
                self.spans_recorded += 1
                live = self._live.get(sp["trace_id"])
                if live is not None:
                    live.append(sp)
                else:
                    self._ring_append(sp)

    # -- ticket lifecycle ----------------------------------------------------
    def finish_ticket(self, ctx: TraceContext, *, error: bool = False,
                      **root_args) -> None:
        """Close a traced batch: emit its root span, move its tree to
        the export ring, offer it to the flight recorder, and feed the
        batch-latency histogram."""
        dur = now() - ctx.t_start
        # batch roots of PIPELINED batches overlap in time, so spread
        # them over 16 sub-lanes by seq (B/E events on one exporter lane
        # must nest; 16 > max_inflight for any sane depth)
        root = span_dict(name="batch", cat="batch",
                         trace_id=ctx.trace_id, span_id=ctx.root_id,
                         parent_id=None, t0=ctx.t_start, dur=dur,
                         host=self.host, track="batch",
                         args=dict(root_args, seq=ctx.seq, error=error,
                                   tid=ctx.seq % 16))
        with self._lock:
            tree = self._live.pop(ctx.trace_id, [])
            tree.append(root)
            for sp in tree:
                self._ring_append(sp)
            self.spans_recorded += 1
        self.hist("batch").record(dur)
        self.flight.offer(ctx.trace_id, dur, tree,
                          meta=dict(root_args, seq=ctx.seq, error=error))

    def discard_ticket(self, ctx: TraceContext) -> None:
        """Drop a context that never ran (submit raced a close)."""
        with self._lock:
            self._live.pop(ctx.trace_id, None)

    # -- metrics -------------------------------------------------------------
    def hist(self, name: str) -> LogHistogram:
        h = self.hists.get(name)
        if h is None:
            with self._lock:
                h = self.hists.setdefault(name, LogHistogram())
        return h

    # -- export --------------------------------------------------------------
    def export_spans(self) -> List[dict]:
        """Snapshot of the finished-span ring plus the flight recorder's
        retained trees (deduped by span id) — everything the chrome
        trace exporter needs."""
        with self._lock:
            spans = list(self._ring)
        seen = {sp["span_id"] for sp in spans}
        for entry in self.flight.entries():
            for sp in entry["spans"]:
                if sp["span_id"] not in seen:
                    seen.add(sp["span_id"])
                    spans.append(sp)
        return sorted(spans, key=lambda s: s["t0"])

    def report(self) -> dict:
        """The ``trace.*`` reporting section (versioned key map in
        core.report_schema)."""
        with self._lock:
            d = {"enabled": True, **self.config.describe(),
                 "tickets_traced": self.tickets_traced,
                 "spans": self.spans_recorded,
                 "spans_dropped": self.spans_dropped,
                 "remote_spans": self.remote_spans,
                 "host": self.host}
        d["hists"] = {k: h.to_dict() for k, h in self.hists.items()}
        d["flight"] = self.flight.summary()
        if self.clock_sync:
            d["clock_sync"] = {ep: {k: round(v, 6) for k, v in s.items()}
                               for ep, s in self.clock_sync.items()}
        return d


__all__ = ["TraceConfig", "TraceContext", "Tracer", "SpanAllocator",
           "span_dict", "now"]
