"""Streaming log-bucketed histograms + a fixed-size reservoir.

The serving stack used to keep every per-request latency in an unbounded
Python list — fine for a benchmark, a slow leak for a server that handles
millions of requests. ``LogHistogram`` is the HDR-histogram idea in fixed
memory: geometric buckets with ``2**(1/16)`` growth (~2.2% bucket width),
so any quantile read off the bucket counts is within ~±2.2% of the true
value while memory stays a few hundred int64 counters regardless of how
many samples were recorded. Count/sum/min/max are tracked exactly, so
``mean`` has no bucket error at all.

``Reservoir`` is the companion raw-sample window: the last ``capacity``
values verbatim (recent forensics — exact values for the newest traffic),
also O(1) in stream length.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Dict, Optional

import numpy as np

# bucket boundaries: value_floor * GROWTH**i ; 16 buckets per doubling
_BUCKETS_PER_DOUBLING = 16
_LOG2_SCALE = float(_BUCKETS_PER_DOUBLING)


class LogHistogram:
    """Fixed-memory streaming histogram over (0, +inf) with bounded
    relative error per bucket.

    ``value_floor`` is the resolution floor: everything at or below it
    lands in bucket 0 (default 1 microsecond — nothing in this codebase
    times shorter). Values above ``value_ceil`` clamp into the last
    bucket. ``quantile`` returns the geometric midpoint of the bucket
    holding the q-th sample — deterministic, exact in bucket units.
    """

    __slots__ = ("value_floor", "counts", "count", "total", "min", "max")

    def __init__(self, value_floor: float = 1e-6,
                 value_ceil: float = 4096.0):
        if value_floor <= 0 or value_ceil <= value_floor:
            raise ValueError("need 0 < value_floor < value_ceil")
        self.value_floor = float(value_floor)
        n = int(math.ceil(math.log2(value_ceil / value_floor)
                          * _LOG2_SCALE)) + 2
        self.counts = np.zeros(n, dtype=np.int64)
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = 0.0

    def _index(self, value: float) -> int:
        if value <= self.value_floor:
            return 0
        i = int(math.log2(value / self.value_floor) * _LOG2_SCALE) + 1
        return min(i, len(self.counts) - 1)

    def _bucket_value(self, i: int) -> float:
        """Geometric midpoint of bucket ``i`` (the quantile estimate)."""
        if i == 0:
            return self.value_floor
        return self.value_floor * 2.0 ** ((i - 0.5) / _LOG2_SCALE)

    def record(self, value: float) -> None:
        v = float(value)
        if v < 0 or v != v:               # negatives/NaN never count
            return
        self.counts[self._index(v)] += 1
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """q in [0, 1]; returns 0.0 for an empty histogram. Clamped to
        the exact observed [min, max] so the bucket-midpoint estimate
        never leaves the data's true range."""
        if self.count == 0:
            return 0.0
        rank = min(self.count - 1, int(q * self.count))
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, rank + 1))
        return float(min(max(self._bucket_value(i), self.min), self.max))

    def percentiles(self) -> Dict[str, float]:
        return {"p50": self.quantile(0.50), "p90": self.quantile(0.90),
                "p99": self.quantile(0.99)}

    def fraction_above(self, threshold: float) -> float:
        """Fraction of recorded samples strictly above ``threshold``,
        read off the bucket counts (a sample in the threshold's own
        bucket counts by its geometric midpoint, so the answer is exact
        up to one ~2.2% bucket). This is the SLO tracker's "bad event"
        fraction for latency objectives."""
        if self.count == 0:
            return 0.0
        t = float(threshold)
        above = sum(int(self.counts[i])
                    for i in np.nonzero(self.counts)[0]
                    if self._bucket_value(int(i)) > t)
        return above / self.count

    def merge(self, other: "LogHistogram") -> "LogHistogram":
        if other.value_floor != self.value_floor or \
                len(other.counts) != len(self.counts):
            raise ValueError("cannot merge histograms with different "
                             "bucket schemes")
        self.counts += other.counts
        self.count += other.count
        self.total += other.total
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        return self

    def to_dict(self) -> dict:
        """Sparse serialization: only non-empty buckets, keyed by index,
        plus the scheme (floor + growth) needed to reconstruct bounds."""
        nz = np.nonzero(self.counts)[0]
        return {"scheme": "log2", "buckets_per_doubling":
                _BUCKETS_PER_DOUBLING,
                "value_floor": self.value_floor,
                "count": int(self.count),
                "mean": round(self.mean, 9),
                "min": 0.0 if self.count == 0 else round(self.min, 9),
                "max": round(self.max, 9),
                **{k: round(v, 9) for k, v in self.percentiles().items()},
                "counts": {int(i): int(self.counts[i]) for i in nz}}

    @classmethod
    def from_dict(cls, d: dict) -> "LogHistogram":
        """Inverse of ``to_dict``: rebuild a histogram from its sparse
        serialization (bucket counts restore exactly, so quantiles are
        bit-identical; ``total`` is recovered as mean*count). Accepts
        string bucket keys — JSON round-trips turn int keys into str."""
        if d.get("scheme") != "log2":
            raise ValueError(f"unknown histogram scheme {d.get('scheme')!r}")
        if d.get("buckets_per_doubling") != _BUCKETS_PER_DOUBLING:
            raise ValueError(
                f"bucket scheme mismatch: serialized "
                f"{d.get('buckets_per_doubling')} buckets/doubling vs "
                f"this build's {_BUCKETS_PER_DOUBLING}")
        h = cls(value_floor=float(d.get("value_floor", 1e-6)))
        for i, c in (d.get("counts") or {}).items():
            h.counts[min(int(i), len(h.counts) - 1)] += int(c)
        h.count = int(d.get("count", 0))
        h.total = float(d.get("mean", 0.0)) * h.count
        if h.count:
            h.min = float(d.get("min", 0.0))
            h.max = float(d.get("max", 0.0))
        return h

    @property
    def nbytes(self) -> int:
        """Fixed memory footprint (the O(1)-in-samples property)."""
        return int(self.counts.nbytes)


class Reservoir:
    """Last-``capacity`` raw values, O(1) memory in stream length."""

    __slots__ = ("_buf",)

    def __init__(self, capacity: int = 256):
        self._buf: deque = deque(maxlen=int(capacity))

    def record(self, value: float) -> None:
        self._buf.append(float(value))

    def values(self) -> list:
        return list(self._buf)

    def __len__(self) -> int:
        return len(self._buf)

    @property
    def capacity(self) -> int:
        return self._buf.maxlen


def merge_hist_dicts(a: Optional[dict], b: Optional[dict]) -> dict:
    """Losslessly merge two ``LogHistogram.to_dict()`` payloads (bucket
    counts add, count/min/max/mean combine exactly, quantiles recompute
    from the merged counts). This is how per-host histograms from a
    cluster metrics scrape fold into one view: merged count equals the
    sum of the per-host counts by construction. Bucket schemes must
    match (same floor + growth); JSON round-trips may have stringified
    the bucket keys, both spellings are accepted."""
    if not a:
        return dict(b or {})
    if not b:
        return dict(a)
    if a.get("value_floor") != b.get("value_floor") or \
            a.get("buckets_per_doubling") != b.get("buckets_per_doubling"):
        raise ValueError("cannot merge histograms with different "
                         "bucket schemes")
    counts: Dict[int, int] = {}
    for d in (a, b):
        for k, v in (d.get("counts") or {}).items():
            counts[int(k)] = counts.get(int(k), 0) + int(v)
    ca, cb = int(a.get("count", 0)), int(b.get("count", 0))
    n = ca + cb
    mean = (a.get("mean", 0.0) * ca + b.get("mean", 0.0) * cb) / n \
        if n else 0.0
    out = {"scheme": "log2",
           "buckets_per_doubling": a.get("buckets_per_doubling",
                                         _BUCKETS_PER_DOUBLING),
           "value_floor": a["value_floor"], "count": n,
           "mean": round(mean, 9),
           "min": min(a.get("min", math.inf), b.get("min", math.inf))
           if n else 0.0,
           "max": max(a.get("max", 0.0), b.get("max", 0.0)),
           "counts": {k: counts[k] for k in sorted(counts)}}
    for name, q in (("p50", 0.50), ("p90", 0.90), ("p99", 0.99)):
        v = hist_dict_quantile(out, q)
        out[name] = round(v, 9) if v is not None else 0.0
    return out


def hist_dict_quantile(d: dict, q: float) -> Optional[float]:
    """Read a quantile back out of a ``LogHistogram.to_dict()`` payload
    (export-side tooling works on serialized histograms)."""
    counts = d.get("counts") or {}
    total = sum(counts.values())
    if not total:
        return None
    floor = d["value_floor"]
    per = d.get("buckets_per_doubling", _BUCKETS_PER_DOUBLING)
    rank = min(total - 1, int(q * total))
    cum = 0
    for i in sorted(int(k) for k in counts):
        cum += counts[i]
        if cum > rank:
            v = floor if i == 0 else floor * 2.0 ** ((i - 0.5) / per)
            return min(max(v, d.get("min", v)), d.get("max", v))
    return None


__all__ = ["LogHistogram", "Reservoir", "hist_dict_quantile",
           "merge_hist_dicts"]
