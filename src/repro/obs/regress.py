"""Benchmark-trajectory regression gate: ``python -m repro.obs.regress``.

Every benchmark appends one point per run to its tracked trajectory
(``results/BENCH_<name>.json``, a JSON list). Points that want to be
gated carry a ``regress`` dict of lower-is-better scalars, e.g.::

    {"ts": ..., "regress": {"p50_ms": 1.8, "p99_ms": 4.1}, ...}

This module compares each metric's NEWEST value against the MEDIAN of
its history (all earlier points that carry the metric): a regression is
``newest > median * (1 + tolerance)``. The median makes the baseline
robust to one noisy historical point; the tolerance absorbs normal CI
jitter. Metrics need ``min_history`` historical points before they are
judged — young trajectories report ``insufficient history`` and pass.

Exit status 0 = clean (or nothing to judge), 1 = at least one
regression. CI runs this right after the bench smokes so a perf cliff
fails the build with the offending metric named.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
from pathlib import Path
from typing import Dict, List, Optional

DEFAULT_TOLERANCE = 0.35
DEFAULT_MIN_HISTORY = 3


def check_trajectory(points: List[dict], *,
                     tolerance: float = DEFAULT_TOLERANCE,
                     min_history: int = DEFAULT_MIN_HISTORY
                     ) -> List[dict]:
    """Judge the newest point of one trajectory against its history.
    Returns one row per gated metric:
    ``{"metric", "newest", "median", "limit", "n_history", "status"}``
    with status ``ok`` / ``regression`` / ``insufficient_history``."""
    rows: List[dict] = []
    if not points:
        return rows
    newest = points[-1].get("regress") or {}
    for metric, value in sorted(newest.items()):
        try:
            v = float(value)
        except (TypeError, ValueError):
            continue
        history = [float(p["regress"][metric]) for p in points[:-1]
                   if isinstance(p.get("regress"), dict)
                   and metric in p["regress"]]
        if len(history) < min_history:
            rows.append({"metric": metric, "newest": v,
                         "median": None, "limit": None,
                         "n_history": len(history),
                         "status": "insufficient_history"})
            continue
        median = statistics.median(history)
        limit = median * (1.0 + tolerance)
        rows.append({"metric": metric, "newest": v,
                     "median": median, "limit": limit,
                     "n_history": len(history),
                     "status": "regression" if v > limit else "ok"})
    return rows


def load_trajectory(path: Path) -> Optional[List[dict]]:
    try:
        data = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError):
        return None
    return data if isinstance(data, list) else None


def check_dir(results_dir: Path, *,
              tolerance: float = DEFAULT_TOLERANCE,
              min_history: int = DEFAULT_MIN_HISTORY
              ) -> Dict[str, List[dict]]:
    out: Dict[str, List[dict]] = {}
    for path in sorted(results_dir.glob("BENCH_*.json")):
        points = load_trajectory(path)
        if points is None:
            out[path.name] = [{"metric": None, "status": "unreadable"}]
            continue
        out[path.name] = check_trajectory(
            points, tolerance=tolerance, min_history=min_history)
    return out


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.regress",
        description="Gate the newest benchmark trajectory points "
                    "against their history.")
    ap.add_argument("--results-dir", default="results",
                    help="directory holding BENCH_*.json trajectories")
    ap.add_argument("--tolerance", type=float,
                    default=DEFAULT_TOLERANCE,
                    help="allowed fractional slowdown vs the median "
                         "(default %(default)s)")
    ap.add_argument("--min-history", type=int,
                    default=DEFAULT_MIN_HISTORY,
                    help="historical points required before judging "
                         "(default %(default)s)")
    args = ap.parse_args(argv)

    results_dir = Path(args.results_dir)
    if not results_dir.is_dir():
        print(f"regress: no results dir at {results_dir}", flush=True)
        return 0
    report = check_dir(results_dir, tolerance=args.tolerance,
                       min_history=args.min_history)
    if not report:
        print("regress: no trajectories found", flush=True)
        return 0
    failed = False
    for name, rows in report.items():
        if not rows:
            print(f"  {name}: no gated metrics")
            continue
        for r in rows:
            if r["status"] == "unreadable":
                print(f"  {name}: unreadable trajectory (skipped)")
                continue
            if r["status"] == "insufficient_history":
                print(f"  {name}: {r['metric']}={r['newest']:g} "
                      f"(only {r['n_history']} historical points, "
                      f"not judged)")
                continue
            mark = "REGRESSION" if r["status"] == "regression" else "ok"
            print(f"  {name}: {r['metric']}={r['newest']:g} "
                  f"median={r['median']:g} limit={r['limit']:g} "
                  f"[{mark}]")
            failed = failed or r["status"] == "regression"
    print("regress: FAIL" if failed else "regress: ok", flush=True)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
