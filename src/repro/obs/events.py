"""Bounded structured event ring — the telemetry plane's alert channel.

Watchdog detections (p99 drift, cache-hit collapse, refresh-backlog
growth), SLO burn-rate breaches, and host-quarantine notices all land
here as plain-dict events: a fixed-capacity ring (old events roll off,
evictions counted) that serving never blocks on and reports surface
verbatim. Events are JSON-scalar trees only, so they cross the wire
codec and land in ``telemetry.*`` report sections unchanged.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Dict, List, Optional

from repro.obs.trace import now

SEVERITIES = ("info", "warn", "crit")


class EventRing:
    """Thread-safe bounded ring of structured events.

    ``emit`` never blocks and never raises on serving paths; when the
    ring is full the oldest event is dropped (counted in ``dropped``).
    """

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self._ring: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._seq = 0
        self.emitted = 0
        self.dropped = 0
        self.by_severity: Dict[str, int] = {s: 0 for s in SEVERITIES}

    def emit(self, kind: str, severity: str = "info",
             message: str = "", **data) -> dict:
        """Record one event; returns the event dict (already ringed)."""
        if severity not in SEVERITIES:
            raise ValueError(
                f"severity={severity!r}, expected one of {SEVERITIES}")
        with self._lock:
            ev = {"seq": self._seq, "t": now(), "kind": str(kind),
                  "severity": severity, "message": str(message),
                  "data": dict(data)}
            self._seq += 1
            self.emitted += 1
            self.by_severity[severity] += 1
            if len(self._ring) == self._ring.maxlen:
                self.dropped += 1
            self._ring.append(ev)
        return ev

    def snapshot(self, limit: Optional[int] = None,
                 kind: Optional[str] = None,
                 min_severity: str = "info") -> List[dict]:
        """Newest-last copy of the retained events, optionally filtered
        by kind and minimum severity."""
        floor = SEVERITIES.index(min_severity)
        with self._lock:
            evs = list(self._ring)
        evs = [e for e in evs
               if SEVERITIES.index(e["severity"]) >= floor
               and (kind is None or e["kind"] == kind)]
        return evs[-limit:] if limit else evs

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def summary(self, recent: int = 16) -> dict:
        """The ``telemetry.events`` report slice: counters + the newest
        ``recent`` events verbatim."""
        with self._lock:
            counts = dict(self.by_severity)
            emitted, dropped = self.emitted, self.dropped
            tail = list(self._ring)[-recent:]
        return {"emitted": emitted, "dropped": dropped,
                "capacity": self.capacity, "by_severity": counts,
                "recent": tail}


__all__ = ["EventRing", "SEVERITIES"]
