"""Chrome trace-event export: span trees -> a Perfetto-loadable JSON.

Emits the Trace Event Format's JSON-array flavor (``{"traceEvents":
[...]}``) using duration events (``ph: "B"``/``"E"``), which both
``chrome://tracing`` and https://ui.perfetto.dev load directly.

Lane layout: one *process* per host (client, each graph-host endpoint)
and one *thread* per (track, OS-thread) pair within it — pipeline
stations (select / build / pack / device / rpc) each get their own lane,
and splitting by the recording OS thread guarantees the B/E events on
every lane are properly nested (each OS thread opens/closes spans as a
stack; two RPC workers sharing one lane would interleave their B/E
pairs and corrupt the nesting).

``validate_chrome_trace`` checks the invariants the CI smoke gates on:
every ``B`` has a matching same-lane ``E``, stacks close in LIFO order,
timestamps are non-negative and monotone per lane, and the span-level
parent references resolve.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Sequence, Tuple


def _lane_maps(spans: Sequence[dict]
               ) -> Tuple[Dict[str, int], Dict[tuple, int]]:
    """Stable pid per host, tid per (host, track, thread) lane."""
    pids: Dict[str, int] = {}
    tids: Dict[tuple, int] = {}
    for sp in spans:
        host = sp.get("host", "client")
        if host not in pids:
            pids[host] = len(pids) + 1
        lane = (host, sp.get("track") or sp["name"],
                sp.get("args", {}).get("tid", 0))
        if lane not in tids:
            tids[lane] = len(tids) + 1
    return pids, tids


def _span_lane(sp: dict, pids, tids) -> Tuple[int, int, str]:
    host = sp.get("host", "client")
    track = sp.get("track") or sp["name"]
    return (pids[host],
            tids[(host, track, sp.get("args", {}).get("tid", 0))], track)


def to_chrome_trace(spans: Sequence[dict]) -> dict:
    """Span dicts (obs.trace.span_dict shape) -> trace-event JSON tree.

    Timestamps are microseconds relative to the earliest span — Perfetto
    renders relative time anyway and small numbers keep the file compact.
    """
    spans = sorted(spans, key=lambda s: (s["t0"], -s["dur"]))
    pids, tids = _lane_maps(spans)
    t_base = spans[0]["t0"] if spans else 0.0
    events: List[dict] = []
    for host, pid in pids.items():
        events.append({"ph": "M", "name": "process_name", "pid": pid,
                       "tid": 0, "args": {"name": host}})
    for (host, track, _thr), tid in tids.items():
        events.append({"ph": "M", "name": "thread_name",
                       "pid": pids[host], "tid": tid,
                       "args": {"name": track}})
    # Per-lane stack simulation. Spans on one lane come from one OS
    # thread's span stack, so they nest exactly (child window inside
    # parent window) — emitting B when a span starts and E when a later
    # span's start passes an open span's end reconstructs the correct
    # LIFO B/E sequence even for zero-duration and equal-timestamp spans
    # (where a plain global timestamp sort would misorder them).
    by_lane: Dict[tuple, List[dict]] = {}
    for sp in spans:
        by_lane.setdefault(_span_lane(sp, pids, tids), []).append(sp)
    for (pid, tid, _track), lane_spans in sorted(by_lane.items(),
                                                 key=lambda t: t[0][:2]):
        lane_spans.sort(key=lambda s: (s["t0"], -s["dur"]))
        open_stack: List[tuple] = []     # (t_end_us, E-event)
        for sp in lane_spans:
            ts = (sp["t0"] - t_base) * 1e6
            dur = max(sp["dur"], 0.0) * 1e6
            while open_stack and open_stack[-1][0] <= ts:
                events.append(open_stack.pop()[1])
            args = {k: v for k, v in sp.get("args", {}).items()
                    if k != "tid"}
            args["trace_id"] = sp["trace_id"]
            args["span_id"] = sp["span_id"]
            if sp.get("parent_id") is not None:
                args["parent_id"] = sp["parent_id"]
            base = {"name": sp["name"], "cat": sp.get("cat", "stage"),
                    "pid": pid, "tid": tid}
            events.append(dict(base, ph="B", ts=ts, args=args))
            # clamp into the parent window: nested recording guarantees
            # containment on live spans; the clamp keeps stitched remote
            # spans (shifted by an *estimated* clock offset) well-formed
            t_end = ts + dur
            if open_stack:
                t_end = min(t_end, open_stack[-1][0])
            open_stack.append((max(t_end, ts),
                               dict(base, ph="E", ts=max(t_end, ts))))
        while open_stack:
            events.append(open_stack.pop()[1])
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, spans: Sequence[dict],
                       metadata: dict = None) -> dict:
    tree = to_chrome_trace(spans)
    if metadata:
        tree["metadata"] = metadata
    with open(path, "w") as f:
        json.dump(tree, f, separators=(",", ":"))
    return tree


def validate_chrome_trace(tree: dict) -> List[str]:
    """Shape invariants of an exported trace; returns a list of problems
    (empty = valid). This is what the CI bench smoke gates on."""
    problems: List[str] = []
    events = tree.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    stacks: Dict[tuple, list] = {}
    last_ts: Dict[tuple, float] = {}
    span_ids = set()
    parent_refs = []
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph == "M":
            continue
        if ph not in ("B", "E"):
            problems.append(f"event {i}: unexpected ph={ph!r}")
            continue
        lane = (ev.get("pid"), ev.get("tid"))
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i}: bad ts {ts!r}")
            continue
        if ts < last_ts.get(lane, 0.0) - 1e-6:
            problems.append(
                f"event {i}: ts went backwards on lane {lane}")
        last_ts[lane] = ts
        stack = stacks.setdefault(lane, [])
        if ph == "B":
            stack.append(ev.get("name"))
            args = ev.get("args", {})
            if "span_id" in args:
                span_ids.add(args["span_id"])
            if args.get("parent_id") is not None:
                parent_refs.append((i, args["parent_id"]))
        else:
            if not stack:
                problems.append(
                    f"event {i}: E with no open B on lane {lane}")
            elif stack[-1] != ev.get("name"):
                problems.append(
                    f"event {i}: E {ev.get('name')!r} closes "
                    f"{stack[-1]!r} (non-LIFO) on lane {lane}")
                stack.pop()
            else:
                stack.pop()
    for lane, stack in stacks.items():
        for name in stack:
            problems.append(f"unclosed B {name!r} on lane {lane}")
    for i, pid in parent_refs:
        if pid not in span_ids:
            problems.append(
                f"event {i}: parent_id {pid} resolves to no span")
    return problems


def containment(spans: Sequence[dict], outer_name: str,
                inner_host: str, slack_s: float = 0.0) -> List[str]:
    """Check that every remote span from ``inner_host`` lies inside its
    batch's ``outer_name`` span window (the clock-offset acceptance
    gate). Returns violations (empty = all contained)."""
    outer: Dict[int, Tuple[float, float]] = {}
    for sp in spans:
        if sp["name"] == outer_name:
            t0, t1 = sp["t0"], sp["t0"] + sp["dur"]
            if sp["trace_id"] in outer:
                o0, o1 = outer[sp["trace_id"]]
                t0, t1 = min(t0, o0), max(t1, o1)
            outer[sp["trace_id"]] = (t0, t1)
    bad = []
    for sp in spans:
        if sp.get("host") != inner_host:
            continue
        win = outer.get(sp["trace_id"])
        if win is None:
            bad.append(f"remote span {sp['name']} trace {sp['trace_id']}"
                       f" has no {outer_name} span")
            continue
        t0, t1 = sp["t0"], sp["t0"] + sp["dur"]
        if t0 < win[0] - slack_s or t1 > win[1] + slack_s:
            bad.append(
                f"remote span {sp['name']} [{t0:.6f},{t1:.6f}] outside "
                f"{outer_name} [{win[0]:.6f},{win[1]:.6f}] "
                f"(trace {sp['trace_id']})")
    return bad


def main(argv=None) -> int:
    """``python -m repro.obs.export``: convert a span-dump JSON (list of
    span dicts, e.g. a flight-recorder entry) to a chrome trace, or
    validate an already-exported trace."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.export",
        description="Span dump -> Perfetto-loadable chrome trace "
                    "(or validate one)")
    ap.add_argument("input", help="JSON file: a list of span dicts, or "
                    "a chrome trace when --validate is given")
    ap.add_argument("-o", "--out", default=None,
                    help="output trace path (default: <input>.trace.json)")
    ap.add_argument("--validate", action="store_true",
                    help="treat input as a chrome trace and validate it")
    args = ap.parse_args(argv)
    with open(args.input) as f:
        tree = json.load(f)
    if args.validate:
        problems = validate_chrome_trace(tree)
        for p in problems:
            print(f"INVALID: {p}")
        print(f"{args.input}: "
              f"{'OK' if not problems else f'{len(problems)} problems'}")
        return 1 if problems else 0
    spans = tree if isinstance(tree, list) else tree.get("spans", [])
    out = args.out or args.input.rsplit(".json", 1)[0] + ".trace.json"
    exported = write_chrome_trace(out, spans)
    problems = validate_chrome_trace(exported)
    n = sum(1 for e in exported["traceEvents"] if e.get("ph") == "B")
    print(f"wrote {out}: {n} spans "
          f"({'valid' if not problems else problems})")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())


__all__ = ["to_chrome_trace", "write_chrome_trace",
           "validate_chrome_trace", "containment", "main"]
