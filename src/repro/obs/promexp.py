"""Prometheus text exposition (format 0.0.4) for the metrics wire form.

``render_wire`` turns a ``MetricsRegistry.collect()`` tree (or a
``merge_wire`` cluster view) into the plain-text format every
Prometheus-compatible scraper speaks: ``# HELP`` / ``# TYPE`` comment
lines followed by one sample line per series. Histograms render as the
classic cumulative triplet — ``_bucket{le="..."}`` lines with
monotonically non-decreasing counts, ``_sum``, ``_count``, and a final
``le="+Inf"`` bucket equal to ``_count``. Our log-bucketed histograms
map naturally: bucket ``i``'s upper bound is
``value_floor * 2**(i / buckets_per_doubling)`` and sparse empty runs
collapse into the next non-empty bucket's cumulative count.

``validate_exposition`` is the in-repo conformance check (tests and the
CI metrics smoke use it — no Prometheus binary in the container): it
parses the text back and returns a list of problems, empty when clean.

``MetricsHTTPServer`` is the tiny stdlib endpoint (`GET /metrics`)
GNNServer and the graph-host CLI mount; threaded, daemonized, port 0
picks an ephemeral port.
"""
from __future__ import annotations

import math
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Dict, List, Optional, Tuple

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def _escape_label(v: str) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"') \
        .replace("\n", r"\n")


def _fmt_labels(labels: Dict[str, str],
                extra: Optional[Tuple[str, str]] = None) -> str:
    items = sorted(labels.items())
    if extra is not None:
        items = items + [extra]
    if not items:
        return ""
    return "{" + ",".join(f'{k}="{_escape_label(v)}"'
                          for k, v in items) + "}"


def _fmt_value(v: float) -> str:
    if v != v:
        return "NaN"
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def _bucket_bound(i: int, floor: float, per: int) -> float:
    """Upper bound of log bucket ``i`` (bucket 0 holds <= floor)."""
    return floor if i == 0 else floor * 2.0 ** (i / per)


def render_wire(wire: dict) -> str:
    """Render a metrics wire form to Prometheus text format 0.0.4."""
    out: List[str] = []
    for name, fam in wire.get("families", {}).items():
        mtype = fam["type"]
        help_ = fam.get("help") or name
        out.append(f"# HELP {name} "
                   + str(help_).replace("\\", r"\\").replace("\n", r"\n"))
        out.append(f"# TYPE {name} {mtype}")
        for row in fam.get("series", []):
            labels = row.get("labels", {})
            if mtype in ("counter", "gauge"):
                out.append(f"{name}{_fmt_labels(labels)} "
                           f"{_fmt_value(row.get('value', 0.0))}")
                continue
            # histogram: cumulative buckets from the lifetime total
            h = row.get("total") or {}
            counts = {int(k): int(v)
                      for k, v in (h.get("counts") or {}).items()}
            floor = h.get("value_floor", 1e-6)
            per = h.get("buckets_per_doubling", 16)
            cum = 0
            for i in sorted(counts):
                cum += counts[i]
                le = _fmt_value(_bucket_bound(i, floor, per))
                out.append(f"{name}_bucket"
                           f"{_fmt_labels(labels, ('le', le))} {cum}")
            total = int(h.get("count", 0))
            out.append(f"{name}_bucket"
                       f"{_fmt_labels(labels, ('le', '+Inf'))} {total}")
            s = float(h.get("mean", 0.0)) * total
            out.append(f"{name}_sum{_fmt_labels(labels)} "
                       f"{_fmt_value(s)}")
            out.append(f"{name}_count{_fmt_labels(labels)} {total}")
    return "\n".join(out) + "\n" if out else ""


# -- validator ----------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?P<labels>\{[^}]*\})?"
    r" (?P<value>\S+)(?: (?P<ts>-?\d+))?$")
_LABEL_PAIR_RE = re.compile(
    r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def _parse_labels(raw: Optional[str]) -> Optional[Dict[str, str]]:
    if not raw:
        return {}
    body = raw[1:-1].rstrip(",")
    if not body:
        return {}
    labels: Dict[str, str] = {}
    pos = 0
    while pos < len(body):
        m = _LABEL_PAIR_RE.match(body, pos)
        if not m:
            return None
        labels[m.group(1)] = m.group(2)
        pos = m.end()
        if pos < len(body):
            if body[pos] != ",":
                return None
            pos += 1
    return labels


def validate_exposition(text: str) -> List[str]:
    """Parse Prometheus 0.0.4 text and return a list of problems
    (empty == conformant). Checks: name syntax, TYPE declared before
    samples and only known types, sample names matching their family
    (histogram suffixes allowed), label syntax, parseable values, no
    duplicate series, and histogram invariants — ``le`` monotonically
    increasing, cumulative bucket counts non-decreasing, the ``+Inf``
    bucket present and equal to ``_count``."""
    problems: List[str] = []
    types: Dict[str, str] = {}
    seen: set = set()
    # (family, labels-sans-le) -> [(le, cum_count)]
    hist_buckets: Dict[Tuple[str, tuple], List[Tuple[float, float]]] = {}
    hist_counts: Dict[Tuple[str, tuple], float] = {}

    def family_of(sample: str) -> Tuple[str, str]:
        for suffix in ("_bucket", "_sum", "_count"):
            base = sample[:-len(suffix)] if sample.endswith(suffix) \
                else None
            if base and types.get(base) == "histogram":
                return base, suffix
        return sample, ""

    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) < 3 or parts[1] not in ("HELP", "TYPE"):
                if parts[1:2] and parts[1] in ("HELP", "TYPE"):
                    problems.append(f"line {ln}: malformed {parts[1]}")
                continue                       # plain comment is legal
            if parts[1] == "TYPE":
                name, mtype = parts[2], (parts[3] if len(parts) > 3
                                         else "")
                if not _NAME_RE.match(name):
                    problems.append(
                        f"line {ln}: bad metric name {name!r}")
                if mtype not in ("counter", "gauge", "histogram",
                                 "summary", "untyped"):
                    problems.append(
                        f"line {ln}: unknown type {mtype!r}")
                types[name] = mtype
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            problems.append(f"line {ln}: unparseable sample {line!r}")
            continue
        sample = m.group("name")
        labels = _parse_labels(m.group("labels"))
        if labels is None:
            problems.append(f"line {ln}: bad label syntax in {line!r}")
            continue
        if not all(_LABEL_RE.match(k) for k in labels):
            problems.append(f"line {ln}: bad label name in {line!r}")
            continue
        raw_value = m.group("value")
        if raw_value in ("+Inf", "-Inf", "NaN"):
            value = {"+Inf": math.inf, "-Inf": -math.inf,
                     "NaN": math.nan}[raw_value]
        else:
            try:
                value = float(raw_value)
            except ValueError:
                problems.append(
                    f"line {ln}: bad value {raw_value!r}")
                continue
        family, suffix = family_of(sample)
        mtype = types.get(family)
        if mtype is None:
            problems.append(
                f"line {ln}: sample {sample!r} before its TYPE")
            types.setdefault(family, "untyped")
            mtype = "untyped"
        if mtype == "counter" and value < 0:
            problems.append(f"line {ln}: counter {sample!r} < 0")
        key = (sample, tuple(sorted(labels.items())))
        if key in seen:
            problems.append(f"line {ln}: duplicate series {key!r}")
        seen.add(key)
        if mtype == "histogram":
            base = {k: v for k, v in labels.items() if k != "le"}
            hkey = (family, tuple(sorted(base.items())))
            if suffix == "_bucket":
                if "le" not in labels:
                    problems.append(
                        f"line {ln}: histogram bucket without le")
                    continue
                le_raw = labels["le"]
                le = math.inf if le_raw == "+Inf" else None
                if le is None:
                    try:
                        le = float(le_raw)
                    except ValueError:
                        problems.append(
                            f"line {ln}: bad le {le_raw!r}")
                        continue
                hist_buckets.setdefault(hkey, []).append((le, value))
            elif suffix == "_count":
                hist_counts[hkey] = value
    for hkey, buckets in hist_buckets.items():
        les = [le for le, _ in buckets]
        cums = [c for _, c in buckets]
        if les != sorted(les):
            problems.append(f"{hkey[0]}: le buckets not increasing")
        if any(b < a for a, b in zip(cums, cums[1:])):
            problems.append(
                f"{hkey[0]}: cumulative bucket counts decrease")
        if not les or les[-1] != math.inf:
            problems.append(f"{hkey[0]}: missing +Inf bucket")
        elif hkey in hist_counts and cums[-1] != hist_counts[hkey]:
            problems.append(
                f"{hkey[0]}: +Inf bucket {cums[-1]} != _count "
                f"{hist_counts[hkey]}")
    return problems


# -- HTTP endpoint ------------------------------------------------------------

class MetricsHTTPServer:
    """Minimal threaded exposition endpoint.

    ``render_fn`` is called per scrape and must return the exposition
    text (so the server composes with any wire source: one registry, a
    lane merge, a cluster view). Routes: ``GET /metrics`` → text,
    ``GET /healthz`` → ``ok``; anything else is 404.
    """

    def __init__(self, render_fn: Callable[[], str],
                 host: str = "127.0.0.1", port: int = 0):
        self.render_fn = render_fn
        outer = self

        class _Handler(BaseHTTPRequestHandler):
            def do_GET(self):
                if self.path.split("?")[0] == "/metrics":
                    try:
                        body = outer.render_fn().encode()
                    except Exception as e:   # surface scrape bugs as 500s,
                        self.send_error(500, str(e))  # not dead sockets
                        return
                    self.send_response(200)
                    self.send_header("Content-Type", CONTENT_TYPE)
                    self.send_header("Content-Length", str(len(body)))
                    self.end_headers()
                    self.wfile.write(body)
                elif self.path == "/healthz":
                    body = b"ok\n"
                    self.send_response(200)
                    self.send_header("Content-Type", "text/plain")
                    self.send_header("Content-Length", str(len(body)))
                    self.end_headers()
                    self.wfile.write(body)
                else:
                    self.send_error(404)

            def log_message(self, *a):        # keep scrapes off stderr
                pass

        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="metrics-http",
            daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def close(self):
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)


__all__ = ["render_wire", "validate_exposition", "MetricsHTTPServer",
           "CONTENT_TYPE"]
