"""Per-op measured latencies: the calibration table for cost dispatch.

The op-mode mux (``specialize(mode="auto")``) picks dense vs
scatter-gather per op from a FLOP model. The ROADMAP's measured-cost
dispatch item wants that decision driven by *measured* per-op latencies
on the serving hardware instead — this module records them.

The compiled program is one jitted ``lax.scan`` — there is no way to
time individual ops inside it. So calibration runs a **separate,
sampled, eager pass**: every ``calibrate_every``-th traced batch, the
engine re-executes the program's sections step by step (the exact step
closures the jit uses, via ``program.compile_steps``), blocking after
each step and recording its duration into a ``LogHistogram`` keyed
``(op_label, mode, size_bucket)``. The pass's outputs are **discarded**
— the jitted result is what gets served — so enabling calibration never
changes serving outputs; it only adds (roughly 1x eager) device work on
the sampled batch, which is why it defaults to off.

Caveat on the numbers: eager per-step timings include dispatch overhead
and exclude jit fusion across steps, so they are an upper bound on the
op's share inside the compiled program — fine for *relative* mode
choices (dense vs sg for the same op), which is what dispatch needs.
"""
from __future__ import annotations

import hashlib
import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs.hist import LogHistogram
from repro.obs.trace import now

CALIB_SCHEMA = 1


class CalibrationArtifactError(RuntimeError):
    """Persisted calibration does not match the live deployment."""


def op_label(ops: Tuple) -> str:
    """Step label: the op class name, or the fused group joined with
    '+' (e.g. ``Aggregate+Residual+Transform`` for the Pallas peephole)."""
    return "+".join(type(o).__name__ for o in ops)


def op_mode(ops: Tuple, impl: str) -> str:
    """``impl/opmode`` — e.g. ``pallas/dense``, ``xla/sg``; ops without
    a dense/sg mux (Residual, AttentionScore) report ``impl/-``."""
    for o in ops:
        m = getattr(o, "mode", None)
        if m:
            return f"{impl}/{m}"
    return f"{impl}/-"


def size_bucket(batch: Dict) -> int:
    """Power-of-two work bucket: bit length of total vertex slots C*N
    (the quantity every ACK kernel's cost scales with). Same deployment
    -> same bucket, so per-deployment tables stay single-bucket while a
    table aggregated across deployments keeps sizes apart."""
    mask = batch.get("mask")
    if mask is None:
        return 0
    c, n = mask.shape[0], mask.shape[1]
    return int(c * n).bit_length()


class CalibrationTable:
    """(op_label, mode, size_bucket) -> LogHistogram of step seconds."""

    def __init__(self):
        self._hists: Dict[Tuple[str, str, int], LogHistogram] = {}
        self._lock = threading.Lock()
        self.passes = 0
        # bumped on every record — dispatch policies key their cached
        # per-bucket decisions on it, so a table that stops growing
        # (warmup over) costs one dict probe per batch, not a re-solve
        self.version = 0

    def record(self, label: str, mode: str, bucket: int,
               dur_s: float) -> None:
        key = (label, mode, bucket)
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = self._hists[key] = LogHistogram()
            self.version += 1
        h.record(dur_s)

    def rows(self) -> List[dict]:
        """Flat sorted rows — what ``trace_report()['calibration']``
        exposes and what a measured-cost dispatcher would consume."""
        with self._lock:
            items = sorted(self._hists.items())
        out = []
        for (label, mode, bucket), h in items:
            out.append({"op": label, "mode": mode, "size_bucket": bucket,
                        "count": h.count, "mean_s": round(h.mean, 9),
                        "p50_s": round(h.quantile(0.5), 9),
                        "p99_s": round(h.quantile(0.99), 9)})
        return out

    def lookup(self, op: str, impl_mode: str,
               size: int = None) -> float:
        """Measured p50 step seconds for ``(op, impl_mode)`` — e.g.
        ``("Aggregate", "xla/sg")`` — at ``size`` (a ``size_bucket``
        value), or at the most-sampled bucket when ``size`` is None.
        Returns None when the cell has no samples, so a dispatcher can
        fall back to the static FLOP model per-cell."""
        with self._lock:
            if size is not None:
                h = self._hists.get((op, impl_mode, size))
            else:
                cands = [h for (lbl, m, _), h in self._hists.items()
                         if lbl == op and m == impl_mode]
                h = max(cands, key=lambda h: h.count, default=None)
        if h is None or not h.count:
            return None
        return h.quantile(0.5)

    def to_dict(self) -> dict:
        return {"passes": self.passes, "rows": self.rows()}

    def to_cells(self) -> dict:
        """Lossless serialization: every cell's full sparse histogram
        (``rows()`` keeps only the summary stats) — what persistence
        saves so a restarted server dispatches from the same p50s."""
        with self._lock:
            items = sorted(self._hists.items())
        return {"passes": self.passes,
                "cells": [{"op": label, "mode": mode, "bucket": bucket,
                           "hist": h.to_dict()}
                          for (label, mode, bucket), h in items]}

    @classmethod
    def from_cells(cls, d: dict) -> "CalibrationTable":
        """Inverse of ``to_cells``."""
        t = cls()
        t.passes = int(d.get("passes", 0))
        for cell in d.get("cells", ()):
            key = (str(cell["op"]), str(cell["mode"]),
                   int(cell["bucket"]))
            t._hists[key] = LogHistogram.from_dict(cell["hist"])
            t.version += 1
        return t

    def __len__(self) -> int:
        with self._lock:
            return len(self._hists)


def run_instrumented(program, params, batch, impl: str,
                     table: CalibrationTable) -> None:
    """One instrumented eager pass over the compiled program's sections.

    Uses the same step closures as the jit (``compile_steps``) but runs
    them eagerly, blocking on the register file after each step so the
    recorded duration covers that step's device work. Inner layers run
    unrolled (index ``i`` of the stacked weights) instead of under
    ``lax.scan`` — scan would hide the per-step boundaries. All outputs
    are discarded."""
    import jax
    from repro.core.program import compile_steps

    bucket = size_bucket(batch)

    def timed_section(section_params, h, steps, h0=None):
        regs = {"h": h, "h_in": h, "h0": h if h0 is None else h0}
        for ops, step in steps:
            t0 = now()
            step(section_params, regs, batch)
            jax.block_until_ready(regs)
            table.record(op_label(ops), op_mode(ops, impl), bucket,
                         now() - t0)
        return regs["h"]

    steps0 = compile_steps(program.layer0, impl)
    h = timed_section(params["layer0"], batch["feats"], steps0)
    if program.n_layers > 1:
        steps_i = compile_steps(program.inner, impl)
        h0 = h
        for i in range(program.n_layers - 1):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            h = timed_section(lp, h, steps_i, h0=h0)
    # the tail (Readout/Classify) is a mask-reduce + one matmul — noise
    # next to the layer ops, and it has no dense/sg mux to calibrate
    table.passes += 1


# ---------------------------------------------------------------------------
# warmup / exploration policy


class WarmupSchedule:
    """Deterministic seeded exploration schedule for cold table cells.

    Per size-bucket, the first ``2 * passes`` dispatch decisions each
    trigger one instrumented eager pass through a FORCED mode vector
    (all-mux-dense / all-mux-sg, alternating; the seed picks which side
    goes first per bucket). The forced pass's outputs are discarded —
    serving itself stays on the fallback decision during warmup, so a
    dispatch-enabled run remains bitwise-identical to its forced-mode
    twin while both mode columns of the table fill in."""

    def __init__(self, passes: int = 4, seed: int = 0):
        self.passes = int(passes)
        self.seed = int(seed)
        self._done: Dict[int, int] = {}
        self._lock = threading.Lock()
        self.history: List[Tuple[int, str]] = []   # (bucket, mode) order

    def _first(self, bucket: int) -> Tuple[str, str]:
        r = np.random.default_rng((self.seed, bucket)).integers(2)
        return ("dense", "sg") if r == 0 else ("sg", "dense")

    def next_mode(self, bucket: int) -> Optional[str]:
        """Consume one warmup slot for ``bucket``; None once exhausted."""
        with self._lock:
            k = self._done.get(bucket, 0)
            if k >= 2 * self.passes:
                return None
            self._done[bucket] = k + 1
            mode = self._first(bucket)[k % 2]
            self.history.append((bucket, mode))
            return mode

    def active(self, bucket: int) -> bool:
        with self._lock:
            return self._done.get(bucket, 0) < 2 * self.passes

    def state(self) -> dict:
        with self._lock:
            return {"passes": self.passes, "seed": self.seed,
                    "done": {int(b): int(k)
                             for b, k in sorted(self._done.items())}}


# ---------------------------------------------------------------------------
# Pallas block-size autotune (rides the same table)

# cell naming for tuned kernels: op="fused_gnn" mode="pallas/bf=<B>",
# op="scatter_gather" mode="pallas/be=<B>" — same (op, mode, bucket)
# key space as the per-op cells, so persistence and reports carry both


def run_block_autotune(program, params, batch, table: CalibrationTable,
                       ) -> None:
    """Time the Pallas fused / scatter-gather kernels over their block
    candidate grids on THIS batch's arrays and record the walltimes as
    table cells. One warm (untimed) call per candidate keeps compile
    time out of the p50s. Outputs are discarded — like
    ``run_instrumented``, tuning never changes serving results."""
    import jax

    from repro.core.program import Transform
    from repro.kernels import ops as kops
    from repro.kernels.fused_gnn import BLOCK_F_CANDIDATES
    from repro.kernels.scatter_gather import BLOCK_E_CANDIDATES

    bucket = size_bucket(batch)
    h = batch["feats"]
    adj = batch.get("adj", batch.get("adj_mean"))
    w = None
    for op in program.layer0:        # representative Fout: first FT weight
        if isinstance(op, Transform):
            w = params["layer0"][op.w]
            break
    if adj is not None and w is not None:
        fout = int(w.shape[1])
        for bf in BLOCK_F_CANDIDATES:
            if bf > fout or fout % bf:
                continue
            args = (adj, h, w, None, None, batch.get("mask"))
            jax.block_until_ready(
                kops.fused_gnn_layer(*args, block_f=bf))
            t0 = now()
            jax.block_until_ready(
                kops.fused_gnn_layer(*args, block_f=bf))
            table.record("fused_gnn", f"pallas/bf={bf}", bucket,
                         now() - t0)
    if "edge_src" in batch:
        for be in BLOCK_E_CANDIDATES:
            args = (batch["edge_src"], batch["edge_dst"],
                    batch["edge_w"], h)
            jax.block_until_ready(
                kops.scatter_gather_aggregate(*args, block_e=be))
            t0 = now()
            jax.block_until_ready(
                kops.scatter_gather_aggregate(*args, block_e=be))
            table.record("scatter_gather", f"pallas/be={be}", bucket,
                         now() - t0)


def best_block(table: CalibrationTable, kernel: str, prefix: str,
               candidates, bucket: int) -> Optional[int]:
    """Lowest-p50 candidate for one tuned kernel at ``bucket``, or None
    until EVERY candidate cell is populated (a partially explored grid
    must not override the default — the unexplored candidate might win).
    Candidates with no cell at all (e.g. a bf that does not divide this
    deployment's Fout, skipped by the tuner) are excluded from the
    completeness requirement when no candidate has a cell yet."""
    seen = []
    for c in candidates:
        v = table.lookup(kernel, f"pallas/{prefix}{c}", bucket)
        seen.append((c, v))
    with_cells = [(c, v) for c, v in seen if v is not None]
    if not with_cells:
        return None
    # the tuner records every legal candidate in one pass, so "some but
    # not all legal candidates" only happens mid-pass — wait it out
    legal = {c for c, _ in with_cells}
    if any(v is None for c, v in seen if c in legal):
        return None
    return min(with_cells, key=lambda cv: cv[1])[0]


# ---------------------------------------------------------------------------
# persistence (repro.ckpt) — a restarted server dispatches warm


def _sha(*arrays) -> str:
    h = hashlib.sha256()
    for a in arrays:
        a = np.ascontiguousarray(a)
        h.update(str((a.dtype.str, a.shape)).encode())
        h.update(a.tobytes())
    return h.hexdigest()[:16]


def graph_structure_fingerprint(graph) -> str:
    """CSR structure only — features don't move op latencies, so a
    feature refresh keeps the table warm while an edge-structure change
    (different densities) invalidates it."""
    return _sha(graph.indptr, graph.indices)


def calibration_signature(cfg, impl: str) -> dict:
    """Everything the measured step latencies are a function of besides
    the graph: the model shape (op stream + feature widths + receptive
    field, which also fixes the size bucket) and the kernel substrate."""
    return {"kind": cfg.kind, "n_layers": cfg.n_layers,
            "f_in": cfg.f_in, "f_hidden": cfg.f_hidden,
            "receptive_field": cfg.receptive_field, "impl": impl}


def save_calibration(path: str, table: CalibrationTable, *, graph, cfg,
                     impl: str) -> str:
    """Persist the table (all cells, incl. block-size cells) as one
    committed ``repro.ckpt`` step stamped with the deployment
    fingerprints; returns the artifact directory."""
    from repro.ckpt import checkpoint as ckpt
    extra = {"schema": CALIB_SCHEMA,
             "graph_fingerprint": graph_structure_fingerprint(graph),
             "model": calibration_signature(cfg, impl),
             "table": table.to_cells()}
    # the ckpt layout wants an array tree; the table itself is manifest
    # metadata (pure JSON), so the tree is a one-cell sentinel
    ckpt.save(path, 0, {"calib_cells": np.array([len(table)], np.int64)},
              extra=extra)
    return path


def load_calibration(path: str, *, graph, cfg,
                     impl: str) -> CalibrationTable:
    """Load + validate a persisted table against the live deployment.
    Raises ``CalibrationArtifactError`` naming the first mismatched
    stamp — stale measured latencies must never drive dispatch."""
    from repro.ckpt import checkpoint as ckpt
    _, _, extra = ckpt.restore(
        path, {"calib_cells": np.zeros(1, np.int64)})
    remedy = (f"delete {path!r} and let the engine re-explore (the "
              f"dispatch warmup policy rebuilds the table on the next "
              f"run), or point DispatchConfig(artifact=...) at the "
              f"matching deployment's artifact")
    checks = [
        ("schema", CALIB_SCHEMA,
         "the calibration artifact schema has changed"),
        ("graph_fingerprint", graph_structure_fingerprint(graph),
         "the graph's CSR structure has changed since the table was "
         "measured — its densities (and so the measured mode costs) no "
         "longer describe this deployment"),
        ("model", calibration_signature(cfg, impl),
         "the model configuration or kernel substrate differs from the "
         "one the table was measured on"),
    ]
    for key, live, why in checks:
        if extra.get(key) != live:
            raise CalibrationArtifactError(
                f"stale calibration artifact at {path!r}: {key} "
                f"mismatch (artifact {extra.get(key)!r} vs live "
                f"{live!r}). {why}; {remedy}.")
    return CalibrationTable.from_cells(extra["table"])


__all__ = ["CalibrationTable", "CalibrationArtifactError",
           "WarmupSchedule", "run_instrumented", "run_block_autotune",
           "best_block", "save_calibration", "load_calibration",
           "calibration_signature", "graph_structure_fingerprint",
           "op_label", "op_mode", "size_bucket"]
