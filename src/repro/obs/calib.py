"""Per-op measured latencies: the calibration table for cost dispatch.

The op-mode mux (``specialize(mode="auto")``) picks dense vs
scatter-gather per op from a FLOP model. The ROADMAP's measured-cost
dispatch item wants that decision driven by *measured* per-op latencies
on the serving hardware instead — this module records them.

The compiled program is one jitted ``lax.scan`` — there is no way to
time individual ops inside it. So calibration runs a **separate,
sampled, eager pass**: every ``calibrate_every``-th traced batch, the
engine re-executes the program's sections step by step (the exact step
closures the jit uses, via ``program.compile_steps``), blocking after
each step and recording its duration into a ``LogHistogram`` keyed
``(op_label, mode, size_bucket)``. The pass's outputs are **discarded**
— the jitted result is what gets served — so enabling calibration never
changes serving outputs; it only adds (roughly 1x eager) device work on
the sampled batch, which is why it defaults to off.

Caveat on the numbers: eager per-step timings include dispatch overhead
and exclude jit fusion across steps, so they are an upper bound on the
op's share inside the compiled program — fine for *relative* mode
choices (dense vs sg for the same op), which is what dispatch needs.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Tuple

from repro.obs.hist import LogHistogram
from repro.obs.trace import now


def op_label(ops: Tuple) -> str:
    """Step label: the op class name, or the fused group joined with
    '+' (e.g. ``Aggregate+Residual+Transform`` for the Pallas peephole)."""
    return "+".join(type(o).__name__ for o in ops)


def op_mode(ops: Tuple, impl: str) -> str:
    """``impl/opmode`` — e.g. ``pallas/dense``, ``xla/sg``; ops without
    a dense/sg mux (Residual, AttentionScore) report ``impl/-``."""
    for o in ops:
        m = getattr(o, "mode", None)
        if m:
            return f"{impl}/{m}"
    return f"{impl}/-"


def size_bucket(batch: Dict) -> int:
    """Power-of-two work bucket: bit length of total vertex slots C*N
    (the quantity every ACK kernel's cost scales with). Same deployment
    -> same bucket, so per-deployment tables stay single-bucket while a
    table aggregated across deployments keeps sizes apart."""
    mask = batch.get("mask")
    if mask is None:
        return 0
    c, n = mask.shape[0], mask.shape[1]
    return int(c * n).bit_length()


class CalibrationTable:
    """(op_label, mode, size_bucket) -> LogHistogram of step seconds."""

    def __init__(self):
        self._hists: Dict[Tuple[str, str, int], LogHistogram] = {}
        self._lock = threading.Lock()
        self.passes = 0

    def record(self, label: str, mode: str, bucket: int,
               dur_s: float) -> None:
        key = (label, mode, bucket)
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = self._hists[key] = LogHistogram()
        h.record(dur_s)

    def rows(self) -> List[dict]:
        """Flat sorted rows — what ``trace_report()['calibration']``
        exposes and what a measured-cost dispatcher would consume."""
        with self._lock:
            items = sorted(self._hists.items())
        out = []
        for (label, mode, bucket), h in items:
            out.append({"op": label, "mode": mode, "size_bucket": bucket,
                        "count": h.count, "mean_s": round(h.mean, 9),
                        "p50_s": round(h.quantile(0.5), 9),
                        "p99_s": round(h.quantile(0.99), 9)})
        return out

    def lookup(self, op: str, impl_mode: str,
               size: int = None) -> float:
        """Measured p50 step seconds for ``(op, impl_mode)`` — e.g.
        ``("Aggregate", "xla/sg")`` — at ``size`` (a ``size_bucket``
        value), or at the most-sampled bucket when ``size`` is None.
        Returns None when the cell has no samples, so a dispatcher can
        fall back to the static FLOP model per-cell."""
        with self._lock:
            if size is not None:
                h = self._hists.get((op, impl_mode, size))
            else:
                cands = [h for (lbl, m, _), h in self._hists.items()
                         if lbl == op and m == impl_mode]
                h = max(cands, key=lambda h: h.count, default=None)
        if h is None or not h.count:
            return None
        return h.quantile(0.5)

    def to_dict(self) -> dict:
        return {"passes": self.passes, "rows": self.rows()}

    def __len__(self) -> int:
        with self._lock:
            return len(self._hists)


def run_instrumented(program, params, batch, impl: str,
                     table: CalibrationTable) -> None:
    """One instrumented eager pass over the compiled program's sections.

    Uses the same step closures as the jit (``compile_steps``) but runs
    them eagerly, blocking on the register file after each step so the
    recorded duration covers that step's device work. Inner layers run
    unrolled (index ``i`` of the stacked weights) instead of under
    ``lax.scan`` — scan would hide the per-step boundaries. All outputs
    are discarded."""
    import jax
    from repro.core.program import compile_steps

    bucket = size_bucket(batch)

    def timed_section(section_params, h, steps, h0=None):
        regs = {"h": h, "h_in": h, "h0": h if h0 is None else h0}
        for ops, step in steps:
            t0 = now()
            step(section_params, regs, batch)
            jax.block_until_ready(regs)
            table.record(op_label(ops), op_mode(ops, impl), bucket,
                         now() - t0)
        return regs["h"]

    steps0 = compile_steps(program.layer0, impl)
    h = timed_section(params["layer0"], batch["feats"], steps0)
    if program.n_layers > 1:
        steps_i = compile_steps(program.inner, impl)
        h0 = h
        for i in range(program.n_layers - 1):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            h = timed_section(lp, h, steps_i, h0=h0)
    # the tail (Readout/Classify) is a mask-reduce + one matmul — noise
    # next to the layer ops, and it has no dense/sg mux to calibrate
    table.passes += 1


__all__ = ["CalibrationTable", "run_instrumented", "op_label",
           "op_mode", "size_bucket"]
