"""Flight recorder: full span trees for the K slowest batches.

The export ring in the tracer is a sliding window — great for "what just
happened", useless for "why was batch 4182 slow twenty minutes ago". The
flight recorder answers the second question in bounded memory: it keeps
the complete span trees (with cache/RPC annotations) of exactly the K
slowest batches seen so far, evicting the fastest of the retained set
when a slower one arrives. K is small (default 8) and each tree is a few
dozen dicts, so the footprint is O(K), independent of batch count.
"""
from __future__ import annotations

import heapq
import itertools
import threading
from typing import List, Optional


class FlightRecorder:
    """Bounded keep-the-K-slowest store of batch span trees.

    A min-heap on duration makes ``offer`` O(log K): the root is the
    fastest retained batch, so a new batch either beats it (replace) or
    is dropped. The monotonic tiebreak counter keeps equal durations
    FIFO and the heap comparison away from dict payloads.
    """

    def __init__(self, k: int = 8):
        self.k = int(k)
        self._heap: List[tuple] = []     # (dur, tick, entry-dict)
        self._tick = itertools.count()
        self._lock = threading.Lock()
        self.offered = 0
        self.kept = 0

    def offer(self, trace_id: int, dur: float, spans: List[dict],
              meta: Optional[dict] = None) -> bool:
        """Consider one finished batch; returns True iff retained."""
        if self.k == 0:
            return False
        entry = {"trace_id": int(trace_id), "dur": float(dur),
                 "spans": list(spans), "meta": dict(meta or {})}
        with self._lock:
            self.offered += 1
            if len(self._heap) < self.k:
                heapq.heappush(self._heap,
                               (entry["dur"], next(self._tick), entry))
                self.kept += 1
                return True
            if entry["dur"] > self._heap[0][0]:
                heapq.heapreplace(self._heap,
                                  (entry["dur"], next(self._tick), entry))
                self.kept += 1
                return True
            return False

    def entries(self) -> List[dict]:
        """Retained batches, slowest first."""
        with self._lock:
            items = sorted(self._heap, key=lambda t: -t[0])
        return [e for _, _, e in items]

    def summary(self) -> dict:
        """Report-sized view: per-batch duration + span count, no trees."""
        with self._lock:
            items = sorted(self._heap, key=lambda t: -t[0])
        return {"k": self.k, "offered": self.offered,
                "retained": len(items),
                "slowest": [{"trace_id": e["trace_id"],
                             "dur": round(e["dur"], 6),
                             "spans": len(e["spans"]),
                             "meta": e["meta"]}
                            for _, _, e in items]}

    def __len__(self) -> int:
        with self._lock:
            return len(self._heap)


__all__ = ["FlightRecorder"]
