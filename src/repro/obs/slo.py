"""SLO burn-rate evaluation and the regression watchdog.

An SLO is a target over a rolling horizon ("99.9% of batches under
50 ms"); the *error budget* is the allowed bad fraction (0.1%). The
*burn rate* is how fast traffic is spending that budget: observed bad
fraction divided by the budget, so burn 1.0 exhausts the budget exactly
at the horizon and burn 14.4 exhausts a 30-day budget in ~2 days. We
follow the multi-window, multi-burn-rate alerting recipe (Google SRE
workbook): a breach fires only when BOTH a short and a long window
exceed the threshold — the short window makes alerts fast to clear when
the problem stops, the long window keeps one latency spike from paging
anyone.

Windows here are the ``WindowedHistogram`` ring: the short window is
the current + newest closed window (~1-2 window_s of traffic), the long
window is everything retained (windows * window_s). Both are lossless
merges, so the fractions are exact in bucket units.

``Watchdog`` is the unconditional companion (no objectives needed): it
compares the newest window against the metric's own recent history and
emits events on p99 drift, cache-hit-rate collapse, and monotone
refresh-backlog growth. Host-quarantine events are emitted at the
source (``HostPool``) — the watchdog only has to summarize them.
"""
from __future__ import annotations

import statistics
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.obs.hist import LogHistogram

# (name, short windows, long windows, burn threshold, severity):
# fast burn — page-worthy — vs slow burn — ticket-worthy.
BURN_POLICIES = (("fast", 1, None, 14.4, "crit"),
                 ("slow", 2, None, 6.0, "warn"))


@dataclass(frozen=True)
class SLObjective:
    """One service-level objective, evaluated against a windowed
    histogram (latency) or a pair of counters (error rate).

    kind="latency":    bad event = sample above ``threshold_s`` in
                       ``metric`` (a histogram family, selected by
                       ``labels``)
    kind="error_rate": bad fraction = bad_metric / (metric + bad_metric)
                       deltas between evaluations

    ``target`` is the success objective (0.999 → 0.1% error budget).
    """
    name: str
    metric: str = "repro_batch_seconds"
    kind: str = "latency"
    threshold_s: float = 0.050
    target: float = 0.999
    labels: Tuple[Tuple[str, str], ...] = ()
    bad_metric: str = "repro_batch_errors_total"
    good_metric: str = "repro_batches_total"

    def __post_init__(self):
        if self.kind not in ("latency", "error_rate"):
            raise ValueError("kind must be 'latency' or 'error_rate'")
        if not 0.0 < self.target < 1.0:
            raise ValueError("target must be in (0, 1)")
        if self.kind == "latency" and self.threshold_s <= 0:
            raise ValueError("threshold_s must be > 0")
        if not isinstance(self.labels, tuple):
            object.__setattr__(self, "labels", tuple(self.labels))

    @property
    def budget(self) -> float:
        return 1.0 - self.target


class SLOTracker:
    """Evaluates the configured objectives against the registry;
    breaches land in the event ring (crit for fast burn, warn for
    slow). One tracker per Telemetry hub."""

    def __init__(self, config, registry, events):
        self.config = config
        self.registry = registry
        self.events = events
        # error-rate objectives need deltas: snapshot counters per eval
        self._counter_marks: Dict[str, Tuple[float, float]] = {}
        self._short_marks: Dict[str, Tuple[float, float]] = {}

    # -- per-kind bad fractions ----------------------------------------------
    def _latency_fractions(self, o: SLObjective):
        wh = self.registry.get_series(o.metric, **dict(o.labels))
        if wh is None:
            return None
        merged: Dict[int, LogHistogram] = {}

        def frac(windows: Optional[int]) -> Tuple[float, int]:
            h = merged.get(-1 if windows is None else windows)
            if h is None:
                h = wh.merged(windows)
                merged[-1 if windows is None else windows] = h
            return wh_frac(h, o.threshold_s), h.count

        return frac

    def _error_fractions(self, o: SLObjective):
        def counter_value(name: str) -> float:
            m = self.registry.get_series(name, **dict(o.labels))
            return float(m.value) if m is not None else 0.0

        bad = counter_value(o.bad_metric)
        good = counter_value(o.good_metric)
        prev_long = self._counter_marks.get(o.name)
        prev_short = self._short_marks.get(o.name, (bad, good))
        # long window: lifetime-so-far until enough evals accumulate
        base = prev_long if prev_long is not None else (0.0, 0.0)

        def frac_pair(prev: Tuple[float, float]) -> Tuple[float, int]:
            d_bad = max(0.0, bad - prev[0])
            d_tot = max(0.0, good - prev[1])
            return (d_bad / d_tot if d_tot else 0.0), int(d_tot)

        short = frac_pair(prev_short)
        long_ = frac_pair(base)
        self._short_marks[o.name] = (bad, good)
        if prev_long is None:
            self._counter_marks[o.name] = (0.0, 0.0)

        def frac(windows: Optional[int]) -> Tuple[float, int]:
            return short if windows is not None else long_

        return frac

    def evaluate(self) -> List[dict]:
        rows: List[dict] = []
        for o in self.config.slos:
            frac = (self._latency_fractions(o) if o.kind == "latency"
                    else self._error_fractions(o))
            if frac is None:
                rows.append({"name": o.name, "status": "no_data"})
                continue
            burns = {}
            breach: Optional[Tuple[str, str, float]] = None
            for policy, short_w, long_w, bar, severity in BURN_POLICIES:
                f_short, n_short = frac(short_w)
                f_long, n_long = frac(long_w)
                b_short = f_short / o.budget
                b_long = f_long / o.budget
                burns[policy] = {"short": round(b_short, 4),
                                 "long": round(b_long, 4),
                                 "threshold": bar}
                enough = min(n_short, n_long) >= self.config.min_samples
                if enough and b_short > bar and b_long > bar \
                        and breach is None:
                    breach = (policy, severity, max(b_short, b_long))
            row = {"name": o.name, "kind": o.kind,
                   "target": o.target, "budget": o.budget,
                   "burn": burns,
                   "status": "breach" if breach else "ok"}
            if o.kind == "latency":
                row["threshold_s"] = o.threshold_s
            rows.append(row)
            if breach:
                policy, severity, worst = breach
                self.events.emit(
                    "slo_breach", severity=severity,
                    message=f"SLO {o.name}: {policy} burn "
                            f"{worst:.1f}x budget",
                    slo=o.name, policy=policy,
                    burn=round(worst, 4), budget=o.budget)
        return rows


def wh_frac(h: LogHistogram, threshold: float) -> float:
    return h.fraction_above(threshold)


class Watchdog:
    """Objective-free regression detection: each check compares the
    newest data against the metric's own retained history.

    p99 drift            newest closed window's p99 above
                         ``p99_drift_factor`` x the median p99 of the
                         older closed windows (every histogram family)
    cache-hit collapse   windowed hit rate below ``hit_floor_ratio`` x
                         lifetime hit rate, for every counter pair
                         following the ``*_hits_total``/``*_misses_total``
                         naming convention
    backlog growth       any ``*_backlog`` gauge strictly increasing
                         for ``backlog_growth_checks`` consecutive
                         checks

    Detections emit warn events; repeated detections of the same kind on
    the same metric are debounced (one event per episode, re-armed when
    the condition clears).
    """

    def __init__(self, config, registry, events):
        self.config = config
        self.registry = registry
        self.events = events
        self.checks = 0
        self._active: Dict[Tuple[str, str], bool] = {}
        self._hit_marks: Dict[str, Tuple[float, float]] = {}
        self._backlog_hist: Dict[str, List[float]] = {}
        self._fired: Dict[str, int] = {}

    def _fire(self, key: Tuple[str, str], message: str, **data):
        if self._active.get(key):
            return                       # still in the same episode
        self._active[key] = True
        self._fired[key[0]] = self._fired.get(key[0], 0) + 1
        self.events.emit(key[0], severity="warn", message=message,
                         metric=key[1], **data)

    def _clear(self, key: Tuple[str, str]):
        self._active[key] = False

    # -- individual checks ---------------------------------------------------
    def _check_p99_drift(self, wire_families: Dict[str, dict]):
        cfg = self.config
        for name, fam in wire_families.items():
            if fam["type"] != "histogram":
                continue
            for items, wh in fam["series"].items():
                label = name if not items else \
                    name + "{" + ",".join(f"{k}={v}"
                                          for k, v in items) + "}"
                key = ("p99_regression", label)
                p99s = wh.window_quantiles(0.99)
                counts = wh.window_counts()
                lineage = [(p, c) for p, c in zip(p99s, counts)
                           if c >= cfg.min_samples]
                if len(lineage) < 2:
                    continue
                *base, (newest_p99, _) = lineage
                baseline = statistics.median(p for p, _ in base)
                if baseline > 0 and \
                        newest_p99 > cfg.p99_drift_factor * baseline:
                    self._fire(key,
                               f"p99 of {label} drifted to "
                               f"{newest_p99 * 1e3:.2f} ms "
                               f"({newest_p99 / baseline:.1f}x the "
                               f"recent baseline)",
                               p99=newest_p99, baseline=baseline,
                               factor=round(newest_p99 / baseline, 2))
                else:
                    self._clear(key)

    def _check_hit_collapse(self, wire_families: Dict[str, dict]):
        cfg = self.config

        def series_sum(name: str) -> Optional[float]:
            fam = wire_families.get(name)
            if fam is None or fam["type"] != "counter":
                return None
            total = 0.0
            for m in fam["series"].values():
                try:
                    total += float(m.value)
                except Exception:
                    return None
            return total

        for name in list(wire_families):
            if not name.endswith("_hits_total"):
                continue
            miss_name = name[:-len("_hits_total")] + "_misses_total"
            hits = series_sum(name)
            misses = series_sum(miss_name)
            if hits is None or misses is None:
                continue
            key = ("cache_hit_collapse", name)
            prev = self._hit_marks.get(name, (0.0, 0.0))
            self._hit_marks[name] = (hits, misses)
            d_h, d_m = hits - prev[0], misses - prev[1]
            window_n = d_h + d_m
            lifetime_n = hits + misses
            if window_n < cfg.min_samples or lifetime_n <= 0:
                continue
            window_rate = d_h / window_n
            lifetime_rate = hits / lifetime_n
            if lifetime_rate > 0 and \
                    window_rate < cfg.hit_floor_ratio * lifetime_rate:
                self._fire(key,
                           f"hit rate of {name} collapsed to "
                           f"{window_rate:.1%} (lifetime "
                           f"{lifetime_rate:.1%})",
                           window_rate=round(window_rate, 4),
                           lifetime_rate=round(lifetime_rate, 4))
            else:
                self._clear(key)

    def _check_backlog_growth(self, wire_families: Dict[str, dict]):
        cfg = self.config
        for name, fam in wire_families.items():
            if fam["type"] != "gauge" or not name.endswith("_backlog"):
                continue
            level = 0.0
            for m in fam["series"].values():
                try:
                    level += float(m.value)
                except Exception:
                    break
            hist = self._backlog_hist.setdefault(name, [])
            hist.append(level)
            del hist[:-(cfg.backlog_growth_checks + 1)]
            key = ("backlog_growth", name)
            if len(hist) > cfg.backlog_growth_checks and \
                    all(b > a for a, b in zip(hist, hist[1:])):
                self._fire(key,
                           f"{name} grew for "
                           f"{cfg.backlog_growth_checks} consecutive "
                           f"checks (now {level:g})",
                           level=level, history=list(hist))
            else:
                self._clear(key)

    def check(self) -> dict:
        """Run every detector once; returns a summary of this check."""
        self.checks += 1
        with self.registry._lock:
            fams = {n: {"type": f["type"],
                        "series": dict(f["series"])}
                    for n, f in self.registry._families.items()}
        self._check_p99_drift(fams)
        self._check_hit_collapse(fams)
        self._check_backlog_growth(fams)
        return self.summary()

    def summary(self) -> dict:
        return {"checks": self.checks,
                "fired": dict(self._fired),
                "active": sorted(f"{k}:{m}" for (k, m), on
                                 in self._active.items() if on)}


__all__ = ["SLObjective", "SLOTracker", "Watchdog", "BURN_POLICIES"]
