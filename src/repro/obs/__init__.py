"""Observability: per-batch distributed tracing, streaming histograms,
flight-recorder forensics, Perfetto-loadable trace export, and the live
telemetry plane (windowed metrics, Prometheus exposition, SLO burn
rates, regression watchdog).

Tracing answers "what happened to that batch"; telemetry answers "what
has been happening lately". Enable with
``ServingConfig(trace=TraceConfig())`` and/or
``ServingConfig(telemetry=TelemetryConfig())``; both are off by default
and zero-cost when off (every instrumentation site is one ``is None``
test, and instrumented runs are bitwise-identical to bare ones). See
docs/OBSERVABILITY.md.
"""
from repro.obs.calib import CalibrationTable, run_instrumented
from repro.obs.events import EventRing
from repro.obs.export import (containment, to_chrome_trace,
                              validate_chrome_trace, write_chrome_trace)
from repro.obs.flight import FlightRecorder
from repro.obs.hist import (LogHistogram, Reservoir, hist_dict_quantile,
                            merge_hist_dicts)
from repro.obs.metrics import (MetricsRegistry, Telemetry,
                               TelemetryConfig, WindowedHistogram,
                               inject_labels, merge_wire, series_count)
from repro.obs.promexp import (MetricsHTTPServer, render_wire,
                               validate_exposition)
from repro.obs.slo import SLObjective, SLOTracker, Watchdog
from repro.obs.trace import (SpanAllocator, TraceConfig, TraceContext,
                             Tracer, now, span_dict)

__all__ = [
    "TraceConfig", "TraceContext", "Tracer", "SpanAllocator",
    "span_dict", "now",
    "LogHistogram", "Reservoir", "hist_dict_quantile",
    "merge_hist_dicts",
    "FlightRecorder",
    "CalibrationTable", "run_instrumented",
    "to_chrome_trace", "write_chrome_trace", "validate_chrome_trace",
    "containment",
    "TelemetryConfig", "MetricsRegistry", "Telemetry",
    "WindowedHistogram", "merge_wire", "inject_labels", "series_count",
    "render_wire", "validate_exposition", "MetricsHTTPServer",
    "SLObjective", "SLOTracker", "Watchdog",
    "EventRing",
]
