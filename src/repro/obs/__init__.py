"""Observability: per-batch distributed tracing, streaming histograms,
flight-recorder forensics, and Perfetto-loadable trace export.

Enable with ``ServingConfig(trace=TraceConfig())``; off by default and
zero-cost when off (every instrumentation site is one ``is None`` test,
and traced runs are bitwise-identical to untraced ones). See
docs/OBSERVABILITY.md.
"""
from repro.obs.calib import CalibrationTable, run_instrumented
from repro.obs.export import (containment, to_chrome_trace,
                              validate_chrome_trace, write_chrome_trace)
from repro.obs.flight import FlightRecorder
from repro.obs.hist import LogHistogram, Reservoir, hist_dict_quantile
from repro.obs.trace import (SpanAllocator, TraceConfig, TraceContext,
                             Tracer, now, span_dict)

__all__ = [
    "TraceConfig", "TraceContext", "Tracer", "SpanAllocator",
    "span_dict", "now",
    "LogHistogram", "Reservoir", "hist_dict_quantile",
    "FlightRecorder",
    "CalibrationTable", "run_instrumented",
    "to_chrome_trace", "write_chrome_trace", "validate_chrome_trace",
    "containment",
]
