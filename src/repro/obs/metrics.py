"""Live telemetry plane: windowed time-series metrics for the serving
stack.

PR 7's tracer answers "what happened to THAT batch"; this module answers
"what has been happening for the last five minutes" — the continuous
signal an operator watches while the deployment serves. Three metric
kinds, all fixed-memory:

  * ``Counter``    — monotonic totals (requests, cache hits, retries).
    Collect-time *callback* counters (``counter_fn``) read an existing
    subsystem counter (cache ``hits``, tier ``demotions``) with ZERO
    hot-path cost: nothing is incremented twice, the registry samples
    the source at scrape time.
  * ``Gauge``      — point-in-time levels (refresh backlog, resident
    rows), set directly or via collect-time callback.
  * ``WindowedHistogram`` — a ring of ``LogHistogram`` windows rotated
    every ``window_s`` seconds plus a lifetime total. The ring gives
    sliding-window quantiles ("p99 over the last 5 minutes") with
    LOSSLESS merge — window histograms share one bucket scheme, so
    merging k windows is bucket-count addition, bitwise the histogram
    of their union of samples.

``MetricsRegistry`` owns the metric families; ``collect()`` serializes
them to a plain JSON tree (the *wire form*) that crosses the RPC codec
for cluster-wide scrape, merges losslessly across hosts
(``merge_wire``), and renders to Prometheus text (obs.promexp).

``Telemetry`` is the per-deployment hub the engine owns when
``ServingConfig(telemetry=TelemetryConfig(...))`` is set: registry +
bounded event ring + SLO tracker + regression watchdog. Telemetry is
**opt-in and zero-cost when off** — with ``telemetry=None`` no objects
exist and every instrumentation site is a single ``is None`` test;
metrics only *count* the existing calls, so metered and unmetered runs
are bitwise-identical.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.obs.events import EventRing
from repro.obs.hist import LogHistogram, merge_hist_dicts

LabelItems = Tuple[Tuple[str, str], ...]

METRIC_TYPES = ("counter", "gauge", "histogram")


def _label_items(labels: Dict[str, str]) -> LabelItems:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


@dataclass(frozen=True)
class TelemetryConfig:
    """Knobs of the telemetry plane (``ServingConfig(telemetry=...)``).

    window_s / windows   sliding-window geometry: histograms rotate a
                         fresh ``LogHistogram`` every ``window_s``
                         seconds and retain the last ``windows`` closed
                         windows (sliding horizon = windows * window_s)
    port                 HTTP exposition port for the deployment's
                         ``/metrics`` endpoint (GNNServer / graph-host
                         CLI); None = no endpoint, 0 = ephemeral
    events_capacity      bounded structured event ring size
    eval_every_s         SLO + watchdog evaluation cadence; 0 (default)
                         = lazy evaluation piggybacked on report() /
                         scrape calls, > 0 = background thread
    slos                 SLO objectives (obs.slo.SLObjective) evaluated
                         with multi-window burn rates; () = none
    watchdog             enable the regression watchdog (p99 drift,
                         cache-hit collapse, backlog growth)
    p99_drift_factor     watchdog: newest window's p99 above factor x
                         median of the older windows' p99 is a drift
    hit_floor_ratio      watchdog: windowed cache-hit rate below ratio x
                         historical rate is a collapse
    backlog_growth_checks watchdog: backlog gauge strictly growing for
                         this many consecutive checks is a leak
    min_samples          watchdog/SLO: windows with fewer samples are
                         not judged (cold starts must not page anyone)
    """
    window_s: float = 60.0
    windows: int = 5
    port: Optional[int] = None
    events_capacity: int = 256
    eval_every_s: float = 0.0
    slos: Tuple = ()
    watchdog: bool = True
    p99_drift_factor: float = 3.0
    hit_floor_ratio: float = 0.5
    backlog_growth_checks: int = 3
    min_samples: int = 8

    def __post_init__(self):
        if self.window_s <= 0:
            raise ValueError("window_s must be > 0")
        if self.windows < 1:
            raise ValueError("windows must be >= 1")
        if self.port is not None and not (0 <= self.port <= 65535):
            raise ValueError("port must be in [0, 65535] (or None)")
        if self.events_capacity < 1:
            raise ValueError("events_capacity must be >= 1")
        if self.eval_every_s < 0:
            raise ValueError("eval_every_s must be >= 0 (0 = lazy)")
        if self.p99_drift_factor <= 1.0:
            raise ValueError("p99_drift_factor must be > 1")
        if not 0.0 < self.hit_floor_ratio < 1.0:
            raise ValueError("hit_floor_ratio must be in (0, 1)")
        if self.backlog_growth_checks < 2:
            raise ValueError("backlog_growth_checks must be >= 2")
        if self.min_samples < 1:
            raise ValueError("min_samples must be >= 1")
        if not isinstance(self.slos, tuple):
            object.__setattr__(self, "slos", tuple(self.slos))
        from repro.obs.slo import SLObjective
        for o in self.slos:
            if not isinstance(o, SLObjective):
                raise TypeError(
                    f"slos entries must be obs.slo.SLObjective, got "
                    f"{type(o).__name__}")

    def describe(self) -> dict:
        return {"window_s": self.window_s, "windows": self.windows,
                "port": self.port, "eval_every_s": self.eval_every_s,
                "slos": [o.name for o in self.slos],
                "watchdog": self.watchdog}


class Counter:
    """Monotonic counter (thread-safe increment)."""

    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Point-in-time level; ``set`` replaces, ``add`` adjusts."""

    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, n: float) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value


class WindowedHistogram:
    """Sliding-window latency distribution: a lifetime ``total``
    LogHistogram plus a ring of per-window histograms rotated every
    ``window_s`` seconds (lazily, on record/read — an idle metric costs
    nothing). All windows share one bucket scheme, so any subset merges
    losslessly into the exact histogram of those windows' samples."""

    __slots__ = ("window_s", "windows", "total", "_cur", "_cur_start",
                 "_ring", "_lock", "_clock")

    def __init__(self, window_s: float = 60.0, windows: int = 5,
                 clock: Callable[[], float] = time.monotonic):
        self.window_s = float(window_s)
        self.windows = int(windows)
        self._clock = clock
        self.total = LogHistogram()
        self._cur = LogHistogram()
        self._cur_start = clock()
        self._ring: deque = deque(maxlen=self.windows)
        self._lock = threading.Lock()

    def _maybe_rotate_locked(self, now: float) -> None:
        elapsed = now - self._cur_start
        if elapsed < self.window_s:
            return
        k = min(int(elapsed // self.window_s), self.windows + 1)
        for _ in range(k):
            self._ring.append(self._cur)
            self._cur = LogHistogram()
        # re-anchor on the window grid (idle gaps produce empty windows,
        # keeping "last k windows" an honest time horizon)
        self._cur_start = now - (elapsed % self.window_s)

    def rotate(self) -> None:
        """Force-close the current window (tests / deterministic
        evaluation; production rotation is lazy on record/read)."""
        with self._lock:
            self._ring.append(self._cur)
            self._cur = LogHistogram()
            self._cur_start = self._clock()

    def record(self, value: float) -> None:
        now = self._clock()
        with self._lock:
            self._maybe_rotate_locked(now)
            self._cur.record(value)
            self.total.record(value)

    def merged(self, windows: Optional[int] = None) -> LogHistogram:
        """Lossless merge of the newest ``windows`` closed windows plus
        the current one (None = all retained) — the sliding-window view
        burn rates and drift checks read."""
        with self._lock:
            self._maybe_rotate_locked(self._clock())
            closed = list(self._ring)
            cur = self._cur
        if windows is not None:
            closed = closed[-windows:] if windows else []
        out = LogHistogram()
        for h in closed:
            out.merge(h)
        out.merge(cur)
        return out

    def window_quantiles(self, q: float = 0.99) -> List[float]:
        """Per-closed-window quantile series, oldest first (the
        watchdog's drift baseline)."""
        with self._lock:
            self._maybe_rotate_locked(self._clock())
            closed = list(self._ring)
        return [h.quantile(q) for h in closed]

    def window_counts(self) -> List[int]:
        with self._lock:
            self._maybe_rotate_locked(self._clock())
            return [h.count for h in self._ring]

    @property
    def count(self) -> int:
        return self.total.count

    def to_dict(self) -> dict:
        """Wire form: lifetime total + merged sliding window, both as
        sparse bucket payloads (mergeable across hosts)."""
        window = self.merged()
        with self._lock:
            total = self.total.to_dict()
        return {"window_s": self.window_s, "windows": self.windows,
                "total": total, "window": window.to_dict()}


class _CallbackSeries:
    """Collect-time metric: value is ``fn()`` at scrape, nothing on the
    hot path (how existing subsystem counters join the plane)."""

    __slots__ = ("fn",)

    def __init__(self, fn: Callable[[], float]):
        self.fn = fn

    @property
    def value(self) -> float:
        return float(self.fn())


class MetricsRegistry:
    """The deployment's metric families, keyed ``name`` then label set.

    Naming follows Prometheus convention: ``repro_<subsystem>_<what>``
    with ``_total`` on counters and ``_seconds`` / ``_bytes`` units.
    ``collect()`` returns the wire form every surface shares:

        {"host": str, "families": {name: {"type", "help", "series":
            [{"labels": {...}, "value": float}                # scalar
             | {"labels": {...}, "total": hist, "window": hist}]}}}
    """

    def __init__(self, host: str = "client", *, window_s: float = 60.0,
                 windows: int = 5,
                 clock: Callable[[], float] = time.monotonic):
        self.host = host
        self.window_s = float(window_s)
        self.windows = int(windows)
        self._clock = clock
        self._lock = threading.Lock()
        # name -> {"type", "help", "series": {label_items: metric}}
        self._families: Dict[str, dict] = {}

    def _get(self, name: str, mtype: str, help_: str,
             labels: Dict[str, str], factory):
        items = _label_items(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = {"type": mtype, "help": help_, "series": {}}
                self._families[name] = fam
            elif fam["type"] != mtype:
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{fam['type']!r}, not {mtype!r}")
            m = fam["series"].get(items)
            if m is None:
                m = fam["series"][items] = factory()
            return m

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(name, "counter", help, labels, Counter)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(name, "gauge", help, labels, Gauge)

    def whist(self, name: str, help: str = "",
              **labels) -> WindowedHistogram:
        return self._get(
            name, "histogram", help, labels,
            lambda: WindowedHistogram(self.window_s, self.windows,
                                      clock=self._clock))

    def counter_fn(self, name: str, fn: Callable[[], float],
                   help: str = "", **labels) -> None:
        """Register a collect-time counter reading ``fn()`` — the
        zero-hot-path spelling for counters a subsystem already keeps
        (cache hits, tier demotions, RPC retries)."""
        self._get(name, "counter", help, labels,
                  lambda: _CallbackSeries(fn))

    def gauge_fn(self, name: str, fn: Callable[[], float],
                 help: str = "", **labels) -> None:
        self._get(name, "gauge", help, labels,
                  lambda: _CallbackSeries(fn))

    def get_series(self, name: str, **labels):
        """The metric object behind one series, or None (tests, SLO and
        watchdog lookups)."""
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                return None
            return fam["series"].get(_label_items(labels))

    def families(self) -> Dict[str, str]:
        with self._lock:
            return {n: f["type"] for n, f in self._families.items()}

    def collect(self) -> dict:
        """Serialize every family to the wire form (JSON scalars only —
        crosses the RPC codec and merges across hosts losslessly)."""
        with self._lock:
            fams = {n: (f["type"], f["help"], dict(f["series"]))
                    for n, f in self._families.items()}
        out: Dict[str, dict] = {}
        for name, (mtype, help_, series) in sorted(fams.items()):
            rows = []
            for items, m in sorted(series.items()):
                row: dict = {"labels": {k: v for k, v in items}}
                if isinstance(m, WindowedHistogram):
                    row.update(m.to_dict())
                else:
                    try:
                        row["value"] = float(m.value)
                    except Exception:       # a dead callback must not
                        continue            # kill the scrape
                rows.append(row)
            out[name] = {"type": mtype, "help": help_, "series": rows}
        return {"host": self.host, "families": out}


# -- wire-form algebra (cluster scrape) --------------------------------------

def inject_labels(wire: dict, **labels) -> dict:
    """Return a copy of a wire form with extra labels on every series
    (``model=`` per server lane, ``graph_host=`` per scraped host)."""
    fams = {}
    for name, fam in wire.get("families", {}).items():
        rows = [dict(r, labels={**r.get("labels", {}),
                                **{k: str(v) for k, v in labels.items()}})
                for r in fam.get("series", [])]
        fams[name] = dict(fam, series=rows)
    return dict(wire, families=fams)


def merge_wire(wires: List[dict]) -> dict:
    """Merge wire forms from several registries into one cluster view:
    same-name same-labels series combine — counters and gauges add,
    histograms merge bucket counts losslessly (merged count == sum of
    per-registry counts). Families present on only some hosts pass
    through; a type conflict raises (a drifted deployment should fail
    the scrape loudly, not average apples with oranges)."""
    fams: Dict[str, dict] = {}
    hosts: List[str] = []
    for w in wires:
        if not w:
            continue
        h = w.get("host")
        if h and h not in hosts:
            hosts.append(h)
        for name, fam in w.get("families", {}).items():
            tgt = fams.get(name)
            if tgt is None:
                tgt = fams[name] = {"type": fam["type"],
                                    "help": fam.get("help", ""),
                                    "series": {}}
            elif tgt["type"] != fam["type"]:
                raise ValueError(
                    f"metric {name!r} is {tgt['type']!r} on one host "
                    f"and {fam['type']!r} on another")
            for row in fam.get("series", []):
                key = _label_items(row.get("labels", {}))
                cur = tgt["series"].get(key)
                if cur is None:
                    tgt["series"][key] = dict(row)
                elif "value" in row:
                    cur["value"] = cur.get("value", 0.0) \
                        + float(row["value"])
                else:
                    cur["total"] = merge_hist_dicts(cur.get("total"),
                                                    row.get("total"))
                    cur["window"] = merge_hist_dicts(cur.get("window"),
                                                    row.get("window"))
    out_fams = {name: dict(fam, series=[fam["series"][k]
                                        for k in sorted(fam["series"])])
                for name, fam in sorted(fams.items())}
    return {"host": ",".join(hosts) or "merged", "hosts": hosts,
            "families": out_fams}


def series_count(wire: dict) -> int:
    return sum(len(f.get("series", []))
               for f in wire.get("families", {}).values())


class Telemetry:
    """Per-deployment telemetry hub: registry + event ring + SLO
    tracker + regression watchdog (one per DecoupledEngine, or one per
    graph-host service). ``evaluate()`` runs the SLO burn-rate and
    watchdog checks; with ``eval_every_s == 0`` it is invoked lazily by
    ``report()`` (rate-limited to once per window), else a background
    thread drives it."""

    def __init__(self, config: Optional[TelemetryConfig] = None,
                 host: str = "client",
                 clock: Callable[[], float] = time.monotonic):
        self.config = config or TelemetryConfig()
        self.host = host
        self.registry = MetricsRegistry(
            host, window_s=self.config.window_s,
            windows=self.config.windows, clock=clock)
        self.events = EventRing(self.config.events_capacity)
        from repro.obs.slo import SLOTracker, Watchdog
        self.slo = SLOTracker(self.config, self.registry, self.events) \
            if self.config.slos else None
        self.watchdog = Watchdog(self.config, self.registry,
                                 self.events) \
            if self.config.watchdog else None
        self.evaluations = 0
        self._last_eval = 0.0
        self._last_slo: List[dict] = []
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # pre-resolved hot-path series (scheduler feeds these per batch)
        self._h_batch = self.registry.whist(
            "repro_batch_seconds", help="end-to-end batch latency")
        self._h_stage: Dict[str, WindowedHistogram] = {}
        self._c_batches = self.registry.counter(
            "repro_batches_total", help="completed batches")
        self._c_errors = self.registry.counter(
            "repro_batch_errors_total", help="failed batches")
        if self.config.eval_every_s > 0:
            self._thread = threading.Thread(
                target=self._eval_loop, name="telemetry-eval",
                daemon=True)
            self._thread.start()

    # -- hot-path feeds ------------------------------------------------------
    def observe_batch(self, latency_s: float, stage_times: Dict[str, float],
                      error: bool = False) -> None:
        """One completed pipeline batch: end-to-end latency + per-stage
        wall split (called from the scheduler's completion path; cost is
        a handful of histogram records per BATCH, not per request)."""
        self._h_batch.record(latency_s)
        self._c_batches.inc()
        if error:
            self._c_errors.inc()
        for stage, dt in stage_times.items():
            h = self._h_stage.get(stage)
            if h is None:
                h = self._h_stage[stage] = self.registry.whist(
                    "repro_stage_seconds",
                    help="host pipeline stage wall time", stage=stage)
            h.record(dt)

    def whist(self, name: str, help: str = "",
              **labels) -> WindowedHistogram:
        return self.registry.whist(name, help=help, **labels)

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self.registry.counter(name, help=help, **labels)

    # -- evaluation ----------------------------------------------------------
    def evaluate(self) -> dict:
        """Run SLO burn-rate + watchdog checks now; breaches and
        regressions land in the event ring. Returns the evaluation."""
        slo_rows = self.slo.evaluate() if self.slo is not None else []
        wd = self.watchdog.check() if self.watchdog is not None else None
        with self._lock:
            self.evaluations += 1
            self._last_eval = time.monotonic()
            self._last_slo = slo_rows
        return {"slo": slo_rows, "watchdog": wd}

    def _maybe_evaluate(self) -> None:
        """Lazy cadence: at most one evaluation per window when no
        background thread drives it."""
        if self.config.eval_every_s > 0:
            return
        with self._lock:
            due = time.monotonic() - self._last_eval \
                >= self.config.window_s
        if due:
            self.evaluate()

    def _eval_loop(self):
        while not self._stop.wait(self.config.eval_every_s):
            try:
                self.evaluate()
            except Exception:    # an evaluation bug must never kill
                pass             # the deployment

    # -- reporting -----------------------------------------------------------
    def to_wire(self) -> dict:
        return self.registry.collect()

    def report(self) -> dict:
        """The ``telemetry.*`` report section (versioned key map in
        core.report_schema)."""
        self._maybe_evaluate()
        wire = self.registry.collect()
        counters: Dict[str, float] = {}
        gauges: Dict[str, float] = {}
        hists: Dict[str, dict] = {}
        for name, fam in wire["families"].items():
            for row in fam["series"]:
                items = _label_items(row.get("labels", {}))
                key = name if not items else \
                    name + "{" + ",".join(f"{k}={v}"
                                          for k, v in items) + "}"
                if fam["type"] == "counter":
                    counters[key] = row["value"]
                elif fam["type"] == "gauge":
                    gauges[key] = row["value"]
                else:
                    t, w = row["total"], row["window"]
                    hists[key] = {
                        "count": t["count"], "mean": t["mean"],
                        "p50": t["p50"], "p99": t["p99"],
                        "window_count": w["count"],
                        "window_p50": w["p50"], "window_p99": w["p99"]}
        with self._lock:
            slo_rows = list(self._last_slo)
            evaluations = self.evaluations
        return {"enabled": True, "host": self.host,
                "window_s": self.config.window_s,
                "windows": self.config.windows,
                "series": series_count(wire),
                "counters": counters, "gauges": gauges, "hists": hists,
                "slo": slo_rows,
                "watchdog": self.watchdog.summary()
                if self.watchdog is not None else None,
                "evaluations": evaluations,
                "events": self.events.summary()}

    def close(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None


__all__ = ["TelemetryConfig", "Counter", "Gauge", "WindowedHistogram",
           "MetricsRegistry", "Telemetry", "merge_wire",
           "inject_labels", "series_count"]
