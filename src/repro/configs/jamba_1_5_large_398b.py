"""Arch config: jamba-1.5-large-398b (see registry for the exact published numbers)."""
from repro.configs.registry import get_config

ARCH = "jamba-1.5-large-398b"
CONFIG = get_config(ARCH)
REDUCED = get_config(ARCH, reduced=True)
