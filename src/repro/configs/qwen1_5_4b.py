"""Arch config: qwen1.5-4b (see registry for the exact published numbers)."""
from repro.configs.registry import get_config

ARCH = "qwen1.5-4b"
CONFIG = get_config(ARCH)
REDUCED = get_config(ARCH, reduced=True)
