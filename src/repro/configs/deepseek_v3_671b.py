"""Arch config: deepseek-v3-671b (see registry for the exact published numbers)."""
from repro.configs.registry import get_config

ARCH = "deepseek-v3-671b"
CONFIG = get_config(ARCH)
REDUCED = get_config(ARCH, reduced=True)
