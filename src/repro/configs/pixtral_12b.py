"""Arch config: pixtral-12b (see registry for the exact published numbers)."""
from repro.configs.registry import get_config

ARCH = "pixtral-12b"
CONFIG = get_config(ARCH)
REDUCED = get_config(ARCH, reduced=True)
