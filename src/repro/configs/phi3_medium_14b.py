"""Arch config: phi3-medium-14b (see registry for the exact published numbers)."""
from repro.configs.registry import get_config

ARCH = "phi3-medium-14b"
CONFIG = get_config(ARCH)
REDUCED = get_config(ARCH, reduced=True)
