"""Arch config: whisper-tiny (see registry for the exact published numbers)."""
from repro.configs.registry import get_config

ARCH = "whisper-tiny"
CONFIG = get_config(ARCH)
REDUCED = get_config(ARCH, reduced=True)
