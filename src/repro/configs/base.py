"""Config dataclasses for the model zoo, shapes, and execution policies.

Every assigned architecture is expressed as a ``ModelConfig``; shape cells
(``train_4k`` etc.) are ``ShapeConfig``; dtype and sharding behaviour are
policies attached to the config so the dry-run can override them per arch
(e.g. FSDP + bf16 optimizer state for the >100B models).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int                 # routed experts
    num_shared: int = 0              # shared (always-on) experts
    top_k: int = 2
    d_ff_expert: int = 0             # per-expert hidden dim
    capacity_factor: float = 1.25
    # layers that are MoE; "every" = all, "alternate" = odd layers,
    # "dense_first_k" = all but the first k layers (deepseek style)
    layout: str = "every"
    dense_first_k: int = 0
    d_ff_shared: int = 0             # hidden dim of shared-expert block
    router_dtype: str = "float32"
    # dispatch implementation: "scatter" = GShard-style dense scatter
    # (baseline), "gather" = index-scatter + sharded gathers (optimized:
    # the big buffers move as expert-sharded gathers, not all-reduces)
    dispatch: str = "scatter"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek Multi-head Latent Attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0             # 0 = no q compression
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD block."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64               # P
    chunk_size: int = 256
    ngroups: int = 1


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (whisper). Frontend is a stub: inputs are
    precomputed frame embeddings of shape [B, n_frames, d_model]."""
    n_layers: int = 4
    n_frames: int = 1500


@dataclass(frozen=True)
class VisionConfig:
    """VLM patch-embedding stub: input_specs provides [B, n_patches, d_model]
    precomputed patch embeddings spliced into the token sequence."""
    n_patches: int = 256


@dataclass(frozen=True)
class DTypePolicy:
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # optimizer moments dtype; ">=100B" archs use bf16 to fit HBM
    opt_dtype: str = "float32"


@dataclass(frozen=True)
class ShardingPolicy:
    """Logical-axis -> mesh-axis mapping policy.

    data axes ('pod','data') shard the batch; 'model' shards tensor dims.
    fsdp=True additionally shards the largest param dim over the data axes
    (ZeRO-3 style) — required for the >=100B archs to fit 16GB/chip.
    """
    fsdp: bool = False
    shard_experts: bool = True       # experts over 'model' axis
    zero1: bool = True               # optimizer state sharded over data axes
    # decode-cache context parallelism: shard the cache SEQ dim over
    # 'model' when kv-heads don't divide the axis (qwen/phi3-style GQA)
    cache_seq_shard: bool = False


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | ssm | hybrid | moe | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # rope
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0       # chatglm3 "2d rope": 0.5
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    act: str = "silu"                # silu (SwiGLU) | gelu (plain MLP)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_attn_period: int = 0      # jamba: 8 -> 1 attn layer per 8
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionConfig] = None
    mtp: bool = False                # deepseek-v3 multi-token-prediction head
    dtype: DTypePolicy = field(default_factory=DTypePolicy)
    sharding: ShardingPolicy = field(default_factory=ShardingPolicy)
    # set True for archs with sub-quadratic sequence mixing (run long_500k)
    subquadratic: bool = False
    # chunked online-softmax attention block (0 = naive S x S baseline)
    attn_chunk_q: int = 0

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = {}
        kw["n_layers"] = min(self.n_layers, 4 if self.hybrid_attn_period == 0
                             else self.hybrid_attn_period)
        kw["d_model"] = 64
        kw["n_heads"] = 4
        kw["n_kv_heads"] = max(1, min(self.n_kv_heads, 2)) \
            if self.n_kv_heads < self.n_heads else 4
        kw["d_ff"] = 128
        kw["vocab_size"] = 256
        kw["head_dim"] = 16
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=2, d_ff_expert=64,
                d_ff_shared=64 if self.moe.num_shared else 0,
                dense_first_k=min(self.moe.dense_first_k, 1))
        if self.mla is not None:
            kw["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=0,
                                  qk_nope_head_dim=16, qk_rope_head_dim=8,
                                  v_head_dim=16)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=16,
                                            chunk_size=32)
        if self.encoder is not None:
            kw["encoder"] = EncoderConfig(n_layers=2, n_frames=16)
        if self.vision is not None:
            kw["vision"] = VisionConfig(n_patches=8)
        if self.hybrid_attn_period:
            kw["n_layers"] = self.hybrid_attn_period  # one full period
        kw["dtype"] = DTypePolicy(param_dtype="float32",
                                  compute_dtype="float32")
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


def optimized(cfg: "ModelConfig") -> "ModelConfig":
    """The beyond-paper performance variant (EXPERIMENTS.md SPerf):
    chunked attention, gather-based MoE dispatch, cache context sharding.
    The unmodified config is the recorded baseline."""
    kw = {"attn_chunk_q": 1024,
          "sharding": dataclasses.replace(cfg.sharding,
                                          cache_seq_shard=True)}
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, dispatch="gather")
    return dataclasses.replace(cfg, **kw)


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K]}


def shape_cells(cfg: ModelConfig):
    """The shape cells that apply to this arch (assignment rules)."""
    cells = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.subquadratic:
        cells.append(LONG_500K)
    return cells
