"""Arch config: chatglm3-6b (see registry for the exact published numbers)."""
from repro.configs.registry import get_config

ARCH = "chatglm3-6b"
CONFIG = get_config(ARCH)
REDUCED = get_config(ARCH, reduced=True)
