"""Arch config: deepseek-7b (see registry for the exact published numbers)."""
from repro.configs.registry import get_config

ARCH = "deepseek-7b"
CONFIG = get_config(ARCH)
REDUCED = get_config(ARCH, reduced=True)
