"""Architecture registry: the 10 assigned archs (exact published configs).

Source tags are in each entry's docstring. ``get_config(name)`` returns the
full config; ``get_config(name, reduced=True)`` the smoke-test reduction.
"""
from __future__ import annotations

from repro.configs.base import (DTypePolicy, EncoderConfig, MLAConfig,
                                ModelConfig, MoEConfig, SSMConfig,
                                ShardingPolicy, VisionConfig)

_BIG = DTypePolicy(param_dtype="bfloat16", compute_dtype="bfloat16",
                   opt_dtype="bfloat16")
_STD = DTypePolicy(param_dtype="float32", compute_dtype="bfloat16",
                   opt_dtype="float32")
_FSDP = ShardingPolicy(fsdp=True)


def chatglm3_6b() -> ModelConfig:
    """[arXiv:2406.12793; hf] 28L d4096 32H GQA kv=2 ff13696 v65024, RoPE-2d."""
    return ModelConfig(name="chatglm3-6b", family="dense", n_layers=28,
                       d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
                       vocab_size=65024, head_dim=128, rope_fraction=0.5,
                       qkv_bias=True, dtype=_STD)


def deepseek_7b() -> ModelConfig:
    """[arXiv:2401.02954; hf] 30L d4096 32H MHA ff11008 v102400, llama arch."""
    return ModelConfig(name="deepseek-7b", family="dense", n_layers=30,
                       d_model=4096, n_heads=32, n_kv_heads=32, d_ff=11008,
                       vocab_size=102400, head_dim=128, dtype=_STD)


def qwen15_4b() -> ModelConfig:
    """[hf:Qwen/Qwen1.5-*; hf] 40L d2560 20H kv=20 ff6912 v151936, QKV bias."""
    return ModelConfig(name="qwen1.5-4b", family="dense", n_layers=40,
                       d_model=2560, n_heads=20, n_kv_heads=20, d_ff=6912,
                       vocab_size=151936, head_dim=128, qkv_bias=True,
                       dtype=_STD)


def phi3_medium_14b() -> ModelConfig:
    """[arXiv:2404.14219] 40L d5120 40H GQA kv=10 ff17920 v100352, SwiGLU."""
    return ModelConfig(name="phi3-medium-14b", family="dense", n_layers=40,
                       d_model=5120, n_heads=40, n_kv_heads=10, d_ff=17920,
                       vocab_size=100352, head_dim=128, dtype=_STD)


def mamba2_2p7b() -> ModelConfig:
    """[arXiv:2405.21060] 64L d2560 attn-free v50280 ssm_state=128 (SSD)."""
    return ModelConfig(name="mamba2-2.7b", family="ssm", n_layers=64,
                       d_model=2560, n_heads=0, n_kv_heads=0, d_ff=0,
                       vocab_size=50280,
                       ssm=SSMConfig(d_state=128, d_conv=4, expand=2,
                                     head_dim=64, chunk_size=256),
                       subquadratic=True, dtype=_STD)


def jamba_1p5_large() -> ModelConfig:
    """[arXiv:2403.19887; hf] 72L d8192 64H GQA kv=8 ff24576 v65536,
    Mamba+attn 1:7 interleave, MoE 16e top-2 (every other layer)."""
    return ModelConfig(name="jamba-1.5-large-398b", family="hybrid",
                       n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
                       d_ff=24576, vocab_size=65536, head_dim=128,
                       hybrid_attn_period=8,
                       ssm=SSMConfig(d_state=128, d_conv=4, expand=2,
                                     head_dim=64, chunk_size=256),
                       moe=MoEConfig(num_experts=16, top_k=2,
                                     d_ff_expert=24576, layout="alternate"),
                       subquadratic=True, dtype=_BIG, sharding=_FSDP)


def whisper_tiny() -> ModelConfig:
    """[arXiv:2212.04356] 4L d384 6H ff1536 v51865 enc-dec, conv stub."""
    return ModelConfig(name="whisper-tiny", family="audio", n_layers=4,
                       d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
                       vocab_size=51865, head_dim=64, act="gelu",
                       encoder=EncoderConfig(n_layers=4, n_frames=1500),
                       rope_fraction=0.0,  # learned positions, no rope
                       dtype=_STD)


def pixtral_12b() -> ModelConfig:
    """[hf:mistralai/Pixtral-12B-2409] 40L d5120 32H GQA kv=8 ff14336
    v131072; ViT frontend stub."""
    return ModelConfig(name="pixtral-12b", family="vlm", n_layers=40,
                       d_model=5120, n_heads=32, n_kv_heads=8, d_ff=14336,
                       vocab_size=131072, head_dim=128,
                       vision=VisionConfig(n_patches=256), dtype=_STD)


def deepseek_v2_lite() -> ModelConfig:
    """[arXiv:2405.04434; hf] 27L d2048 16H ff1408(expert) v102400,
    MLA kv_lora=512, 2 shared + 64 routed top-6, first layer dense."""
    return ModelConfig(name="deepseek-v2-lite-16b", family="moe", n_layers=27,
                       d_model=2048, n_heads=16, n_kv_heads=16, d_ff=10944,
                       vocab_size=102400,
                       mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                                     qk_nope_head_dim=128,
                                     qk_rope_head_dim=64, v_head_dim=128),
                       moe=MoEConfig(num_experts=64, num_shared=2, top_k=6,
                                     d_ff_expert=1408, d_ff_shared=2816,
                                     layout="dense_first_k", dense_first_k=1),
                       dtype=_STD)


def deepseek_v3() -> ModelConfig:
    """[arXiv:2412.19437; hf] 61L d7168 128H ff2048(expert) v129280,
    MLA (q_lora 1536), 1 shared + 256 routed top-8, 3 dense first, MTP."""
    return ModelConfig(name="deepseek-v3-671b", family="moe", n_layers=61,
                       d_model=7168, n_heads=128, n_kv_heads=128, d_ff=18432,
                       vocab_size=129280,
                       mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                                     qk_nope_head_dim=128,
                                     qk_rope_head_dim=64, v_head_dim=128),
                       moe=MoEConfig(num_experts=256, num_shared=1, top_k=8,
                                     d_ff_expert=2048, d_ff_shared=2048,
                                     layout="dense_first_k", dense_first_k=3),
                       mtp=True, dtype=_BIG, sharding=_FSDP)


ARCHS = {
    "chatglm3-6b": chatglm3_6b,
    "deepseek-7b": deepseek_7b,
    "qwen1.5-4b": qwen15_4b,
    "phi3-medium-14b": phi3_medium_14b,
    "mamba2-2.7b": mamba2_2p7b,
    "jamba-1.5-large-398b": jamba_1p5_large,
    "whisper-tiny": whisper_tiny,
    "pixtral-12b": pixtral_12b,
    "deepseek-v2-lite-16b": deepseek_v2_lite,
    "deepseek-v3-671b": deepseek_v3,
}


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    cfg = ARCHS[name]()
    return cfg.reduced() if reduced else cfg
