"""Arch config: deepseek-v2-lite-16b (see registry for the exact published numbers)."""
from repro.configs.registry import get_config

ARCH = "deepseek-v2-lite-16b"
CONFIG = get_config(ARCH)
REDUCED = get_config(ARCH, reduced=True)
