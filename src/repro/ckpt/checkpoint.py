"""Checkpointing: atomic, resumable, elastic.

Layout per step:  <dir>/step_000123/
    arrays.npz        flattened param/opt leaves (host numpy)
    manifest.json     step, keypaths, shapes, dtypes, config fingerprint
    COMMITTED         written last — restore ignores dirs without it

Atomicity: write into step_xxx.tmp, fsync, rename, then touch COMMITTED.
A crash mid-write leaves only an ignored .tmp. Elasticity: arrays are saved
UNsharded (gathered to host); restore re-shards onto whatever mesh/sharding
the new job passes — chip-count changes between runs are transparent.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None):
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    # numpy can't serialize ml_dtypes (bf16/f8): store bit patterns, the
    # manifest records the logical dtype for restore
    packed = {k: (a.view(np.uint16) if a.dtype.name == "bfloat16" else a)
              for k, a in arrays.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **packed)
    manifest = {
        "step": step,
        "keys": sorted(arrays),
        "shapes": {k: list(a.shape) for k, a in arrays.items()},
        "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(final, "COMMITTED"), "w") as f:
        f.write("ok")
    return final


def committed_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, name, "COMMITTED")):
            steps.append(int(name.split("_")[1]))
    return sorted(steps)


def restore(ckpt_dir: str, like_tree, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, int, dict]:
    """Restore into the structure of ``like_tree``; ``shardings`` (optional
    matching pytree of jax shardings) re-shards for the current mesh —
    the elastic-rescale path."""
    steps = committed_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    step = steps[-1] if step is None else step
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    flat_like = jax.tree_util.tree_flatten_with_path(like_tree)
    leaves = []
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else None)
    for i, (path, leaf) in enumerate(flat_like[0]):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = data[key]
        saved_dtype = manifest["dtypes"].get(key, str(arr.dtype))
        if saved_dtype == "bfloat16" and arr.dtype == np.uint16:
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        want_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
        arr = np.asarray(jnp.asarray(arr).astype(want_dtype)) \
            if str(want_dtype) != str(arr.dtype) else arr
        if shard_flat is not None:
            arr = jax.device_put(arr, shard_flat[i])
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(flat_like[1], leaves)
    return tree, step, manifest.get("extra", {})


def prune(ckpt_dir: str, keep: int = 3):
    steps = committed_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
