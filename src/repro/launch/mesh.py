"""Production mesh builders. Functions, not module constants, so importing
never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for unit tests (requires >= n_data*n_model devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
