"""input_specs(): ShapeDtypeStruct stand-ins (or real random batches) for
every model input of every (arch x shape) cell. Shardable, weak-type
correct, no device allocation in 'specs' mode."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.transformer import init_cache


def _mk(mode, rng, shape, dtype, maxval=None):
    if mode == "specs":
        return jax.ShapeDtypeStruct(shape, dtype)
    if np.issubdtype(dtype, np.integer):
        return jnp.asarray(rng.integers(0, maxval or 2, size=shape,
                                        dtype=np.int32))
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


def train_specs(cfg: ModelConfig, shape: ShapeConfig, mode="specs",
                seed=0) -> Dict[str, Any]:
    rng = np.random.default_rng(seed)
    B, S = shape.global_batch, shape.seq_len
    d = {
        "tokens": _mk(mode, rng, (B, S), np.int32, cfg.vocab_size),
        "labels": _mk(mode, rng, (B, S), np.int32, cfg.vocab_size),
    }
    if cfg.family == "audio":
        d["frames"] = _mk(mode, rng, (B, cfg.encoder.n_frames, cfg.d_model),
                          np.float32)
    if cfg.family == "vlm":
        d["patch_embeds"] = _mk(mode, rng,
                                (B, cfg.vision.n_patches, cfg.d_model),
                                np.float32)
    return d


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig, mode="specs",
                  seed=0) -> Dict[str, Any]:
    d = train_specs(cfg, shape, mode, seed)
    d.pop("labels")
    return d


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, mode="specs",
                 seed=0) -> Dict[str, Any]:
    """Inputs of serve_step: one new token + a full KV cache of seq_len."""
    rng = np.random.default_rng(seed)
    B, S = shape.global_batch, shape.seq_len
    cache = init_cache(cfg, B, S, mode="specs" if mode == "specs" else
                       "zeros")
    d = {
        "token": _mk(mode, rng, (B, 1), np.int32, cfg.vocab_size),
        "pos": (jax.ShapeDtypeStruct((), jnp.int32) if mode == "specs"
                else jnp.int32(S - 1)),
        "cache": cache,
    }
    return d


def specs_for(cfg: ModelConfig, shape: ShapeConfig, mode="specs", seed=0):
    if shape.kind == "train":
        return train_specs(cfg, shape, mode, seed)
    if shape.kind == "prefill":
        return prefill_specs(cfg, shape, mode, seed)
    return decode_specs(cfg, shape, mode, seed)
