import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape) cell on the single-pod 16x16 mesh and the
2x16x16 multi-pod mesh; record memory_analysis, cost_analysis and the HLO
roofline terms per cell as JSON.

The device-count override above MUST precede any jax import (jax locks the
backend device count at first init), which is why this file sets it in its
first two lines and why nothing else in the package sets it globally.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # everything
  ... --arch deepseek-7b --shape train_4k --mesh single        # one cell
  ... --gnn                                                    # GNN cells
  ... --out results/dryrun --skip-existing                     # resumable
"""

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs.base import SHAPES, optimized, shape_cells  # noqa: E402
from repro.configs.registry import ARCHS, get_config        # noqa: E402
from repro.gnn.model import GNNConfig                       # noqa: E402
from repro.launch.cells import build_cell, build_gnn_cell   # noqa: E402
from repro.launch.hlo_analysis import analyze               # noqa: E402
from repro.launch.mesh import make_production_mesh          # noqa: E402

GNN_CELLS = [GNNConfig(kind=k, n_layers=L, receptive_field=N, f_in=512)
             for (k, L, N) in
             [("gcn", 3, 128), ("sage", 5, 128), ("gat", 3, 128),
              ("sage", 16, 256), ("gcn", 8, 64)]]


def run_cell(fn, args, in_sh, out_sh, mesh, n_devices: int,
             donate=()) -> dict:
    t0 = time.time()
    with mesh:
        jf = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=donate)
        lowered = jf.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):   # older jax: one dict per device
            ca = ca[0] if ca else {}
        hlo = analyze(compiled.as_text(), n_devices=n_devices)
    return {
        "ok": True,
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_est": ma.argument_size_in_bytes
            + ma.output_size_in_bytes + ma.temp_size_in_bytes,
        },
        "cost_analysis": {k: ca[k] for k in ("flops",)
                          if k in ca},
        "hlo": hlo.to_json(),
    }


def cell_name(arch: str, shape: str, mesh_kind: str) -> str:
    return f"{arch}__{shape}__{mesh_kind}".replace("/", "_")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch name | all (LM archs)")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--gnn", action="store_true",
                    help="also run the GNN serve cells")
    ap.add_argument("--gnn-only", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="base", choices=["base", "opt"],
                    help="opt = beyond-paper optimizations "
                         "(chunked attention, gather MoE, cache CP)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": (make_production_mesh(), 256),
              "multi": (make_production_mesh(multi_pod=True), 512)}
    if args.mesh != "both":
        meshes = {args.mesh: meshes[args.mesh]}

    cells = []
    if not args.gnn_only:
        archs = list(ARCHS) if args.arch == "all" else [args.arch]
        for arch in archs:
            cfg = get_config(arch)
            if args.variant == "opt":
                cfg = optimized(cfg)
            shapes = (shape_cells(cfg) if args.shape == "all"
                      else [SHAPES[args.shape]])
            for shp in shapes:
                cells.append(("lm", arch, cfg, shp))
    if args.gnn or args.gnn_only:
        for g in GNN_CELLS:
            cells.append(("gnn", g.display, g, None))

    failures = []
    for mesh_kind, (mesh, ndev) in meshes.items():
        for kind, arch, cfg, shp in cells:
            sname = shp.name if shp else "serve"
            if args.variant != "base":
                sname += "." + args.variant
            name = cell_name(arch, sname, mesh_kind)
            path = os.path.join(args.out, name + ".json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip] {name}")
                continue
            print(f"[cell] {name} ...", flush=True)
            try:
                if kind == "lm":
                    fn, a, i_sh, o_sh, don = build_cell(cfg, shp, mesh)
                else:
                    fn, a, i_sh, o_sh, don = build_gnn_cell(
                        cfg, mesh, variant=args.variant)
                rec = run_cell(fn, a, i_sh, o_sh, mesh, ndev, don)
            except Exception as e:   # noqa: BLE001 — survey must continue
                rec = {"ok": False, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                failures.append(name)
            rec.update(arch=arch, shape=sname, mesh=mesh_kind,
                       n_devices=ndev)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            if rec["ok"]:
                mm = rec["memory"]
                print(f"  ok: compile {rec['t_compile_s']}s, "
                      f"args {mm['argument_bytes']/2**30:.2f} GiB, "
                      f"temp {mm['temp_bytes']/2**30:.2f} GiB, "
                      f"flops {rec['hlo']['flops']:.3e}", flush=True)
            else:
                print(f"  FAIL: {rec['error']}", flush=True)
    print(f"\ndone. {len(failures)} failures: {failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
