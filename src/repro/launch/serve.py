"""Serving launcher — mini-batch GNN inference (the paper's workload).

  PYTHONPATH=src python -m repro.launch.serve --model gcn --layers 3 \
      --receptive-field 128 --dataset flickr --scale 0.05 \
      --requests 256 --batch-size 64
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core.engine import DecoupledEngine
from repro.gnn.model import GNNConfig
from repro.graphs.synthetic import get_graph
from repro.serve.gnn_server import GNNServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="gcn",
                    choices=["gcn", "sage", "gin", "gat"])
    ap.add_argument("--layers", type=int, default=3)
    ap.add_argument("--receptive-field", type=int, default=128)
    ap.add_argument("--dataset", default="flickr")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--impl", default="xla", choices=["xla", "pallas"])
    args = ap.parse_args()

    g = get_graph(args.dataset, scale=args.scale)
    cfg = GNNConfig(kind=args.model, n_layers=args.layers,
                    receptive_field=args.receptive_field,
                    f_in=g.feature_dim)
    engine = DecoupledEngine(g, cfg, batch_size=args.batch_size,
                             impl=args.impl)
    print(f"graph {g.name}: {g.num_vertices} vertices, {g.num_edges} edges")
    print(f"model {cfg.display}; ACK mode={engine.mode} "
          f"({engine.decision.summary}; {engine.decision.reason})")

    server = GNNServer(engine)
    server.start()
    rng = np.random.default_rng(0)
    reqs = [server.submit(t) for t in
            rng.integers(0, g.num_vertices, size=args.requests)]
    server.drain(reqs, timeout=600)
    server.stop()
    print(json.dumps(server.stats.percentiles(), indent=1))


if __name__ == "__main__":
    main()
