"""Static analysis of compiled (post-SPMD) HLO text for roofline terms.

Why not just ``compiled.cost_analysis()``: XLA counts a ``while`` body ONCE
(verified on this backend: an L-step scan reports 1/L of the true FLOPs),
and it reports no per-collective breakdown at all. This analyzer parses the
HLO text into computations, builds the call graph (fusion ``calls=``,
``to_apply=``, while ``body=/condition=``), reads each while's
``known_trip_count`` from its backend_config, and propagates execution
multipliers — so FLOPs, HBM bytes and collective bytes are *steady-state
per-device per-step* quantities.

Conventions:
  * FLOPs: 2*prod(result)*prod(contracted dims) per dot (batch dims
    handled: contracted size read from the lhs operand shape). Counted in
    every computation, scaled by its multiplier — remat recompute therefore
    shows up honestly (that is the point of MODEL_FLOPS / HLO_FLOPS).
  * HBM bytes: sum over *top-level* ops (fusion bodies excluded — their
    internals live in registers/VMEM) of result + operand bytes, skipping
    pure metadata ops (tuple/gte/parameter/constant/bitcast).
  * Collective bytes: per op, the result-buffer bytes with the standard
    ring-cost factor applied: all-gather/reduce-scatter move
    (g-1)/g * bytes across links, all-reduce 2x that, all-to-all
    (g-1)/g, collective-permute 1x. Group size g parsed from
    replica_groups (iota ``[a,b]<=[n]`` or explicit braces).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1,
               "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
               "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
               "c64": 8, "c128": 16}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*{")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|condition|body)=%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _parse_shapes(s: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """All dtype[shape] tokens in a type string (tuples give several)."""
    out = []
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        shape = tuple(int(x) for x in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


def _nbytes(shapes) -> int:
    tot = 0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        tot += n * DTYPE_BYTES[dt]
    return tot


@dataclass
class OpInfo:
    name: str
    kind: str
    result_shapes: list
    operands: List[str]
    line: str

    @property
    def result_bytes(self) -> int:
        return _nbytes(self.result_shapes)


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    is_fusion_body: bool = False
    ops: List[OpInfo] = field(default_factory=list)
    symbols: Dict[str, list] = field(default_factory=dict)  # name->shapes
    calls: List[Tuple[str, str]] = field(default_factory=list)
    # (callee, kind) kind in {call, while_body, while_cond}
    while_trips: Dict[str, int] = field(default_factory=dict)  # body->trip
    cond_trips: Dict[str, int] = field(default_factory=dict)   # cond->trip


_OPS_SKIP_BYTES = {"tuple", "get-tuple-element", "parameter", "constant",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "iota", "while", "conditional", "call"}


_KIND_RE = re.compile(r"[\s)}\]]([a-z][\w\-]*)\(")


def _op_kind(rest: str) -> str:
    # rest looks like: "f32[8,64]{1,0} dot(%a, %b), attrs..." or, for
    # tuple-typed results, "(s32[], f32[8,16]{1,0}) while(%tuple), ...".
    # The opcode is the first lowercase word directly before a '(' after
    # the result type — scanning left-to-right stays ahead of metadata.
    m = _KIND_RE.search(rest)
    return m.group(1) if m else "unknown"


def parse_module(txt: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in txt.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        mc = _COMP_RE.match(line) if not line.startswith(" ") else None
        if mc and ("->" in line):
            name = mc.group(1)
            cur = Computation(name=name,
                              is_entry=line.startswith("ENTRY"),
                              is_fusion_body="fused_computation" in name
                              or "wrapped_" in name)
            comps[name] = cur
            continue
        if s == "}":
            cur = None
            continue
        if cur is None:
            continue
        md = _DEF_RE.match(s)
        if not md:
            continue
        opname, rest = md.group(1), md.group(2)
        kind = _op_kind(rest)
        # result type = text before the op kind token
        type_part = rest.split(f" {kind}(")[0] if f" {kind}(" in rest \
            else rest.split("(")[0]
        shapes = _parse_shapes(type_part)
        # operand names
        paren = rest[rest.find("("):]
        opnds = re.findall(r"%([\w\.\-]+)", paren.split("),")[0]
                           if ")," in paren else paren)
        cur.symbols[opname] = shapes
        op = OpInfo(opname, kind, shapes, opnds, s)
        cur.ops.append(op)
        for m in _CALL_ATTR_RE.finditer(s):
            callee = m.group(1)
            k = "call"
            if f"body=%{callee}" in s:
                k = "while_body"
            elif f"condition=%{callee}" in s:
                k = "while_cond"
            cur.calls.append((callee, k))
        if kind == "while":
            mt = _TRIP_RE.search(s)
            trip = int(mt.group(1)) if mt else 1
            mb = re.search(r"body=%([\w\.\-]+)", s)
            if mb:
                cur.while_trips[mb.group(1)] = trip
            mc = re.search(r"condition=%([\w\.\-]+)", s)
            if mc:
                cur.cond_trips[mc.group(1)] = trip
    return comps


def multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # propagate in topological-ish order via worklist
    work = [entry]
    seen_edges = set()
    while work:
        cname = work.pop()
        c = comps.get(cname)
        if c is None:
            continue
        m = mult[cname]
        for callee, kind in c.calls:
            factor = 1.0
            if kind == "while_body":
                factor = float(c.while_trips.get(callee, 1))
            elif kind == "while_cond":
                factor = float(c.cond_trips.get(callee, 0)) + 1.0
            edge = (cname, callee)
            if edge in seen_edges:
                continue
            seen_edges.add(edge)
            mult[callee] += m * factor
            work.append(callee)
    return dict(mult)


def _dot_flops(op: OpInfo, comp: Computation) -> float:
    result_elems = 1
    for _, shape in op.result_shapes:
        for d in shape:
            result_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    if not m or not op.operands:
        return 2.0 * result_elems          # fallback
    dims = [int(x) for x in m.group(1).split(",") if x]
    lhs = comp.symbols.get(op.operands[0])
    if not lhs:
        return 2.0 * result_elems
    _, lhs_shape = lhs[0]
    contracted = 1
    for d in dims:
        if d < len(lhs_shape):
            contracted *= lhs_shape[d]
    return 2.0 * result_elems * contracted


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


_COLL_FACTOR = {"all-gather": 1.0, "reduce-scatter": 1.0, "all-reduce": 2.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


@dataclass
class HLOSummary:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0            # raw buffer bytes x multiplier
    collective_link_bytes: float = 0.0       # with ring (g-1)/g cost factors
    per_collective: Dict[str, float] = field(default_factory=dict)
    collective_count: Dict[str, int] = field(default_factory=dict)
    n_while: int = 0
    trip_counts: List[int] = field(default_factory=list)

    def to_json(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_link_bytes": self.collective_link_bytes,
            "per_collective": self.per_collective,
            "collective_count": self.collective_count,
            "n_while": self.n_while, "trip_counts": self.trip_counts,
        }


def analyze(txt: str, n_devices: int = 1) -> HLOSummary:
    comps = parse_module(txt)
    mult = multipliers(comps)
    out = HLOSummary()
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        for op in comp.ops:
            if op.kind in ("dot",):
                out.flops += m * _dot_flops(op, comp)
            elif op.kind == "convolution":
                out.flops += m * 2.0 * op.result_bytes  # rough; none expected
            if op.kind in COLLECTIVES:
                b = op.result_bytes
                g = _group_size(op.line, n_devices)
                ring = _COLL_FACTOR[op.kind] * b * max(g - 1, 0) / max(g, 1)
                out.collective_bytes += m * b
                out.collective_link_bytes += m * ring
                out.per_collective[op.kind] = \
                    out.per_collective.get(op.kind, 0.0) + m * b
                out.collective_count[op.kind] = \
                    out.collective_count.get(op.kind, 0) + 1
            if op.kind == "while":
                out.n_while += 1
                out.trip_counts.extend(comp.while_trips.values())
            if not comp.is_fusion_body and op.kind not in _OPS_SKIP_BYTES:
                opnd_bytes = sum(
                    _nbytes(comp.symbols.get(o, [])) for o in op.operands)
                out.hbm_bytes += m * (op.result_bytes + opnd_bytes)
    return out


def analyze_compiled(compiled, n_devices: int = 1) -> HLOSummary:
    return analyze(compiled.as_text(), n_devices)
