"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh)
cell from the dry-run artifacts, dominant bottleneck, and the
MODEL_FLOPS / HLO_FLOPS usefulness ratio.

    compute   = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory    = HLO_bytes_per_device / HBM_bw
    collective= collective_link_bytes_per_device / ICI_link_bw

HLO terms come from launch.hlo_analysis (per-device, while-trip-corrected).
Hardware constants are the assignment's TPU v5e numbers.

Usage: PYTHONPATH=src python -m repro.launch.roofline \
           --dryrun-dir results/dryrun [--fmt md|json]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS


def _param_counts(arch: str) -> Dict[str, float]:
    """(total, active, embedding) parameter counts via eval_shape."""
    import jax
    from repro.configs.registry import get_config
    from repro.models.transformer import init_params
    cfg = get_config(arch)
    shapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), max_seq=4096))
    total = active = embed = 0.0
    moe = cfg.moe
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for path, leaf in flat:
        keys = [str(getattr(p, "key", "")) for p in path]
        n = float(leaf.size)
        total += n
        if any(k in ("embed", "lm_head", "pos_emb", "enc_pos_emb")
               for k in keys):
            embed += n
            continue
        is_routed = (moe is not None and "ffn" in keys
                     and any(k in ("w_gate", "w_up", "w_down")
                             for k in keys)
                     and leaf.ndim >= 3
                     and moe.num_experts in leaf.shape)
        active += n * (moe.top_k / moe.num_experts) if is_routed else n
    return {"total": total, "active": active, "embed": embed,
            "nonembed": total - embed,
            "active_nonembed": active - 0.0}


def model_flops(arch: str, shape_kind: str, tokens: float) -> float:
    """6*N*D train / 2*N*D forward-only, N = active non-embedding params."""
    counts = _param_counts(arch)
    n = counts["active"] - 0.0
    n_nonembed = n - counts["embed"] if n > counts["embed"] else n
    factor = 6.0 if shape_kind == "train" else 2.0
    return factor * n_nonembed * tokens


SHAPE_TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
                "decode_32k": 128.0, "long_500k": 1.0}
SHAPE_KIND = {"train_4k": "train", "prefill_32k": "prefill",
              "decode_32k": "decode", "long_500k": "decode"}


# ---------------------------------------------------------------------------


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops: Optional[float]
    hlo_flops_global: float
    useful_ratio: Optional[float]
    fit: bool
    hint: str

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """compute-term share of the binding constraint: 1.0 = compute
        bound at peak; lower = dominated by memory/collective."""
        return self.t_compute / self.t_bound if self.t_bound else 0.0


_HINTS = {
    "compute": "at compute roof — reduce recompute (remat policy) or"
               " raise MXU utilization via fusion/layout",
    "memory": "HBM-bound — increase arithmetic intensity: fuse attention"
              " (flash), keep activations bf16, raise per-step batch/chip",
    "collective": "ICI-bound — reshard to cut all-gathers (kv-head"
                  " replication, expert-parallel a2a), overlap via"
                  " async collectives / decomposed matmul-collectives",
}


def row_from_record(rec: dict) -> Optional[RooflineRow]:
    if not rec.get("ok"):
        return None
    rec = dict(rec, shape=rec["shape"].replace(".opt", "+opt"))
    h = rec["hlo"]
    ndev = rec["n_devices"]
    t_c = h["flops"] / PEAK_FLOPS
    t_m = h["hbm_bytes"] / HBM_BW
    t_l = h["collective_link_bytes"] / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_l),
              key=lambda kv: kv[1])[0]
    mf = None
    ratio = None
    base_shape = rec["shape"].replace("+opt", "")
    if base_shape in SHAPE_TOKENS and not rec["arch"].startswith(
            ("gcn", "sage", "gat", "gin")):
        mf = model_flops(rec["arch"], SHAPE_KIND[base_shape],
                         SHAPE_TOKENS[base_shape])
        ratio = mf / (h["flops"] * ndev) if h["flops"] else None
    peak = rec["memory"]["peak_bytes_est"]
    return RooflineRow(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        t_compute=t_c, t_memory=t_m, t_collective=t_l, dominant=dom,
        model_flops=mf, hlo_flops_global=h["flops"] * ndev,
        useful_ratio=ratio, fit=peak <= 16 * 2 ** 30,
        hint=_HINTS[dom])


def load_rows(dryrun_dir: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        r = row_from_record(rec)
        if r:
            rows.append(r)
    return rows


def _fmt_t(t: float) -> str:
    if t >= 1:
        return f"{t:.2f}s"
    if t >= 1e-3:
        return f"{t*1e3:.2f}ms"
    return f"{t*1e6:.1f}us"


def render_md(rows) -> str:
    out = ["| arch | shape | mesh | compute | memory | collective | "
           "bound | useful FLOPs | fits 16G |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        ur = f"{r.useful_ratio:.2f}" if r.useful_ratio else "—"
        out.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {_fmt_t(r.t_compute)} | "
            f"{_fmt_t(r.t_memory)} | {_fmt_t(r.t_collective)} | "
            f"{r.dominant} | {ur} | {'y' if r.fit else 'NO'} |")
    bounds = {}
    for r in rows:
        bounds[r.dominant] = bounds.get(r.dominant, 0) + 1
    fits = sum(1 for r in rows if r.fit)
    fracs = sorted(r.roofline_fraction for r in rows)
    out.append("")
    out.append(f"cells: {len(rows)}; fits 16G: {fits}; bound mix: "
               + ", ".join(f"{k}={v}" for k, v in sorted(bounds.items()))
               + f"; roofline fraction median {fracs[len(fracs)//2]:.3f}, "
                 f"best {fracs[-1]:.3f}")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--fmt", default="md", choices=["md", "json"])
    args = ap.parse_args()
    rows = load_rows(args.dryrun_dir)
    if args.fmt == "md":
        print(render_md(rows))
    else:
        print(json.dumps([r.__dict__ for r in rows], indent=1))


if __name__ == "__main__":
    main()
