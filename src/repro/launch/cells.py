"""Dry-run cell builders: one (function, example_args, shardings) triple per
(arch x shape) cell, plus GNN serve cells for the paper's own models.

Used by launch/dryrun.py (lower+compile), launch/roofline.py (terms) and
benchmarks. Keeping the builders separate from the CLI keeps them
importable without touching the XLA device-count env var.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import (activation_rules, batch_spec,
                                        cache_pspecs, named, param_pspecs,
                                        zero1_pspecs)
from repro.gnn.model import GNNConfig, gnn_forward, init_gnn
from repro.launch.mesh import data_axes
from repro.launch.specs import specs_for
from repro.models.common import logical_axis_rules
from repro.models.transformer import (decode_step, init_params, prefill)
from repro.train.optim import AdamWConfig, OptState, init_opt
from repro.train.step import make_train_step


def _tree_specs(tree, spec_fn):
    return jax.tree.map(spec_fn, tree)


def _batch_shardings(batch, bspec: P, mesh):
    def spec(v):
        nd = getattr(v, "ndim", 0)
        if nd >= 2:
            return NamedSharding(mesh, bspec)
        if nd == 1:
            return NamedSharding(mesh, P(None))
        return NamedSharding(mesh, P())
    return jax.tree.map(spec, batch)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh
               ) -> Tuple[Any, tuple, Any, Any, tuple]:
    """Returns (fn, args, in_shardings, out_shardings, donate_argnums)
    ready for jax.jit(...).lower(*args). Donation aliases the params/opt
    (train) and KV cache (decode) buffers — without it every step would
    double-allocate its largest operand."""
    rules = activation_rules(cfg, mesh)
    # learned-position archs (whisper) need the position table to cover
    # the full cell seq_len; rope archs don't materialize positions
    max_seq = shape.seq_len if cfg.family == "audio" \
        else min(shape.seq_len, 4096)
    params = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), max_seq=max_seq))
    pspecs = param_pspecs(cfg, params, mesh)
    p_shard = named(pspecs, mesh)
    bspec = batch_spec(shape.global_batch, mesh)

    if shape.kind == "train":
        opt_cfg = AdamWConfig(moment_dtype=cfg.dtype.opt_dtype)
        opt = jax.eval_shape(lambda: init_opt(params, opt_cfg))
        mspec = zero1_pspecs(pspecs, params, mesh)
        opt_shard = OptState(step=NamedSharding(mesh, P()),
                             m=named(mspec, mesh), v=named(mspec, mesh))
        batch = specs_for(cfg, shape)
        b_shard = _batch_shardings(batch, bspec, mesh)
        step = make_train_step(cfg, opt_cfg, remat=True)

        def fn(p, o, b):
            with logical_axis_rules(rules):
                return step(p, o, b)

        return (fn, (params, opt, batch),
                (p_shard, opt_shard, b_shard),
                (p_shard, opt_shard, None), (0, 1))

    if shape.kind == "prefill":
        batch = specs_for(cfg, shape)
        b_shard = _batch_shardings(batch, bspec, mesh)

        def fn(p, b):
            with logical_axis_rules(rules):
                return prefill(cfg, p, b)

        return fn, (params, batch), (p_shard, b_shard), None, ()

    # decode
    d = specs_for(cfg, shape)
    c_pspecs = cache_pspecs(cfg, d["cache"], mesh, shape.global_batch)
    c_shard = named(c_pspecs, mesh)
    tok_shard = NamedSharding(mesh, P(bspec[0] if len(bspec) else None,
                                      None))
    pos_shard = NamedSharding(mesh, P())

    def fn(p, cache, token, pos):
        with logical_axis_rules(rules):
            return decode_step(cfg, p, cache, token, pos)

    return (fn, (params, d["cache"], d["token"], d["pos"]),
            (p_shard, c_shard, tok_shard, pos_shard),
            (None, c_shard), (1,))   # donate the KV cache (in-place update)


# ---------------------------------------------------------------------------
# GNN serve cells (the paper's models on the production mesh)


GNN_SERVE_BATCH = 4096      # targets per global step (8 per chip @ 512)


def gnn_batch_specs(cfg: GNNConfig, C: int, f_pad: int = 0,
                    variant: str = "base"
                    ) -> Dict[str, jax.ShapeDtypeStruct]:
    n = cfg.receptive_field
    f = f_pad or cfg.f_in
    sds = jax.ShapeDtypeStruct
    if variant == "opt":
        # beyond-paper serve slimming: ship ONLY the adjacency arrays the
        # model's lowered AckProgram reads, in bf16 (weights are
        # 1/sqrt(deg) -- bf16's 8-bit mantissa is plenty), and bf16
        # features. Halves the HBM/PCIe bytes that dominate the roofline.
        from repro.core.program import lower, required_adjacency
        d = {"feats": sds((C, n, f), np.dtype("bfloat16")),
             "mask": sds((C, n), np.float32)}
        for key in required_adjacency(lower(cfg)):
            d[key] = sds((C, n, n), np.dtype("bfloat16"))
        return d
    return {"feats": sds((C, n, f), np.float32),
            "adj": sds((C, n, n), np.float32),
            "adj_mean": sds((C, n, n), np.float32),
            "mask": sds((C, n), np.float32)}


def build_gnn_cell(cfg: GNNConfig, mesh, C: int = GNN_SERVE_BATCH,
                   variant: str = "base"):
    """Mini-batch GNN inference step on the production mesh. Targets (the
    paper's N_pe parallelism) shard over EVERY mesh axis — the GNN weights
    are tiny and replicated, so the whole pod is one large PE array."""
    all_axes = tuple(mesh.axis_names)
    n_total = int(np.prod([mesh.shape[a] for a in all_axes]))
    cspec = P(all_axes) if C % n_total == 0 else P(data_axes(mesh))
    params = jax.eval_shape(
        lambda: init_gnn(cfg, jax.random.PRNGKey(0)))
    if variant == "opt":     # bf16 weights: halves every layer-boundary
        params = jax.tree.map(                        # write the XLA path
            lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16), params)
    p_shard = jax.tree.map(lambda _: NamedSharding(mesh, P()), params)
    batch = gnn_batch_specs(cfg, C, variant=variant)
    b_shard = {k: NamedSharding(mesh, P(*([cspec[0]] + [None] * (v.ndim - 1))
                                        if len(cspec) else [None] * v.ndim))
               for k, v in batch.items()}

    def fn(p, b):
        emb, _ = gnn_forward(cfg, p, b, mode="dense")
        return emb

    return fn, (params, batch), (p_shard, b_shard), None, ()
