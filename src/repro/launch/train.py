"""Training launcher.

Reduced configs run end-to-end on CPU (examples/tests); full configs are
meant for the real mesh — on this container use launch/dryrun.py for the
compile-only path.

  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b \
      --reduced --steps 100 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json

from repro.configs.registry import get_config
from repro.train.loop import TrainJobConfig, train
from repro.train.optim import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    job = TrainJobConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, log_path=args.log,
                         seq_len=args.seq_len,
                         global_batch=args.global_batch)
    _, _, hist = train(cfg, job, AdamWConfig(lr=args.lr))
    print(json.dumps({"first_loss": hist[0]["loss"],
                      "last_loss": hist[-1]["loss"],
                      "steps": len(hist)}, indent=1))


if __name__ == "__main__":
    main()
