"""Multi-model streaming GNN serving (the paper's deployment shape).

The paper's headline system property (§4.5, pushed further by GraphAGILE):
ONE accelerator configuration from design space exploration serves a SET of
GNN models — GCN, GraphSAGE, GAT — with the task scheduler hiding host work
under device compute. ``GNNServer`` is that shape as a running server:

* several ``DecoupledEngine``s register under one server, validated against
  a shared ``DSEPlan`` from ``core.dse.explore`` (admission control — a
  model outside the plan is rejected, the software "doesn't fit the
  bitstream");
* each model gets its own micro-batcher lane: requests route by model name,
  batch up to C with a tail-latency deadline, and stream into the engine's
  PERSISTENT ``PipelineScheduler`` (no per-batch pipeline construction);
* per-model latency percentiles (p50/p90/p99) and the achieved host/device
  overlap fraction are reported, per model and aggregate.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.dse import DSEPlan, TPUSpec, explore, validate_models
from repro.core.engine import DecoupledEngine

DEFAULT_MODEL = "default"


@dataclass
class Request:
    target: int
    model: str = DEFAULT_MODEL
    t_enqueue: float = field(default_factory=time.perf_counter)
    t_done: float = 0.0
    embedding: Optional[np.ndarray] = None
    error: Optional[BaseException] = None

    @property
    def latency(self) -> float:
        return self.t_done - self.t_enqueue


@dataclass
class ServerStats:
    latencies: List[float] = field(default_factory=list)
    batch_latencies: List[float] = field(default_factory=list)
    n_batches: int = 0

    def percentiles(self) -> Dict[str, float]:
        if not self.latencies:
            return {}
        a = np.array(self.latencies)
        return {"p50": float(np.percentile(a, 50)),
                "p90": float(np.percentile(a, 90)),
                "p99": float(np.percentile(a, 99)),
                "mean": float(a.mean()),
                "batch_mean": float(np.mean(self.batch_latencies)),
                "n": len(a)}


class _ModelLane:
    """One registered model: request queue + micro-batcher thread that
    streams padded batches into the engine's persistent scheduler."""

    def __init__(self, name: str, engine: DecoupledEngine,
                 max_wait_s: float):
        self.name = name
        self.engine = engine
        self.max_wait_s = max_wait_s
        self.q: "queue.Queue[Request]" = queue.Queue()
        self.stats = ServerStats()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- micro-batching ------------------------------------------------------
    def _collect_batch(self) -> List[Request]:
        c = self.engine.batch_size
        out: List[Request] = []
        try:
            out.append(self.q.get(timeout=0.05))
        except queue.Empty:
            return out
        deadline = out[0].t_enqueue + self.max_wait_s
        while len(out) < c:
            tmo = deadline - time.perf_counter()
            if tmo <= 0:
                # deadline passed: still drain whatever is ALREADY queued
                # (no extra waiting) so batches fill under load
                try:
                    while len(out) < c:
                        out.append(self.q.get_nowait())
                except queue.Empty:
                    pass
                break
            try:
                out.append(self.q.get(timeout=tmo))
            except queue.Empty:
                break
        return out

    def _batch_loop(self):
        while not self._stop.is_set():
            reqs = self._collect_batch()
            if not reqs:
                continue
            targets = np.array([r.target for r in reqs])
            t0 = time.perf_counter()
            # streams into the engine's ONE persistent pipeline; blocks
            # only when the scheduler's in-flight bound applies backpressure
            self.engine.submit_chunk(
                targets,
                on_done=lambda tk, rs=reqs, ts=t0: self._on_done(rs, ts, tk))

    def _on_done(self, reqs: List[Request], t0: float, ticket):
        t1 = time.perf_counter()
        if ticket.error is not None:
            # surface the cause on every request of the failed batch so
            # drain() can raise immediately instead of timing out
            for r in reqs:
                r.error = ticket.error
            self.stats.batch_latencies.append(t1 - t0)
            self.stats.n_batches += 1
            return
        emb = np.asarray(ticket.output)
        for i, r in enumerate(reqs):
            r.embedding = emb[i]
            r.t_done = t1
            self.stats.latencies.append(r.latency)
        self.stats.batch_latencies.append(t1 - t0)
        self.stats.n_batches += 1

    # -- lifecycle -----------------------------------------------------------
    def start(self):
        if self._thread is None:
            self._stop.clear()       # server may stop() then start() again
            self._thread = threading.Thread(
                target=self._batch_loop, name=f"lane-{self.name}",
                daemon=True)
            self._thread.start()

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)
            if self._thread.is_alive():
                # a later start() would race a still-live consumer on the
                # same queue — refuse instead of doubling up
                raise RuntimeError(f"lane {self.name!r} did not stop")
            self._thread = None
        self.engine.scheduler.flush(timeout=60)

    def report(self) -> dict:
        r = dict(self.stats.percentiles())
        sched = self.engine.scheduler.stats
        r["overlap"] = round(sched.overlap_fraction, 3)
        r["sched_batches"] = sched.n_batches
        r["kind"] = self.engine.cfg.kind
        # compiled ACK program: per-op mode mux of this lane's datapath
        r["ack"] = {"mode": self.engine.mode,
                    "summary": self.engine.decision.summary,
                    "ops": [{"site": d.site, "op": d.op, "mode": d.mode}
                            for d in self.engine.decision]}
        # host BatchPlan pipeline: per-stage wall time totals (the
        # software Fig. 3 breakdown) + the Build stage's row-cache outcome
        if sched.stage_times:
            r["stage_times"] = {k: round(v, 6) for k, v
                                in list(sched.stage_times.items())}
        r["build_hit_rate"] = round(sched.build_hit_rate, 4)
        # store subsystem: transfer + cache observability (paper t_load /
        # t_pre — what the two-level store saved this lane)
        r["bytes_shipped"] = sched.bytes_shipped
        r["transfer_ratio"] = round(sched.transfer_ratio, 4)
        r["cache_hit_rate"] = round(sched.cache_hit_rate, 4)
        r["dedup_ratio"] = sched.last_dedup_ratio
        if sched.shard_bytes:
            # sharded feature store: per-shard link bytes + skew (1.0 =
            # perfectly even traffic across shards)
            r["shard_bytes"] = list(sched.shard_bytes)
            r["shard_balance"] = round(sched.shard_balance, 4)
        r["store"] = self.engine.store_report()
        return r


class GNNServer:
    """Multi-tenant micro-batching router over DecoupledEngines.

    ``register(name, engine)`` admits a model under the server's shared
    ``DSEPlan`` (recomputed over ALL registered configs unless a fixed plan
    was passed — then admission is validate-only). ``submit`` routes a
    request to its model's lane. max_wait_s bounds tail latency: a partial
    batch is flushed (padded with repeats) once the oldest queued request
    exceeds the wait.

    Back-compat: ``GNNServer(engine)`` registers it as "default" and
    ``submit(target)`` with one registered model needs no model name.
    """

    def __init__(self, engine: Optional[DecoupledEngine] = None,
                 max_wait_s: float = 0.005, *,
                 plan: Optional[DSEPlan] = None,
                 spec: Optional[TPUSpec] = None):
        self.max_wait_s = max_wait_s
        self.spec = spec or TPUSpec()
        self.plan = plan
        self._plan_fixed = plan is not None
        self._lanes: Dict[str, _ModelLane] = {}
        self._started = False
        if engine is not None:
            self.register(DEFAULT_MODEL, engine)

    # -- model registry ------------------------------------------------------
    def register(self, name: str, engine: DecoupledEngine) -> "GNNServer":
        if name in self._lanes:
            raise ValueError(f"model {name!r} already registered")
        cfgs = [ln.engine.cfg for ln in self._lanes.values()] + [engine.cfg]
        if self._plan_fixed:
            validate_models(self.plan, [engine.cfg], self.spec)
        else:
            # one shared plan covering every registered model (the paper's
            # DSE over the model SET), then admission-check each
            plan = explore(cfgs, self.spec)
            validate_models(plan, cfgs, self.spec)
            self.plan = plan
        lane = _ModelLane(name, engine, self.max_wait_s)
        self._lanes[name] = lane
        if self._started:
            lane.start()
        return self

    @property
    def models(self) -> List[str]:
        return list(self._lanes)

    def engine_for(self, model: str) -> DecoupledEngine:
        return self._lanes[model].engine

    # -- request path --------------------------------------------------------
    def submit(self, target: int, model: Optional[str] = None) -> Request:
        if model is None:
            if len(self._lanes) != 1:
                raise ValueError(
                    f"model name required, registered: {self.models}")
            model = next(iter(self._lanes))
        lane = self._lanes.get(model)
        if lane is None:
            raise KeyError(f"unknown model {model!r}; "
                           f"registered: {self.models}")
        r = Request(int(target), model=model)
        lane.q.put(r)
        return r

    def drain(self, requests: List[Request], timeout: float = 60.0):
        t0 = time.perf_counter()
        while any(r.t_done == 0.0 for r in requests):
            failed = next((r for r in requests if r.error is not None),
                          None)
            if failed is not None:
                raise RuntimeError(
                    f"request for vertex {failed.target} via "
                    f"{failed.model!r} failed") from failed.error
            if time.perf_counter() - t0 > timeout:
                raise TimeoutError("serve drain timed out")
            time.sleep(0.002)

    # -- lifecycle -----------------------------------------------------------
    def start(self):
        if not self._lanes:
            raise RuntimeError("no models registered")
        self._started = True
        for lane in self._lanes.values():
            lane.start()

    def stop(self):
        for lane in self._lanes.values():
            lane.stop()
        self._started = False

    # -- reporting -----------------------------------------------------------
    def model_stats(self, model: str) -> ServerStats:
        return self._lanes[model].stats

    @property
    def stats(self) -> ServerStats:
        """Aggregate over all models (back-compat single-model view)."""
        agg = ServerStats()
        for lane in self._lanes.values():
            agg.latencies += lane.stats.latencies
            agg.batch_latencies += lane.stats.batch_latencies
            agg.n_batches += lane.stats.n_batches
        return agg

    def report(self) -> dict:
        """Per-model p50/p90/p99 + overlap fraction under the shared plan."""
        per_model = {n: ln.report() for n, ln in self._lanes.items()}
        return {"models": per_model,
                "plan": {"block_f": self.plan.block_f,
                         "c_core": self.plan.c_core,
                         "buffer_depth": self.plan.buffer_depth,
                         "vmem_used": self.plan.vmem_used},
                "aggregate": self.stats.percentiles()}
