"""Batched mini-batch GNN inference serving (the paper's deployment shape).

Requests (target vertex ids) arrive on a queue; the server forms
fixed-size micro-batches (padding the tail with repeats), runs them through
a DecoupledEngine with the pipelined scheduler, and records per-request
latency. This is the "latency per batch" measurement loop of paper §3.1 /
§5.3 as an actual server.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.engine import DecoupledEngine


@dataclass
class Request:
    target: int
    t_enqueue: float = field(default_factory=time.perf_counter)
    t_done: float = 0.0
    embedding: Optional[np.ndarray] = None

    @property
    def latency(self) -> float:
        return self.t_done - self.t_enqueue


@dataclass
class ServerStats:
    latencies: List[float] = field(default_factory=list)
    batch_latencies: List[float] = field(default_factory=list)
    n_batches: int = 0

    def percentiles(self) -> Dict[str, float]:
        if not self.latencies:
            return {}
        a = np.array(self.latencies)
        return {"p50": float(np.percentile(a, 50)),
                "p90": float(np.percentile(a, 90)),
                "p99": float(np.percentile(a, 99)),
                "mean": float(a.mean()),
                "batch_mean": float(np.mean(self.batch_latencies)),
                "n": len(a)}


class GNNServer:
    """Micro-batching server over a DecoupledEngine.

    max_wait_s bounds tail latency: a partial batch is flushed (padded with
    repeated targets) once the oldest queued request exceeds the wait.
    """

    def __init__(self, engine: DecoupledEngine, max_wait_s: float = 0.005):
        self.engine = engine
        self.max_wait_s = max_wait_s
        self.q: "queue.Queue[Request]" = queue.Queue()
        self.stats = ServerStats()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def submit(self, target: int) -> Request:
        r = Request(int(target))
        self.q.put(r)
        return r

    def _collect_batch(self) -> List[Request]:
        c = self.engine.batch_size
        out: List[Request] = []
        try:
            out.append(self.q.get(timeout=0.05))
        except queue.Empty:
            return out
        deadline = out[0].t_enqueue + self.max_wait_s
        while len(out) < c:
            tmo = deadline - time.perf_counter()
            if tmo <= 0:
                # deadline passed: still drain whatever is ALREADY queued
                # (no extra waiting) so batches fill under load
                try:
                    while len(out) < c:
                        out.append(self.q.get_nowait())
                except queue.Empty:
                    pass
                break
            try:
                out.append(self.q.get(timeout=tmo))
            except queue.Empty:
                break
        return out

    def _serve_loop(self):
        while not self._stop.is_set():
            reqs = self._collect_batch()
            if not reqs:
                continue
            c = self.engine.batch_size
            targets = np.array([r.target for r in reqs])
            if len(targets) < c:
                targets = np.concatenate(
                    [targets, np.repeat(targets[-1:], c - len(targets))])
            t0 = time.perf_counter()
            res = self.engine.infer(targets, overlap=True)
            t1 = time.perf_counter()
            for i, r in enumerate(reqs):
                r.embedding = res.embeddings[i]
                r.t_done = t1
                self.stats.latencies.append(r.latency)
            self.stats.batch_latencies.append(t1 - t0)
            self.stats.n_batches += 1

    def start(self):
        self._thread = threading.Thread(target=self._serve_loop,
                                        daemon=True)
        self._thread.start()

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)

    def drain(self, requests: List[Request], timeout: float = 60.0):
        t0 = time.perf_counter()
        while any(r.t_done == 0.0 for r in requests):
            if time.perf_counter() - t0 > timeout:
                raise TimeoutError("serve drain timed out")
            time.sleep(0.002)
