"""Multi-model streaming GNN serving (the paper's deployment shape).

The paper's headline system property (§4.5, pushed further by GraphAGILE):
ONE accelerator configuration from design space exploration serves a SET of
GNN models — GCN, GraphSAGE, GAT — with the task scheduler hiding host work
under device compute. ``GNNServer`` is that shape as a running server:

* several ``DecoupledEngine``s register under one server, validated against
  a shared ``DSEPlan`` from ``core.dse.explore`` (admission control — a
  model outside the plan is rejected, the software "doesn't fit the
  bitstream");
* each model gets its own micro-batcher lane: requests route by model name,
  batch up to C with a tail-latency deadline, and stream into the engine's
  PERSISTENT ``PipelineScheduler`` (no per-batch pipeline construction);
* per-model latency percentiles (p50/p90/p99) and the achieved host/device
  overlap fraction are reported, per model and aggregate.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.config import ServingConfig
from repro.core.dse import DSEPlan, TPUSpec, explore, validate_models
from repro.core.engine import DecoupledEngine
from repro.core.report_schema import (SCHEMA_VERSION, dispatch_section,
                                      precompute_section, rpc_section,
                                      shards_section, stages_section,
                                      store_section, telemetry_section,
                                      trace_section)
from repro.obs.hist import LogHistogram, Reservoir

DEFAULT_MODEL = "default"


@dataclass
class Request:
    target: int
    model: str = DEFAULT_MODEL
    t_enqueue: float = field(default_factory=time.perf_counter)
    t_done: float = 0.0
    embedding: Optional[np.ndarray] = None
    error: Optional[BaseException] = None

    @property
    def latency(self) -> float:
        return self.t_done - self.t_enqueue


@dataclass
class ServerStats:
    """Per-lane latency state in O(1) memory (schema v2): request and
    batch latencies stream into fixed-size ``LogHistogram``s (exact
    count/mean, quantiles within one ~2.2% bucket) instead of the
    unbounded raw lists of schema v1 — a server that handles millions of
    requests no longer leaks a float per request. ``recent`` keeps the
    newest 256 raw request latencies verbatim for forensics."""
    hist: LogHistogram = field(default_factory=LogHistogram)
    batch_hist: LogHistogram = field(default_factory=LogHistogram)
    recent: Reservoir = field(default_factory=lambda: Reservoir(256))
    n_batches: int = 0

    def record(self, latency_s: float) -> None:
        self.hist.record(latency_s)
        self.recent.record(latency_s)

    def record_batch(self, latency_s: float) -> None:
        self.batch_hist.record(latency_s)
        self.n_batches += 1

    def merge(self, other: "ServerStats") -> "ServerStats":
        self.hist.merge(other.hist)
        self.batch_hist.merge(other.batch_hist)
        for v in other.recent.values():
            self.recent.record(v)
        self.n_batches += other.n_batches
        return self

    @property
    def nbytes(self) -> int:
        """Fixed footprint of the stats structures (the O(1)-in-request-
        count property the regression test pins)."""
        return self.hist.nbytes + self.batch_hist.nbytes \
            + self.recent.capacity * 8

    def percentiles(self) -> Dict[str, float]:
        if not self.hist.count:
            return {}
        return {**self.hist.percentiles(),
                "mean": self.hist.mean,
                "batch_mean": self.batch_hist.mean,
                "n": self.hist.count,
                "hist": self.hist.to_dict()}


class _ModelLane:
    """One registered model: request queue + micro-batcher thread that
    streams padded batches into the engine's persistent scheduler."""

    def __init__(self, name: str, engine: DecoupledEngine,
                 max_wait_s: float):
        self.name = name
        self.engine = engine
        self.max_wait_s = max_wait_s
        self.q: "queue.Queue[Request]" = queue.Queue()
        self.stats = ServerStats()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # metered lane: end-to-end request latency (enqueue -> done)
        # into the engine's windowed registry
        self._h_request = engine.telemetry.whist(
            "repro_request_seconds",
            help="end-to-end request latency") \
            if engine.telemetry is not None else None

    # -- micro-batching ------------------------------------------------------
    def _collect_batch(self) -> List[Request]:
        c = self.engine.batch_size
        out: List[Request] = []
        try:
            out.append(self.q.get(timeout=0.05))
        except queue.Empty:
            return out
        deadline = out[0].t_enqueue + self.max_wait_s
        while len(out) < c:
            tmo = deadline - time.perf_counter()
            if tmo <= 0:
                # deadline passed: still drain whatever is ALREADY queued
                # (no extra waiting) so batches fill under load
                try:
                    while len(out) < c:
                        out.append(self.q.get_nowait())
                except queue.Empty:
                    pass
                break
            try:
                out.append(self.q.get(timeout=tmo))
            except queue.Empty:
                break
        return out

    def _batch_loop(self):
        while not self._stop.is_set():
            reqs = self._collect_batch()
            if not reqs:
                continue
            targets = np.array([r.target for r in reqs])
            t0 = time.perf_counter()
            # streams into the engine's ONE persistent pipeline; blocks
            # only when the scheduler's in-flight bound applies backpressure
            self.engine.submit_chunk(
                targets,
                on_done=lambda tk, rs=reqs, ts=t0: self._on_done(rs, ts, tk))

    def _on_done(self, reqs: List[Request], t0: float, ticket):
        t1 = time.perf_counter()
        if ticket.error is not None:
            # surface the cause on every request of the failed batch so
            # drain() can raise immediately instead of timing out
            for r in reqs:
                r.error = ticket.error
            self.stats.record_batch(t1 - t0)
            return
        emb = np.asarray(ticket.output)
        for i, r in enumerate(reqs):
            r.embedding = emb[i]
            r.t_done = t1
            self.stats.record(r.latency)
            if self._h_request is not None:
                self._h_request.record(r.latency)
        self.stats.record_batch(t1 - t0)

    # -- lifecycle -----------------------------------------------------------
    def start(self):
        if self._thread is None:
            self._stop.clear()       # server may stop() then start() again
            self._thread = threading.Thread(
                target=self._batch_loop, name=f"lane-{self.name}",
                daemon=True)
            self._thread.start()

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)
            if self._thread.is_alive():
                # a later start() would race a still-live consumer on the
                # same queue — refuse instead of doubling up
                raise RuntimeError(f"lane {self.name!r} did not stop")
            self._thread = None
        self.engine.scheduler.flush(timeout=60)

    def report(self) -> dict:
        """This lane's slice of the versioned report schema
        (core.report_schema): latency.* request percentiles, stages.*
        pipeline breakdown, store.* transfer + subsystem state, and —
        when the deployment shards or goes multi-host — shards.*/rpc.*."""
        sched = self.engine.scheduler.stats
        r = {"kind": self.engine.cfg.kind,
             # compiled ACK program: per-op mode mux of this lane
             "ack": {"mode": self.engine.mode,
                     "summary": self.engine.decision.summary,
                     "ops": [{"site": d.site, "op": d.op, "mode": d.mode}
                             for d in self.engine.decision]},
             "latency": dict(self.stats.percentiles()),
             "stages": stages_section(sched),
             # store.*: the scheduler's transfer counters (paper t_load)
             # merged with the engine's store-subsystem state — one
             # namespace, no fourth ad-hoc dict
             "store": {**store_section(sched),
                       **self.engine.store_report()}}
        shards = shards_section(sched)
        if shards is not None:
            r["shards"] = shards
        rpc = rpc_section(sched)
        if rpc is not None:
            r["rpc"] = rpc
        trace = trace_section(self.engine.tracer,
                              self.engine._calib)
        if trace is not None:
            r["trace"] = trace
        if self.engine.precompute is not None:
            r["precompute"] = precompute_section(self.engine.precompute)
        telemetry = telemetry_section(self.engine.telemetry)
        if telemetry is not None:
            r["telemetry"] = telemetry
        dispatch = dispatch_section(self.engine)
        if dispatch is not None:
            r["dispatch"] = dispatch
        return r


class GNNServer:
    """Multi-tenant micro-batching router over DecoupledEngines.

    ``register(name, engine)`` admits a model under the server's shared
    ``DSEPlan`` (recomputed over ALL registered configs unless a fixed plan
    was passed — then admission is validate-only). ``submit`` routes a
    request to its model's lane. max_wait_s bounds tail latency: a partial
    batch is flushed (padded with repeats) once the oldest queued request
    exceeds the wait.

    Back-compat: ``GNNServer(engine)`` registers it as "default" and
    ``submit(target)`` with one registered model needs no model name.
    """

    def __init__(self, engine: Optional[DecoupledEngine] = None,
                 max_wait_s: Optional[float] = None, *,
                 plan: Optional[DSEPlan] = None,
                 spec: Optional[TPUSpec] = None,
                 config: Optional[ServingConfig] = None):
        self.config = config or ServingConfig()
        self.max_wait_s = self.config.max_wait_s if max_wait_s is None \
            else max_wait_s
        self.spec = spec or TPUSpec()
        self.plan = plan
        self._plan_fixed = plan is not None
        self._lanes: Dict[str, _ModelLane] = {}
        self._started = False
        self._metrics_server = None
        if engine is not None:
            self.register(DEFAULT_MODEL, engine)

    # -- model registry ------------------------------------------------------
    def register(self, name: str,
                 engine: Optional[DecoupledEngine] = None, *,
                 graph=None, cfg=None, params=None,
                 config: Optional[ServingConfig] = None) -> "GNNServer":
        """Admit a model: pass a constructed ``engine``, or pass
        ``graph=`` + ``cfg=`` (+ optional ``config=ServingConfig(...)``,
        defaulting to the server's) and the server builds the engine —
        the config-first spelling of multi-model serving."""
        if name in self._lanes:
            raise ValueError(f"model {name!r} already registered")
        if engine is None:
            if graph is None or cfg is None:
                raise TypeError(
                    "register() needs either an engine or graph= + cfg= "
                    "(+ optional config=ServingConfig(...))")
            engine = DecoupledEngine(graph, cfg, params=params,
                                     config=config or self.config)
        elif config is not None:
            raise TypeError(
                "config= applies only when the server builds the engine "
                "(omit engine=, pass graph= and cfg=)")
        cfgs = [ln.engine.cfg for ln in self._lanes.values()] + [engine.cfg]
        if self._plan_fixed:
            validate_models(self.plan, [engine.cfg], self.spec)
        else:
            # one shared plan covering every registered model (the paper's
            # DSE over the model SET), then admission-check each
            plan = explore(cfgs, self.spec)
            validate_models(plan, cfgs, self.spec)
            self.plan = plan
        lane = _ModelLane(name, engine, self.max_wait_s)
        self._lanes[name] = lane
        if self._started:
            lane.start()
        return self

    @property
    def models(self) -> List[str]:
        return list(self._lanes)

    def engine_for(self, model: str) -> DecoupledEngine:
        return self._lanes[model].engine

    # -- request path --------------------------------------------------------
    def submit(self, target: int, model: Optional[str] = None) -> Request:
        if model is None:
            if len(self._lanes) != 1:
                raise ValueError(
                    f"model name required, registered: {self.models}")
            model = next(iter(self._lanes))
        lane = self._lanes.get(model)
        if lane is None:
            raise KeyError(f"unknown model {model!r}; "
                           f"registered: {self.models}")
        r = Request(int(target), model=model)
        lane.q.put(r)
        return r

    def drain(self, requests: List[Request], timeout: float = 60.0):
        t0 = time.perf_counter()
        while any(r.t_done == 0.0 for r in requests):
            failed = next((r for r in requests if r.error is not None),
                          None)
            if failed is not None:
                raise RuntimeError(
                    f"request for vertex {failed.target} via "
                    f"{failed.model!r} failed") from failed.error
            if time.perf_counter() - t0 > timeout:
                raise TimeoutError("serve drain timed out")
            time.sleep(0.002)

    # -- metrics exposition ---------------------------------------------------
    def metrics_wire(self) -> dict:
        """All metered lanes' registries merged into one server view:
        each lane's wire gets a ``model=<name>`` label first, so
        same-name families from different models stay distinct series
        (and a multi-host lane folds its graph hosts in losslessly via
        ``engine.metrics_wire``)."""
        from repro.obs.metrics import inject_labels, merge_wire
        wires = []
        for name, lane in self._lanes.items():
            if lane.engine.telemetry is None:
                continue
            wires.append(inject_labels(lane.engine.metrics_wire(),
                                       model=name))
        return merge_wire(wires)

    def metrics_text(self) -> str:
        """Prometheus text exposition of every metered lane (what the
        server's HTTP ``/metrics`` endpoint serves)."""
        from repro.obs.promexp import render_wire
        return render_wire(self.metrics_wire())

    @property
    def metrics_url(self) -> Optional[str]:
        return self._metrics_server.url if self._metrics_server else None

    # -- lifecycle -----------------------------------------------------------
    def start(self):
        if not self._lanes:
            raise RuntimeError("no models registered")
        self._started = True
        for lane in self._lanes.values():
            lane.start()
        # exposition endpoint: on when the server's config asks for a
        # port (a Prometheus scraper polls GET /metrics; port 0 picks an
        # ephemeral one, surfaced via .metrics_url)
        tconf = self.config.telemetry
        if tconf is not None and tconf.port is not None \
                and self._metrics_server is None:
            from repro.obs.promexp import MetricsHTTPServer
            self._metrics_server = MetricsHTTPServer(
                self.metrics_text, port=tconf.port)

    def stop(self):
        for lane in self._lanes.values():
            lane.stop()
        if self._metrics_server is not None:
            self._metrics_server.close()
            self._metrics_server = None
        self._started = False

    # -- reporting -----------------------------------------------------------
    def model_stats(self, model: str) -> ServerStats:
        return self._lanes[model].stats

    @property
    def stats(self) -> ServerStats:
        """Aggregate over all models (back-compat single-model view)."""
        agg = ServerStats()
        for lane in self._lanes.values():
            agg.merge(lane.stats)
        return agg

    def report(self) -> dict:
        """Per-model latency.*/stages.*/store.*(/shards.*/rpc.*) under
        the shared plan — the versioned report schema
        (core.report_schema.SCHEMA_VERSION)."""
        per_model = {n: ln.report() for n, ln in self._lanes.items()}
        return {"schema_version": SCHEMA_VERSION,
                "models": per_model,
                "plan": {"block_f": self.plan.block_f,
                         "c_core": self.plan.c_core,
                         "buffer_depth": self.plan.buffer_depth,
                         "vmem_used": self.plan.vmem_used},
                "aggregate": {"latency": self.stats.percentiles()}}
