# GNN serving: multi-model streaming runtime over DecoupledEngines.
from repro.core.config import ServingConfig
from repro.core.report_schema import SCHEMA, SCHEMA_VERSION
from repro.serve.gnn_server import GNNServer, Request, ServerStats

__all__ = ["GNNServer", "Request", "ServerStats", "ServingConfig",
           "SCHEMA", "SCHEMA_VERSION"]
