# GNN serving: multi-model streaming runtime over DecoupledEngines.
from repro.serve.gnn_server import GNNServer, Request, ServerStats
