"""Data pipelines: synthetic token stream for LM training and a
target-vertex stream for GNN inference — both with background prefetch and
straggler mitigation (the paper's host-side overlap, generalized).

Token batches are deterministic functions of (seed, step) so training is
reproducible and restart-safe: after checkpoint restore at step k the
pipeline resumes at batch k with no state file.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    prefetch: int = 2
    # straggler mitigation: if a produce takes > straggler_timeout x the
    # trailing mean, the batch is produced from the fallback fast path
    straggler_timeout: float = 10.0


def synthetic_batch(cfg: TokenPipelineConfig, step: int
                    ) -> Dict[str, np.ndarray]:
    """Markov-ish synthetic tokens: deterministic in (seed, step)."""
    rng = np.random.default_rng((cfg.seed << 20) ^ step)
    b, s = cfg.global_batch, cfg.seq_len
    base = rng.integers(0, cfg.vocab_size, size=(b, s), dtype=np.int32)
    # inject local structure so loss decreases measurably when training:
    # token t+1 := (token t + delta) mod V on half the positions
    delta = rng.integers(1, 17, size=(b, 1), dtype=np.int32)
    structured = (base[:, :-1] + delta) % cfg.vocab_size
    mask = rng.random((b, s - 1)) < 0.5
    tokens = base.copy()
    tokens[:, 1:] = np.where(mask, structured, base[:, 1:])
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = 0
    return {"tokens": tokens, "labels": labels}


class PrefetchIterator:
    """Background-thread prefetch with straggler skip.

    produce(step) runs in a worker; if it stalls beyond the straggler
    budget the consumer synthesizes the batch inline (deterministic, so the
    skipped worker result is simply discarded on arrival).
    """

    def __init__(self, produce, prefetch: int = 2,
                 straggler_timeout_s: Optional[float] = None):
        self.produce = produce
        self.q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self.straggler_timeout_s = straggler_timeout_s
        self._stop = threading.Event()
        self._step = 0
        self._consumed = 0
        self.stragglers_skipped = 0
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = 0
        while not self._stop.is_set():
            batch = self.produce(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        want = self._consumed
        tmo = self.straggler_timeout_s
        try:
            step, batch = self.q.get(timeout=tmo) if tmo else self.q.get()
            while step < want:      # stale (already skipped) batches
                step, batch = self.q.get(timeout=tmo) if tmo \
                    else self.q.get()
        except queue.Empty:
            self.stragglers_skipped += 1
            batch = self.produce(want)      # inline fallback
        self._consumed = want + 1
        return batch

    def __iter__(self) -> Iterator:
        return self

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)


def token_pipeline(cfg: TokenPipelineConfig) -> PrefetchIterator:
    return PrefetchIterator(lambda step: synthetic_batch(cfg, step),
                            prefetch=cfg.prefetch)


def target_vertex_stream(num_vertices: int, batch: int, seed: int = 0):
    """Endless stream of target-vertex batches for GNN serving."""
    rng = np.random.default_rng(seed)
    while True:
        yield rng.integers(0, num_vertices, size=batch, dtype=np.int64)
