"""Hypothesis property tests for the PPR host path.

Kept separate from test_gnn_core so the tier-1 suite collects (and a fixed
seed of the same property still runs there) when ``hypothesis`` is not
installed — ``pip install -e .[test]`` pulls it in for CI.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.ini import ppr_local_push, ppr_power_iteration  # noqa: E402
from repro.graphs.csr import from_edge_list  # noqa: E402


def small_graph(n, seed, extra_edges=2):
    rng = np.random.default_rng(seed)
    # random connected-ish graph
    src = np.arange(1, n)
    dst = rng.integers(0, np.maximum(src, 1))
    e_src = rng.integers(0, n, size=n * extra_edges)
    e_dst = rng.integers(0, n, size=n * extra_edges)
    feats = rng.standard_normal((n, 8)).astype(np.float32)
    return from_edge_list(np.concatenate([src, e_src]),
                          np.concatenate([dst, e_dst]), n, feats)


class TestPPRProperties:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 10_000))
    def test_local_push_matches_power_iteration(self, seed):
        g = small_graph(60, seed)
        t = int(np.random.default_rng(seed).integers(0, 60))
        verts, scores = ppr_local_push(g, t, eps=1e-7)
        pi = ppr_power_iteration(g, t)
        dense = np.zeros(g.num_vertices)
        dense[verts] = scores
        # approximate PPR within eps * deg per vertex (ACL guarantee)
        err = np.abs(dense - pi).max()
        assert err < 1e-4, err

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 10_000), st.floats(1e-6, 1e-4))
    def test_push_mass_bounded(self, seed, eps):
        g = small_graph(40, seed)
        _, scores = ppr_local_push(g, seed % 40, eps=eps)
        assert (scores >= 0).all()
        assert scores.sum() <= 1.0 + 1e-6
