"""Paper-core behaviour: PPR-INI, subgraph building, decoupled==coupled,
ACK mode equivalence, scheduler overlap, DSE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ack import choose_mode
from repro.core.coupled import (coupled_reference_embedding, lhop_nodes,
                                receptive_field_size)
from repro.core.dse import TPUSpec, explore
from repro.core.engine import DecoupledEngine
from repro.core.ini import (ini_batch, ppr_local_push, ppr_power_iteration,
                            select_important)
from repro.core.scheduler import PipelineScheduler
from repro.core.subgraph import (batch_from_node_lists, build_batch,
                                 default_edge_pad)
from repro.gnn.model import (GNNConfig, gnn_forward, init_gnn,
                             paper_model_grid)
from repro.graphs.csr import from_edge_list
from repro.graphs.synthetic import get_graph


@pytest.fixture(scope="module")
def graph():
    return get_graph("flickr", scale=0.02, seed=1)   # ~1.8k vertices


def small_graph(n, seed, extra_edges=2):
    rng = np.random.default_rng(seed)
    # random connected-ish graph
    src = np.arange(1, n)
    dst = rng.integers(0, np.maximum(src, 1))
    e_src = rng.integers(0, n, size=n * extra_edges)
    e_dst = rng.integers(0, n, size=n * extra_edges)
    feats = rng.standard_normal((n, 8)).astype(np.float32)
    return from_edge_list(np.concatenate([src, e_src]),
                          np.concatenate([dst, e_dst]), n, feats)


class TestPPR:
    # the hypothesis-driven push-vs-power-iteration property test lives in
    # test_gnn_properties.py (skips cleanly when hypothesis is absent);
    # this seed pins one deterministic instance of it in tier-1
    def test_local_push_matches_power_iteration_fixed_seed(self):
        g = small_graph(60, seed=1234)
        t = int(np.random.default_rng(1234).integers(0, 60))
        verts, scores = ppr_local_push(g, t, eps=1e-7)
        pi = ppr_power_iteration(g, t)
        dense = np.zeros(g.num_vertices)
        dense[verts] = scores
        # approximate PPR within eps * deg per vertex (ACL guarantee)
        err = np.abs(dense - pi).max()
        assert err < 1e-4, err

    def test_push_mass_conservation(self, graph):
        verts, scores = ppr_local_push(graph, 3, eps=1e-5)
        total = scores.sum()
        assert 0.5 < total <= 1.0 + 1e-6   # p mass <= 1, most recovered

    def test_select_important_target_first(self, graph):
        nodes = select_important(graph, 17, 64)
        assert nodes[0] == 17
        assert len(nodes) <= 64
        assert len(np.unique(nodes)) == len(nodes)

    def test_ini_batch_threads_match_serial(self, graph):
        targets = [1, 5, 9, 13]
        a = ini_batch(graph, targets, 32, num_threads=1)
        b = ini_batch(graph, targets, 32, num_threads=4)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)


class TestSubgraph:
    def test_padding_and_norms(self, graph):
        sb = build_batch(graph, [3, 7], 64, num_threads=1)
        assert sb.feats.shape == (2, 64, graph.feature_dim)
        assert sb.adj.shape == (2, 64, 64)
        # masked rows are all-zero
        for c in range(2):
            k = int(sb.n_vertices[c])
            assert sb.mask[c, :k].all() and not sb.mask[c, k:].any()
            assert (sb.adj[c, k:, :] == 0).all()
            assert (sb.feats[c, k:, :] == 0).all()
            # adj_mean rows are row-stochastic where a row has neighbors
            rs = sb.adj_mean[c].sum(1)
            nz = rs > 0
            np.testing.assert_allclose(rs[nz], 1.0, rtol=1e-5)

    def test_pad_invariance(self, graph):
        """Embedding must not depend on the pad width."""
        nodes = select_important(graph, 3, 32)
        cfg64 = GNNConfig(kind="gcn", n_layers=2, receptive_field=64,
                          f_in=graph.feature_dim, readout="max")
        cfg128 = GNNConfig(kind="gcn", n_layers=2, receptive_field=128,
                           f_in=graph.feature_dim, readout="max")
        params = init_gnn(cfg64, jax.random.PRNGKey(0))
        e = default_edge_pad(graph, 128)
        for cfg, npad in ((cfg64, 64), (cfg128, 128)):
            sb = batch_from_node_lists(graph, [3], [nodes], npad, e)
            b = dict(feats=sb.feats, adj=sb.adj, adj_mean=sb.adj_mean,
                     mask=sb.mask)
            emb, _ = gnn_forward(cfg, params, b)
            if npad == 64:
                ref = np.asarray(emb)
            else:
                np.testing.assert_allclose(np.asarray(emb), ref,
                                           rtol=1e-5, atol=1e-5)

    def test_receptive_field_growth(self, graph):
        """Coupled L-hop receptive field explodes; decoupled stays fixed."""
        targets = list(range(8))
        r1 = receptive_field_size(graph, targets, 1)
        r2 = receptive_field_size(graph, targets, 2)
        r3 = receptive_field_size(graph, targets, 3)
        assert r1 < r2 < r3
        assert r3 > 10 * r1


class TestDecoupledVsCoupled:
    """The paper's equivalence: over the FULL L-hop receptive field with
    readout='target', decoupled inference == Algorithm-1 recursion."""

    @pytest.mark.parametrize("kind", ["gcn", "sage"])
    @pytest.mark.parametrize("L", [1, 2, 3])
    def test_equivalence(self, kind, L):
        g = small_graph(80, seed=L * 7 + (kind == "sage"))
        tgt = 5
        nodes = lhop_nodes(g, tgt, L)
        npad = int(max(8, 1 << int(np.ceil(np.log2(len(nodes))))))
        cfg = GNNConfig(kind=kind, n_layers=L, receptive_field=npad,
                        f_in=g.feature_dim, f_hidden=16, readout="target")
        params = init_gnn(cfg, jax.random.PRNGKey(L))
        sb = batch_from_node_lists(g, [tgt], [nodes], npad,
                                   max(1, npad * (npad - 1)))
        assert sb.edges_dropped == 0
        b = dict(feats=sb.feats, adj=sb.adj, adj_mean=sb.adj_mean,
                 mask=sb.mask)
        emb, _ = gnn_forward(cfg, params, b)
        ref = coupled_reference_embedding(
            g, tgt, L, jax.tree.map(np.asarray, params), kind)
        np.testing.assert_allclose(np.asarray(emb)[0], ref,
                                   rtol=2e-4, atol=2e-5)


class TestAckModes:
    @pytest.mark.parametrize("kind", ["gcn", "sage", "gin", "gat"])
    def test_dense_equals_sg(self, kind, graph):
        cfg = GNNConfig(kind=kind, n_layers=2, receptive_field=64,
                        f_in=graph.feature_dim)
        e = DecoupledEngine(graph, cfg, batch_size=4, impl="xla",
                            mode="dense", e_pad=64 * 63)
        r1 = e.infer(np.arange(4), overlap=False)
        e2 = DecoupledEngine(graph, cfg, params=e.params, batch_size=4,
                             impl="xla", mode="sg", e_pad=64 * 63)
        r2 = e2.infer(np.arange(4), overlap=False)
        scale = np.abs(r1.embeddings).max()
        np.testing.assert_allclose(r1.embeddings / scale,
                                   r2.embeddings / scale,
                                   rtol=1e-4, atol=1e-5)

    def test_mode_choice(self):
        # dense subgraph -> dense mode; ultra-sparse -> sg
        assert choose_mode(128, avg_edges=2000, f=256).mode == "dense"
        assert choose_mode(256, avg_edges=20, f=256).mode == "sg"
        assert choose_mode(64, avg_edges=999, f=256,
                           force="sg").mode == "sg"


class TestEngineAndScheduler:
    def test_engine_end_to_end(self, graph):
        cfg = GNNConfig(kind="sage", n_layers=3, receptive_field=64,
                        f_in=graph.feature_dim)
        eng = DecoupledEngine(graph, cfg, batch_size=8)
        res = eng.infer(np.arange(20))     # non-multiple of batch
        assert res.embeddings.shape == (20, cfg.f_hidden)
        assert np.isfinite(res.embeddings).all()
        assert res.stats.n_batches == 3
        assert res.stats.t_initialization > 0

    def test_scheduler_overlap_vs_serial(self):
        import time

        def host_fn(i):
            time.sleep(0.01)
            return i

        def dev_fn(x):
            time.sleep(0.01)
            return jnp.asarray(x)

        sched = PipelineScheduler(host_fn, dev_fn, depth=3)
        sched.run([0])   # warm one-time device dispatch init out of timing
        _, st_overlap = sched.run(list(range(8)), overlap=True)
        _, st_serial = sched.run(list(range(8)), overlap=False)
        sched.close()
        assert st_overlap.t_wall < st_serial.t_wall * 0.85
        assert st_overlap.overlap_fraction > 0.3

    def test_batch_results_identical_with_and_without_overlap(self, graph):
        cfg = GNNConfig(kind="gcn", n_layers=2, receptive_field=32,
                        f_in=graph.feature_dim)
        eng = DecoupledEngine(graph, cfg, batch_size=4)
        a = eng.infer(np.arange(8), overlap=True).embeddings
        b = eng.infer(np.arange(8), overlap=False).embeddings
        np.testing.assert_allclose(a, b, rtol=1e-6)


class TestDSE:
    def test_plan_properties(self):
        models = list(paper_model_grid())
        plan = explore(models)
        assert plan.ops_ok
        assert plan.block_f % 128 == 0
        assert plan.block_f & (plan.block_f - 1) == 0   # power of two
        assert plan.vmem_used <= TPUSpec().vmem_bytes
        assert len(plan.per_model) == len({m.display for m in models})

    def test_single_plan_covers_all_models(self):
        """Paper's claim: ONE hardware point serves every model spec."""
        small = explore([GNNConfig(kind="gcn", n_layers=3,
                                   receptive_field=64, f_in=128)])
        big = explore(list(paper_model_grid()))
        # plan for the superset must still fit VMEM
        assert big.vmem_used <= TPUSpec().vmem_bytes
        assert big.block_f <= small.block_f * 4


class TestFeatureDedup:
    """Beyond-paper H6: cross-target feature dedup (EXPERIMENTS SPerf)."""

    def test_packed_equals_dense(self, graph):
        cfg = GNNConfig(kind="gcn", n_layers=2, receptive_field=64,
                        f_in=graph.feature_dim)
        from repro.store import StorePolicy
        e1 = DecoupledEngine(graph, cfg, batch_size=8)
        e2 = DecoupledEngine(graph, cfg, params=e1.params, batch_size=8,
                             store=StorePolicy(features="packed"))
        t = np.arange(16)
        r1 = e1.infer(t, overlap=False)
        r2 = e2.infer(t, overlap=False)
        np.testing.assert_array_equal(r1.embeddings, r2.embeddings)
        assert e2.last_dedup_ratio < 1.0   # hubs recur -> actual savings

    def test_ratio_improves_with_batch(self, graph):
        from repro.core.ini import ini_batch
        from repro.core.subgraph import packed_features
        nl8 = ini_batch(graph, list(range(8)), 64, num_threads=1)
        nl64 = ini_batch(graph, list(range(64)), 64, num_threads=1)
        _, _, r8 = packed_features(nl8, graph, 64)
        _, _, r64 = packed_features(nl64, graph, 64)
        assert r64 < r8    # more targets -> more hub reuse
