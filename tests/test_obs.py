"""Observability subsystem: streaming histograms (fixed memory, exact
counts), flight recorder (exactly the K slowest), span tree
well-formedness, chrome-trace export shape, cross-RPC trace propagation
through BOTH transports with clock-offset stitching, disabled-tracing
bitwise equality, and the O(1)-memory regression for server stats."""
import json

import numpy as np
import pytest

from repro.core.config import ServingConfig
from repro.core.engine import DecoupledEngine
from repro.core.report_schema import SCHEMA, SCHEMA_VERSION
from repro.distributed.graph_host import GraphHostService
from repro.distributed.rpc import GraphHostServer, estimate_clock_offsets
from repro.gnn.model import GNNConfig
from repro.graphs.synthetic import get_graph
from repro.obs import (CalibrationTable, FlightRecorder, LogHistogram,
                       Reservoir, TraceConfig, Tracer, containment,
                       hist_dict_quantile, to_chrome_trace,
                       validate_chrome_trace)
from repro.obs.export import main as export_main
from repro.serve.gnn_server import GNNServer, ServerStats

N = 16
C = 4
SCALE = 0.004
SEED = 1
TARGETS = np.arange(12)


@pytest.fixture(scope="module")
def graph():
    return get_graph("flickr", scale=SCALE, seed=SEED)


def _cfg(graph):
    return GNNConfig(kind="gcn", n_layers=2, receptive_field=N,
                     f_in=graph.feature_dim)


def _assert_well_formed(spans):
    """No orphans, no negative durations, children inside parents'
    traces."""
    ids = {s["span_id"] for s in spans}
    by_id = {s["span_id"]: s for s in spans}
    assert len(ids) == len(spans), "duplicate span ids"
    for s in spans:
        assert s["dur"] >= 0, f"negative duration: {s}"
        if s["parent_id"] is not None:
            assert s["parent_id"] in ids, f"orphan span: {s}"
            assert by_id[s["parent_id"]]["trace_id"] == s["trace_id"], \
                "child crosses trace boundary"


class TestLogHistogram:
    def test_exact_count_mean_min_max(self):
        h = LogHistogram()
        vals = [0.001, 0.002, 0.004, 0.1, 1.5]
        for v in vals:
            h.record(v)
        assert h.count == len(vals)
        assert h.mean == pytest.approx(np.mean(vals))
        assert h.min == min(vals) and h.max == max(vals)

    def test_quantile_within_bucket_error(self):
        rng = np.random.default_rng(0)
        vals = rng.lognormal(-5, 1.0, 10_000)
        h = LogHistogram()
        for v in vals:
            h.record(v)
        for q in (0.5, 0.9, 0.99):
            exact = float(np.quantile(vals, q))
            est = h.quantile(q)
            # one bucket is 2**(1/16) wide (~4.4% total slack)
            assert est == pytest.approx(exact, rel=0.05)

    def test_fixed_memory(self):
        h = LogHistogram()
        before = h.nbytes
        for v in np.random.default_rng(1).uniform(1e-6, 10, 50_000):
            h.record(float(v))
        assert h.nbytes == before      # O(1) in samples

    def test_ignores_negative_and_nan(self):
        h = LogHistogram()
        h.record(-1.0)
        h.record(float("nan"))
        assert h.count == 0 and h.quantile(0.5) == 0.0

    def test_merge_and_serialized_quantile(self):
        a, b = LogHistogram(), LogHistogram()
        for v in (0.001, 0.002):
            a.record(v)
        for v in (0.1, 0.2):
            b.record(v)
        a.merge(b)
        assert a.count == 4
        d = a.to_dict()
        assert d["count"] == 4
        assert hist_dict_quantile(d, 0.5) == a.quantile(0.5)

    def test_reservoir_bounded(self):
        r = Reservoir(8)
        for i in range(100):
            r.record(float(i))
        assert len(r) == 8
        assert r.values() == [float(i) for i in range(92, 100)]


class TestFlightRecorder:
    def test_keeps_exactly_k_slowest(self):
        fr = FlightRecorder(4)
        rng = np.random.default_rng(2)
        durs = rng.uniform(0.001, 1.0, 50)
        for i, d in enumerate(durs):
            fr.offer(i, float(d), [{"span": i}])
        kept = [e["dur"] for e in fr.entries()]
        assert len(kept) == 4
        assert kept == sorted(durs, reverse=True)[:4]
        assert kept == sorted(kept, reverse=True)   # slowest first

    def test_k_zero_keeps_nothing(self):
        fr = FlightRecorder(0)
        assert fr.offer(1, 1.0, []) is False
        assert len(fr) == 0


class TestTracerCore:
    def test_span_tree_well_formed(self):
        tr = Tracer(TraceConfig())
        for i in range(3):
            ctx = tr.maybe_trace(seq=i)
            with tr.span("select", ctx=ctx):
                with tr.span("inner"):
                    pass
            tr.finish_ticket(ctx)
        spans = tr.export_spans()
        _assert_well_formed(spans)
        assert sum(1 for s in spans if s["name"] == "batch") == 3
        inner = next(s for s in spans if s["name"] == "inner")
        sel = next(s for s in spans
                   if s["name"] == "select"
                   and s["trace_id"] == inner["trace_id"])
        assert inner["parent_id"] == sel["span_id"]

    def test_sampling(self):
        tr = Tracer(TraceConfig(sample_every=3))
        ctxs = [tr.maybe_trace() for _ in range(9)]
        assert sum(c is not None for c in ctxs) == 3

    def test_untraced_span_is_noop(self):
        tr = Tracer(TraceConfig())
        with tr.span("anything") as h:   # no ctx, no current span
            assert h is None
        assert tr.spans_recorded == 0

    def test_ring_bounded(self):
        tr = Tracer(TraceConfig(ring_capacity=10, flight_k=0))
        for i in range(50):
            ctx = tr.maybe_trace(seq=i)
            with tr.span("s", ctx=ctx):
                pass
            tr.finish_ticket(ctx)
        assert len(tr.export_spans()) <= 10
        assert tr.spans_dropped > 0

    def test_config_validation(self):
        with pytest.raises(ValueError):
            TraceConfig(sample_every=0)
        with pytest.raises(ValueError):
            TraceConfig(ring_capacity=0)
        with pytest.raises(TypeError):
            ServingConfig(trace="yes")


class TestChromeExport:
    def test_export_shape_and_validation(self):
        tr = Tracer(TraceConfig())
        ctx = tr.maybe_trace(seq=0)
        with tr.span("select", ctx=ctx):
            with tr.span("inner"):
                pass
        tr.finish_ticket(ctx)
        tree = to_chrome_trace(tr.export_spans())
        assert validate_chrome_trace(tree) == []
        evs = tree["traceEvents"]
        assert sum(1 for e in evs if e["ph"] == "B") \
            == sum(1 for e in evs if e["ph"] == "E")
        # metadata rows name processes and lanes
        assert any(e["ph"] == "M" and e["name"] == "process_name"
                   for e in evs)

    def test_validator_catches_broken_traces(self):
        b = {"ph": "B", "name": "x", "pid": 1, "tid": 1, "ts": 0.0,
             "args": {}}
        assert validate_chrome_trace({"traceEvents": [b]})  # unclosed B
        e = {"ph": "E", "name": "x", "pid": 1, "tid": 1, "ts": 1.0}
        assert validate_chrome_trace({"traceEvents": [e]})  # E without B
        dangling = dict(b, args={"span_id": 1, "parent_id": 999})
        probs = validate_chrome_trace(
            {"traceEvents": [dangling, dict(e)]})
        assert any("resolves to no span" in p for p in probs)

    def test_cli_roundtrip(self, tmp_path):
        tr = Tracer(TraceConfig())
        ctx = tr.maybe_trace(seq=0)
        with tr.span("select", ctx=ctx):
            pass
        tr.finish_ticket(ctx)
        dump = tmp_path / "spans.json"
        dump.write_text(json.dumps(tr.export_spans()))
        out = tmp_path / "out.trace.json"
        assert export_main([str(dump), "-o", str(out)]) == 0
        assert export_main([str(out), "--validate"]) == 0


class TestCalibration:
    def test_table_rows(self):
        t = CalibrationTable()
        for d in (0.001, 0.002, 0.003):
            t.record("Aggregate", "xla/dense", 10, d)
        rows = t.rows()
        assert len(rows) == 1 and rows[0]["count"] == 3
        assert rows[0]["op"] == "Aggregate"

    def test_engine_calibration_pass(self, graph):
        tc = TraceConfig(calibrate_every=1)
        sc = ServingConfig(batch_size=C, num_threads=2, trace=tc)
        with DecoupledEngine(graph, _cfg(graph), config=sc) as eng:
            out = eng.infer(TARGETS).embeddings
        with DecoupledEngine(graph, _cfg(graph),
                             config=ServingConfig(
                                 batch_size=C, num_threads=2)) as eng2:
            ref = eng2.infer(TARGETS).embeddings
        # calibration outputs are discarded: serving stays bitwise
        np.testing.assert_array_equal(out, ref)


class TestEngineTracing:
    def test_disabled_tracing_bitwise_equal(self, graph):
        cfg = _cfg(graph)
        with DecoupledEngine(graph, cfg, config=ServingConfig(
                batch_size=C, num_threads=2)) as eng:
            ref = eng.infer(TARGETS).embeddings
            assert eng.trace_report() == {"enabled": False}
            with pytest.raises(ValueError):
                eng.export_trace("/tmp/never.json")
        with DecoupledEngine(graph, cfg, config=ServingConfig(
                batch_size=C, num_threads=2,
                trace=TraceConfig())) as eng:
            out = eng.infer(TARGETS).embeddings
            rep = eng.trace_report()
        np.testing.assert_array_equal(ref, out)
        assert rep["enabled"] and rep["tickets_traced"] == 3
        for key in rep:
            assert key in SCHEMA["trace"], f"undocumented trace key {key}"

    def test_span_tree_from_real_pipeline(self, graph, tmp_path):
        with DecoupledEngine(graph, _cfg(graph), config=ServingConfig(
                batch_size=C, num_threads=2,
                trace=TraceConfig())) as eng:
            eng.infer(TARGETS)
            spans = eng.tracer.export_spans()
            tree = eng.export_trace(str(tmp_path / "t.json"))
        _assert_well_formed(spans)
        names = {s["name"] for s in spans}
        assert {"batch", "select", "build", "pack", "device"} <= names
        assert validate_chrome_trace(tree) == []
        assert json.loads(
            (tmp_path / "t.json").read_text())["traceEvents"]

    def test_flight_recorder_in_engine(self, graph):
        with DecoupledEngine(graph, _cfg(graph), config=ServingConfig(
                batch_size=C, num_threads=2,
                trace=TraceConfig(flight_k=2))) as eng:
            eng.infer(np.arange(24))     # 6 batches
            rep = eng.trace_report()
        assert rep["flight"]["k"] == 2
        assert rep["flight"]["retained"] == 2
        assert rep["flight"]["offered"] == 6
        durs = [s["dur"] for s in rep["flight"]["slowest"]]
        assert durs == sorted(durs, reverse=True)


class TestRemoteTracing:
    def test_inproc_propagation_and_stitching(self, graph):
        sc = ServingConfig(batch_size=C, num_threads=2,
                           transport="inproc", trace=TraceConfig())
        with DecoupledEngine(graph, _cfg(graph), config=sc) as eng:
            ref_local = DecoupledEngine(
                graph, _cfg(graph),
                config=ServingConfig(batch_size=C, num_threads=2))
            ref = ref_local.infer(TARGETS).embeddings
            ref_local.close()
            out = eng.infer(TARGETS).embeddings
            spans = eng.tracer.export_spans()
            rep = eng.trace_report()
            sr = eng.store_report()
        np.testing.assert_array_equal(ref, out)
        _assert_well_formed(spans)
        remote = [s for s in spans if s["host"].startswith("graph-host")]
        assert {s["name"] for s in remote} \
            == {"remote.select", "remote.build"}
        # remote spans join the client's trace under the rpc stage span
        by_id = {s["span_id"]: s for s in spans}
        for s in remote:
            assert by_id[s["parent_id"]]["name"] == "select_build"
        assert containment(spans, "select_build", remote[0]["host"]) \
            == []
        assert rep["remote_spans"] == len(remote)
        assert "inproc" in rep["clock_sync"]
        # satellite: remote Select/Build split per host in store_report
        host_rep = sr["graph_hosts"][0]["report"]
        assert host_rep["stage_times"]["select"] > 0
        assert host_rep["spans_emitted"] == len(remote)

    def test_socket_propagation_and_stitching(self, graph):
        svc = GraphHostService(graph, num_threads=2)
        server = GraphHostServer(svc)
        try:
            sc = ServingConfig(batch_size=C, num_threads=2,
                               transport="socket",
                               endpoints=(server.endpoint,),
                               trace=TraceConfig())
            with DecoupledEngine(graph, _cfg(graph), config=sc) as eng:
                eng.infer(TARGETS)
                spans = eng.tracer.export_spans()
                rep = eng.trace_report()
            _assert_well_formed(spans)
            remote = [s for s in spans
                      if s["host"].startswith("graph-host")]
            assert len(remote) == 2 * 3          # 2 spans x 3 batches
            assert all(s["args"]["endpoint"] == server.endpoint
                       for s in remote)
            assert containment(spans, "select_build",
                               remote[0]["host"]) == []
            assert server.endpoint in rep["clock_sync"]
            tree = to_chrome_trace(spans)
            assert validate_chrome_trace(tree) == []
        finally:
            server.close()

    def test_clock_offset_estimator(self, graph):
        from repro.distributed.rpc import HostPool, InProcTransport
        svc = GraphHostService(graph, num_threads=1)
        pool = HostPool([InProcTransport(svc, owns_service=True)])
        try:
            sync = estimate_clock_offsets(pool, pings=3)
            # same process, same clock anchor: offset is ~0 (< 5 ms)
            assert abs(sync["inproc"]["offset_s"]) < 5e-3
            assert sync["inproc"]["rtt_s"] >= 0
        finally:
            pool.close()


class TestServerStatsBounded:
    def test_percentile_keys_preserved(self):
        st = ServerStats()
        for v in (0.01, 0.02, 0.03):
            st.record(v)
        st.record_batch(0.05)
        p = st.percentiles()
        assert {"p50", "p90", "p99", "mean", "batch_mean",
                "n", "hist"} <= set(p)
        assert p["n"] == 3
        assert p["hist"]["count"] == 3

    def test_stats_memory_o1_in_batch_count(self):
        """Regression: stats structures stay fixed-size as requests
        stream in (the schema-v1 lists grew one float per request)."""
        st = ServerStats()
        for v in np.random.default_rng(0).uniform(1e-4, 1.0, 200):
            st.record(float(v))
            st.record_batch(float(v))
        before = st.nbytes
        for v in np.random.default_rng(1).uniform(1e-4, 1.0, 20_000):
            st.record(float(v))
            st.record_batch(float(v))
        assert st.nbytes == before
        assert st.hist.count == 20_200

    def test_scheduler_times_bounded(self):
        from repro.core.scheduler import RECENT_TIMES, SchedulerStats
        s = SchedulerStats()
        for i in range(RECENT_TIMES * 2):
            s.record(0.001, 0.002)
        assert len(s.host_times) == RECENT_TIMES
        assert s.n_batches == RECENT_TIMES * 2      # totals stay exact
        assert s.t_initialization == 0.001

    def test_server_report_has_trace_section(self, graph):
        eng = DecoupledEngine(graph, _cfg(graph), config=ServingConfig(
            batch_size=C, num_threads=2, trace=TraceConfig()))
        srv = GNNServer(eng, max_wait_s=0.01)
        srv.start()
        reqs = [srv.submit(i) for i in range(8)]
        srv.drain(reqs, timeout=120)
        srv.stop()
        rep = srv.report()
        assert rep["schema_version"] == SCHEMA_VERSION
        lane = rep["models"]["default"]
        assert lane["trace"]["enabled"]
        assert lane["trace"]["tickets_traced"] >= 1
        assert lane["latency"]["hist"]["count"] == 8
        for key in lane["latency"]:
            assert key in SCHEMA["latency"]
        eng.close()
