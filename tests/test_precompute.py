"""Offline precompute tier + hybrid serving: the tier's answers must
equal the online path's (layer-major propagation == per-batch subgraph
propagation under full coverage), edge updates must demote exactly the
dependency ball, refreshed rows must equal a fresh offline build, mixed
batches must split and rejoin correctly, and the artifact must refuse to
load against a mutated deployment."""
import numpy as np
import pytest

from repro.core.config import ServingConfig
from repro.core.engine import DecoupledEngine
from repro.core.program import lower, specialize
from repro.core.report_schema import SCHEMA, SCHEMA_VERSION
from repro.gnn.model import GNNConfig, init_gnn
from repro.graphs.synthetic import DatasetSpec, make_graph
from repro.precompute import (EmbeddingTier, PrecomputeArtifactError,
                              PrecomputeConfig, PrecomputeError,
                              agg_hops)

SPEC = DatasetSpec("tiny", 64, 4.0, 16, 4)
V = 64
C = 8
TARGETS = np.arange(24)


def _graph(seed=0):
    return make_graph(SPEC, seed=seed)


def _cfg(kind="sgc", n_layers=2):
    # receptive_field = V + tiny ppr_eps: the online subgraph is the
    # FULL graph, so online and offline compute the same function
    return GNNConfig(kind=kind, n_layers=n_layers, receptive_field=V,
                     f_in=SPEC.feature_dim, f_hidden=32, ppr_eps=1e-9,
                     readout="target")


def _sc(**kw):
    kw.setdefault("batch_size", C)
    kw.setdefault("e_pad", 8192)
    kw.setdefault("num_threads", 1)
    return ServingConfig(**kw)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("kind", ["sgc", "appnp"])
def test_tier_equals_online(kind, impl):
    g = _graph()
    cfg = _cfg(kind)
    with DecoupledEngine(g, cfg, config=_sc(impl=impl)) as online, \
            DecoupledEngine(g, cfg, config=_sc(
                impl=impl, precompute=PrecomputeConfig())) as hybrid:
        a = online.infer(TARGETS).embeddings
        b = hybrid.infer(TARGETS).embeddings
        rep = hybrid.precompute_report()
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
    assert rep["hits"] == len(TARGETS) and rep["misses"] == 0


def test_tier_equals_online_forced_sg():
    g = _graph()
    cfg = _cfg("sgc")
    with DecoupledEngine(g, cfg, config=_sc(mode="sg")) as online, \
            DecoupledEngine(g, cfg, config=_sc(
                mode="sg", precompute=PrecomputeConfig())) as hybrid:
        np.testing.assert_allclose(online.infer(TARGETS).embeddings,
                                   hybrid.infer(TARGETS).embeddings,
                                   rtol=1e-4, atol=1e-5)


def test_demotes_exact_dependency_ball():
    g = _graph(seed=3)
    cfg = _cfg("gcn", n_layers=2)
    sc = _sc(precompute=PrecomputeConfig(auto_refresh=False))
    with DecoupledEngine(g, cfg, config=sc) as eng:
        hops = agg_hops(eng.program)
        assert hops == 2            # one Aggregate per executed layer
        v0 = 11
        eng.precompute.on_invalidate([v0])
        # expected ball: BFS within `hops` over the (symmetric) edges
        ball, frontier = {v0}, {v0}
        for _ in range(hops):
            nxt = set()
            for u in frontier:
                nxt.update(g.indices[g.indptr[u]:g.indptr[u + 1]].tolist())
            frontier = nxt - ball
            ball |= nxt
        _, fresh = eng.precompute.tier.lookup(np.arange(V))
        assert set(np.flatnonzero(~fresh).tolist()) == ball


def test_post_refresh_equals_fresh_build():
    g = _graph(seed=4)
    cfg = _cfg("sgc")
    params = init_gnn(cfg, __import__("jax").random.PRNGKey(0))
    sc = _sc(precompute=PrecomputeConfig(auto_refresh=False))
    with DecoupledEngine(g, cfg, params=params, config=sc) as eng:
        g.apply_edge_updates(insert=[(5, 9), (2, 40)])
        assert eng.precompute_report()["demotions"] > 0
        eng.precompute.drain()
        rep = eng.precompute_report()
        assert rep["refresh_backlog"] == 0 and rep["fresh"] == V
        got = eng.infer(TARGETS).embeddings
        with DecoupledEngine(g, cfg, params=params, config=_sc(
                precompute=PrecomputeConfig())) as fresh:
            want = fresh.infer(TARGETS).embeddings
    np.testing.assert_allclose(want, got, rtol=1e-4, atol=1e-5)


def test_mixed_batch_splits_and_rejoins():
    g = _graph(seed=5)
    cfg = _cfg("sgc")
    params = init_gnn(cfg, __import__("jax").random.PRNGKey(0))
    sc = _sc(precompute=PrecomputeConfig(auto_refresh=False))
    with DecoupledEngine(g, cfg, params=params, config=sc) as hybrid, \
            DecoupledEngine(g, cfg, params=params,
                            config=_sc()) as online:
        hybrid.precompute.on_invalidate([7])
        got = hybrid.infer(TARGETS).embeddings
        want = online.infer(TARGETS).embeddings
        rep = hybrid.precompute_report()
    np.testing.assert_allclose(want, got, rtol=1e-4, atol=1e-5)
    # genuinely mixed traffic: both routes ran
    assert rep["hits"] > 0 and rep["misses"] > 0


def test_all_fresh_plan_short_circuits_pipeline():
    g = _graph()
    cfg = _cfg("sgc")
    with DecoupledEngine(g, cfg, config=_sc(
            precompute=PrecomputeConfig())) as eng:
        plan = eng.plan(np.arange(C))
        assert plan.tier_done
        assert plan.tier_rows is not None and plan.tier_fresh.all()
        # Select/Build/Pack all passed through untouched
        assert plan.node_lists is None and plan.rows is None \
            and plan.device is None
        out = np.asarray(eng.run_device(plan))
        np.testing.assert_array_equal(out, plan.tier_rows)


def test_budget_bytes_caps_residency():
    g = _graph(seed=6)
    cfg = _cfg("sgc")
    params = init_gnn(cfg, __import__("jax").random.PRNGKey(0))
    budget = 16 * 32 * 4                   # room for 16 of 64 rows
    with DecoupledEngine(g, cfg, params=params, config=_sc(
            precompute=PrecomputeConfig(budget_bytes=budget))) as eng, \
            DecoupledEngine(g, cfg, params=params,
                            config=_sc()) as online:
        rep = eng.precompute_report()
        assert rep["resident"] == 16 and rep["tier_bytes"] <= budget
        # non-resident vertices are served by the online path, exactly
        np.testing.assert_allclose(online.infer(TARGETS).embeddings,
                                   eng.infer(TARGETS).embeddings,
                                   rtol=1e-4, atol=1e-5)
        assert eng.precompute_report()["misses"] > 0


def test_models_filter_and_unsupported_kind():
    g = _graph()
    # excluded kind: engine runs pure online, no tier
    with DecoupledEngine(g, _cfg("sgc"), config=_sc(
            precompute=PrecomputeConfig(models=("appnp",)))) as eng:
        assert eng.precompute is None
        assert eng.precompute_report() == {"enabled": False}
    # unsupported program shapes raise actionable errors
    gat = GNNConfig(kind="gat", n_layers=2, receptive_field=V,
                    f_in=SPEC.feature_dim, f_hidden=32, readout="target")
    with pytest.raises(PrecomputeError, match="not precomputable"):
        DecoupledEngine(g, gat, config=_sc(
            precompute=PrecomputeConfig()))
    maxout = GNNConfig(kind="sgc", n_layers=2, receptive_field=V,
                       f_in=SPEC.feature_dim, f_hidden=32, readout="max")
    with pytest.raises(PrecomputeError, match="readout"):
        DecoupledEngine(g, maxout, config=_sc(
            precompute=PrecomputeConfig()))


def test_artifact_roundtrip_and_stale_rejection(tmp_path):
    from repro.graphs.synthetic import get_graph
    from repro.precompute import build

    out = str(tmp_path / "tier")
    rc = build.main(["--dataset", "flickr", "--scale", "0.001",
                     "--kind", "sgc", "--layers", "2", "--hidden", "32",
                     "--rf", "32", "--out", out])
    assert rc == 0
    g = get_graph("flickr", scale=0.001, seed=0)
    cfg = GNNConfig(kind="sgc", n_layers=2, receptive_field=32,
                    f_in=g.feature_dim, f_hidden=32, readout="target")
    art = _sc(precompute=PrecomputeConfig(artifact=out))
    t = np.arange(16)
    with DecoupledEngine(g, cfg, config=art) as loaded, \
            DecoupledEngine(g, cfg, config=_sc(
                precompute=PrecomputeConfig())) as built:
        assert loaded.precompute_report()["builds"] == 0
        assert built.precompute_report()["builds"] == 1
        np.testing.assert_array_equal(loaded.infer(t).embeddings,
                                      built.infer(t).embeddings)
    # mutate the graph: the stamped artifact must refuse to load, with a
    # rebuild instruction in the message
    g2 = make_graph(SPEC, seed=0)
    cfg2 = GNNConfig(kind="sgc", n_layers=2, receptive_field=32,
                     f_in=SPEC.feature_dim, f_hidden=32, readout="target")
    with pytest.raises(PrecomputeArtifactError, match="rebuild"):
        DecoupledEngine(g2, cfg2, config=art)


def test_tier_lookup_and_epoch_guard():
    tier = EmbeddingTier(8, 4)
    rows = np.arange(32, dtype=np.float32).reshape(8, 4)
    tier.install(np.arange(8), rows)
    got, fresh = tier.lookup(np.array([1, 5]))
    assert fresh.all()
    np.testing.assert_array_equal(got, rows[[1, 5]])
    # a demote between epoch snapshot and promote wins the race
    epochs = tier.epoch_of(np.array([2, 3]))
    tier.demote(np.array([3]))
    tier.promote(np.array([2, 3]), np.zeros((2, 4), np.float32), epochs)
    _, fresh = tier.lookup(np.array([2, 3]))
    assert fresh[0] and not fresh[1]


def test_calibration_lookup_and_measured_specialize():
    from repro.obs.calib import CalibrationTable

    t = CalibrationTable()
    assert t.lookup("Aggregate", "xla/dense") is None
    for _ in range(8):
        t.record("Aggregate", "xla/dense", 5, 4e-3)
        t.record("Aggregate", "xla/sg", 5, 1e-3)
    assert t.lookup("Aggregate", "xla/sg", 5) < \
        t.lookup("Aggregate", "xla/dense", 5)
    assert t.lookup("Aggregate", "xla/sg") is not None   # best bucket
    cfg = GNNConfig(kind="gcn", n_layers=2, receptive_field=16,
                    f_in=8, f_hidden=16)
    # measured cells populated for both modes: they drive the mux
    _, dec = specialize(lower(cfg), n=16, avg_edges=4.0, f_in=8,
                        f_hidden=16, measured=t, measured_bucket=5)
    agg = [d for d in dec if d.mux]
    assert agg and all(d.mode == "sg" for d in agg)
    assert all("measured" in d.reason for d in agg)
    # an explicit force always beats the measured table
    _, dec = specialize(lower(cfg), n=16, avg_edges=4.0, f_in=8,
                        f_hidden=16, measured=t, measured_bucket=5,
                        force="dense")
    assert all(d.mode == "dense" for d in dec if d.mux)
    # half-populated cell (missing bucket): FLOP model fallback
    _, dec = specialize(lower(cfg), n=16, avg_edges=4.0, f_in=8,
                        f_hidden=16, measured=t, measured_bucket=9)
    assert all("measured" not in d.reason for d in dec if d.mux)


def test_report_schema_section():
    assert SCHEMA_VERSION >= 3    # precompute.* landed in v3
    g = _graph()
    with DecoupledEngine(g, _cfg("sgc"), config=_sc(
            precompute=PrecomputeConfig())) as eng:
        eng.infer(np.arange(C))
        rep = eng.precompute_report()
    assert rep["enabled"] is True
    assert set(rep) <= set(SCHEMA["precompute"])


def test_precompute_config_validation():
    with pytest.raises(ValueError):
        PrecomputeConfig(chunk_size=0)
    with pytest.raises(ValueError):
        PrecomputeConfig(refresh_workers=0)
    with pytest.raises(ValueError):
        PrecomputeConfig(budget_bytes=-1)
    with pytest.raises(TypeError, match="PrecomputeConfig"):
        ServingConfig(precompute=42)
    d = ServingConfig(precompute=PrecomputeConfig()).describe()
    assert "precompute" in d
