"""AckProgram IR: lowering registry, per-op mode dispatch, and executor
equivalence against the pre-IR paths (which are reconstructed here, from
the layer ops in gnn.layers and the Pallas kernel entry points, exactly as
engine/gnn_forward composed them before the IR)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dse import PlanViolation, explore, plan_covers
from repro.core.engine import DecoupledEngine
from repro.core.program import (AckProgram, Aggregate, AttentionScore,
                                AttentionSoftmax, Classify, Readout,
                                Residual, Transform, execute, lower,
                                lower_and_specialize, program_alu_ops,
                                register_lowering, registered_kinds,
                                required_adjacency, specialize)
from repro.core.subgraph import build_batch
from repro.gnn.layers import (LAYER_APPLY, gat_layer, init_gcn_layer,
                              readout)
from repro.gnn.model import GNNConfig, gnn_forward, init_gnn
from repro.graphs.csr import from_edge_list
from repro.graphs.synthetic import get_graph
from repro.kernels import ops as kops
from repro.serve.gnn_server import GNNServer

KINDS = ("gcn", "sage", "gin", "gat")
N = 32
E_PAD = N * (N - 1)


@pytest.fixture(scope="module")
def graph():
    return get_graph("flickr", scale=0.02, seed=1)   # ~1.8k vertices


@pytest.fixture(scope="module")
def batches(graph):
    """One padded device batch (dense + sg arrays) per kind-agnostic
    shape, plus per-kind params."""
    sb = build_batch(graph, [1, 5, 9, 13], N, e_pad=E_PAD, num_threads=1)
    out = {}
    for kind in KINDS:
        cfg = GNNConfig(kind=kind, n_layers=3, receptive_field=N,
                        f_in=graph.feature_dim)
        params = init_gnn(cfg, jax.random.PRNGKey(3))
        eng = DecoupledEngine(graph, cfg, params=params, batch_size=4,
                              mode="sg", e_pad=E_PAD)
        batch = eng.device_batch(sb)    # edge arrays + required adjacency
        # the legacy reference paths read BOTH adjacencies; the engine
        # now ships only what its program needs, so add the rest back
        batch.setdefault("adj", sb.adj)
        batch.setdefault("adj_mean", sb.adj_mean)
        eng.close()
        out[kind] = (cfg, params, batch)
    return out


# -- pre-IR reference implementations ---------------------------------------


def legacy_xla(cfg, params, batch, mode):
    def apply(p, h):
        if cfg.kind == "gat":
            return gat_layer(p, h, batch, mode)
        return LAYER_APPLY[cfg.kind](p, h, batch, mode)
    h = apply(params["layer0"], batch["feats"])
    if cfg.n_layers > 1:
        def body(hh, lp):
            return apply(lp, hh), None
        h, _ = jax.lax.scan(body, h, params["layers"])
    emb = readout(h, batch["mask"], cfg.readout)
    if cfg.num_classes:
        emb = emb @ params["cls_w"] + params["cls_b"]
    return emb


def legacy_pallas_dense(cfg, params, batch):
    """The engine's pre-IR _pallas_layer chain, verbatim."""
    def apply(p, h, b):
        adj, adj_mean, mask = b["adj"], b["adj_mean"], b["mask"]
        if cfg.kind == "gcn":
            return kops.fused_gnn_layer(adj, h, p["w"], None, p["b"],
                                        mask, act="relu")
        if cfg.kind == "sage":
            return kops.fused_gnn_layer(adj_mean, h, p["w_neigh"],
                                        p["w_self"], p["b"], mask,
                                        act="relu")
        if cfg.kind == "gin":
            n = h.shape[1]
            a_gin = jnp.sign(adj_mean) + \
                (1.0 + p["eps"]) * jnp.eye(n, dtype=h.dtype)
            hid = kops.fused_gnn_layer(a_gin, h, p["w1"], None, p["b1"],
                                       mask, act="relu")
            return kops.fused_gnn_layer(adj, hid, None, p["w2"], p["b2"],
                                        mask, act="relu")
        nh = cfg.n_heads
        z = kops.fused_gnn_layer(adj, h, None, p["w"], None, mask,
                                 act="none")
        s_src = jnp.einsum("cnhf,hf->cnh",
                           z.reshape(*z.shape[:2], nh, -1), p["a_src"])
        s_dst = jnp.einsum("cnhf,hf->cnh",
                           z.reshape(*z.shape[:2], nh, -1), p["a_dst"])
        n = h.shape[1]
        struct = (jnp.sign(adj_mean) + jnp.eye(n, dtype=h.dtype)) \
            * mask[:, None, :]
        out = kops.gat_attention(z, s_src, s_dst, struct, n_heads=nh)
        return jax.nn.elu(out + p["b"]) * mask[..., None]

    h = apply(params["layer0"], batch["feats"], batch)
    if cfg.n_layers > 1:
        def body(hh, lp):
            return apply(lp, hh, batch), None
        h, _ = jax.lax.scan(body, h, params["layers"])
    return readout(h, batch["mask"], cfg.readout)


def run_program(cfg, params, batch, force, impl):
    prog, dec = lower_and_specialize(cfg, force=force)
    emb, _ = execute(prog, params, batch, impl=impl)
    return np.asarray(emb), dec


# -- lowering table ----------------------------------------------------------


class TestLowering:
    def test_builtin_kinds_registered(self):
        assert set(KINDS) <= set(registered_kinds())

    @pytest.mark.parametrize("kind,expect", [
        ("gcn", [Aggregate, Transform]),
        ("sage", [Aggregate, Transform]),
        ("gin", [Aggregate, Residual, Transform, Transform]),
        ("gat", [Transform, AttentionScore, AttentionSoftmax]),
    ])
    def test_layer_templates(self, kind, expect):
        cfg = GNNConfig(kind=kind, n_layers=2, receptive_field=N, f_in=8)
        prog = lower(cfg)
        assert [type(op) for op in prog.layer0] == expect
        assert prog.layer0 == prog.inner
        assert isinstance(prog.tail[0], Readout)

    def test_classify_tail_and_alu_ops(self):
        cfg = GNNConfig(kind="gcn", n_layers=2, receptive_field=N,
                        f_in=8, num_classes=7)
        prog = lower(cfg)
        assert isinstance(prog.tail[-1], Classify)
        assert "matmul" in program_alu_ops(cfg)

    def test_required_adjacency(self):
        mk = lambda k: lower(GNNConfig(kind=k, n_layers=2,
                                       receptive_field=N, f_in=8))
        assert required_adjacency(mk("gcn")) == ("adj",)
        assert required_adjacency(mk("sage")) == ("adj_mean",)
        assert required_adjacency(mk("gat")) == ("adj_mean",)

    def test_unknown_kind_actionable(self):
        with pytest.raises(KeyError, match="register_lowering"):
            lower(GNNConfig(kind="nope", n_layers=2, receptive_field=N,
                            f_in=8))

    def test_execute_rejects_unspecialized(self, batches):
        cfg, params, batch = batches["gcn"]
        with pytest.raises(ValueError, match="specialize"):
            execute(lower(cfg), params, batch)


# -- executor equivalence vs the pre-IR paths -------------------------------


class TestExecutorEquivalence:
    @pytest.mark.parametrize("kind", KINDS)
    def test_xla_dense_bitwise(self, kind, batches):
        cfg, params, batch = batches[kind]
        got, dec = run_program(cfg, params, batch, "dense", "xla")
        want = np.asarray(legacy_xla(cfg, params, batch, "dense"))
        np.testing.assert_array_equal(got, want)
        assert dec.mode == "dense" and dec.n_sg == 0

    @pytest.mark.parametrize("kind", KINDS)
    def test_xla_sg_matches(self, kind, batches):
        cfg, params, batch = batches[kind]
        got, dec = run_program(cfg, params, batch, "sg", "xla")
        want = np.asarray(legacy_xla(cfg, params, batch, "sg"))
        # identical segment-op composition -> bitwise here too
        np.testing.assert_array_equal(got, want)
        # transforms stay systolic: the "sg" program is heterogeneous
        assert dec.mode == "sg"
        assert dec.n_dense > 0 and dec.n_sg > 0

    @pytest.mark.parametrize("kind", KINDS)
    def test_pallas_dense_bitwise(self, kind, batches):
        cfg, params, batch = batches[kind]
        got, _ = run_program(cfg, params, batch, "dense", "pallas")
        want = np.asarray(legacy_pallas_dense(cfg, params, batch))
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("kind", KINDS)
    def test_pallas_sg_allclose(self, kind, batches):
        """Pre-IR engines fell back to XLA for sg; the executor now runs
        the Pallas scatter-gather kernel — same math, different kernel."""
        cfg, params, batch = batches[kind]
        got, _ = run_program(cfg, params, batch, "sg", "pallas")
        want = np.asarray(legacy_xla(cfg, params, batch, "sg"))
        scale = np.abs(want).max()
        np.testing.assert_allclose(got / scale, want / scale,
                                   rtol=2e-4, atol=1e-5)

    @pytest.mark.parametrize("impl", ["xla", "pallas"])
    @pytest.mark.parametrize("kind", KINDS)
    def test_mixed_per_op(self, kind, impl, batches):
        """Force ONLY the aggregation-family ops to sg: the compiled
        program then mixes sg aggregation with dense transforms (the
        paper's per-kernel mux) and still matches the reference."""
        cfg, params, batch = batches[kind]
        force = {"Aggregate": "sg", "AttentionSoftmax": "sg"}
        got, dec = run_program(cfg, params, batch, force, impl)
        want = np.asarray(legacy_xla(cfg, params, batch, "sg"))
        scale = np.abs(want).max()
        np.testing.assert_allclose(got / scale, want / scale,
                                   rtol=2e-4, atol=1e-5)
        assert dec.n_sg > 0 and dec.n_dense > 0
        assert set(dec.modes) == {"dense", "sg"}

    def test_gnn_forward_is_program_backed(self, batches):
        cfg, params, batch = batches["gcn"]
        emb, h = gnn_forward(cfg, params, batch, mode="dense")
        got, _ = run_program(cfg, params, batch, "dense", "xla")
        np.testing.assert_array_equal(np.asarray(emb), got)
        assert h.shape == batch["feats"].shape[:2] + (cfg.f_hidden,)


# -- per-op auto dispatch ----------------------------------------------------


def sparse_graph(v=400, edges=40, f=16, seed=0):
    rng = np.random.default_rng(seed)
    src = rng.choice(v, edges, replace=False)
    dst = (src + 1) % v
    feats = rng.standard_normal((v, f)).astype(np.float32)
    return from_edge_list(src, dst, v, feats), src.astype(np.int64)


class TestPerOpAutoDispatch:
    def test_auto_program_mixes_modes_on_sparse_graph(self):
        """The acceptance shape: an auto-specialized program holding BOTH
        an sg op (aggregation over an ultra-sparse neighborhood) and
        dense ops (the wide transforms) in one compiled datapath."""
        g, hot = sparse_graph()
        cfg = GNNConfig(kind="gcn", n_layers=2, receptive_field=N,
                        f_in=g.feature_dim, f_hidden=256)
        with DecoupledEngine(g, cfg, batch_size=4, mode="auto") as eng:
            assert eng.needs_edges
            modes = {d.mode for d in eng.decision}
            assert modes == {"dense", "sg"}
            agg = [d for d in eng.decision if d.op.startswith("Aggregate")]
            assert all(d.mode == "sg" for d in agg)
            tfs = [d for d in eng.decision if d.op.startswith("Transform")]
            assert all(d.mode == "dense" for d in tfs)
            auto = eng.infer(hot[:4], overlap=False)
        with DecoupledEngine(g, cfg, params=None, batch_size=4, seed=0,
                             mode="dense") as dense_eng:
            ref = dense_eng.infer(hot[:4], overlap=False)
        np.testing.assert_allclose(auto.embeddings, ref.embeddings,
                                   rtol=1e-4, atol=1e-5)

    def test_dense_graph_stays_dense(self, graph):
        cfg = GNNConfig(kind="gcn", n_layers=2, receptive_field=N,
                        f_in=graph.feature_dim)
        with DecoupledEngine(graph, cfg, batch_size=4) as eng:
            assert eng.mode == "dense" and not eng.needs_edges
            assert all(d.mode == "dense" for d in eng.decision)

    def test_decision_reason_reports_compared_quantities(self):
        from repro.core.ack import choose_mode
        d = choose_mode(128, avg_edges=2000.0, f=256)
        assert d.reason == "N=128 vs 2E=4000"

    def test_inference_result_carries_per_op_decisions(self, graph):
        cfg = GNNConfig(kind="sage", n_layers=2, receptive_field=N,
                        f_in=graph.feature_dim)
        with DecoupledEngine(graph, cfg, batch_size=4) as eng:
            res = eng.infer(np.arange(4), overlap=False)
        assert len(res.decision) == len(lower(cfg).ops)
        assert "dense" in res.decision.summary
        sites = [d.site for d in res.decision]
        assert "layer0[0]" in sites and "tail[0]" in sites


# -- runtime registry: a custom kind serves with zero core edits -------------


@register_lowering(
    "toygcn",
    layer_init=lambda cfg, key, fi, fo: init_gcn_layer(key, fi, fo))
def lower_toygcn(cfg):
    layer = (Aggregate(norm="mean"),
             Transform(w="w", b="b", act="relu"))
    tail = (Readout(kind=cfg.readout),)
    return AckProgram(kind=cfg.kind, layer0=layer, inner=layer,
                      tail=tail, n_layers=cfg.n_layers)


class TestRuntimeRegistry:
    def test_custom_kind_serves_through_shared_plan(self, graph):
        cfg = GNNConfig(kind="toygcn", n_layers=2, receptive_field=N,
                        f_in=graph.feature_dim)
        base = GNNConfig(kind="gcn", n_layers=2, receptive_field=N,
                         f_in=graph.feature_dim)
        toy = DecoupledEngine(graph, cfg, batch_size=4)
        ref = DecoupledEngine(graph, base, batch_size=4)
        srv = GNNServer(max_wait_s=0.01)
        srv.register("toygcn", toy)
        srv.register("gcn", ref)            # one shared plan covers both
        assert plan_covers(srv.plan, cfg) == []
        srv.start()
        reqs = [srv.submit(i, model="toygcn") for i in range(6)]
        srv.drain(reqs, timeout=120)
        srv.stop()
        want = toy.infer(np.arange(6), overlap=False).embeddings
        got = np.stack([r.embedding for r in reqs])
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
        rep = srv.report()["models"]["toygcn"]
        assert rep["ack"]["mode"] == "dense"
        toy.close()
        ref.close()

    def test_unregistered_kind_rejected_with_actionable_message(self,
                                                                graph):
        plan = explore([GNNConfig(kind="gcn", n_layers=2,
                                  receptive_field=N, f_in=8)])
        bad = GNNConfig(kind="notakind", n_layers=2, receptive_field=N,
                        f_in=8)
        reasons = plan_covers(plan, bad)
        assert reasons and "register_lowering" in reasons[0]
        with pytest.raises(PlanViolation, match="notakind"):
            from repro.core.dse import validate_models
            validate_models(plan, [bad])

    def test_explore_covers_custom_kind(self, graph):
        cfg = GNNConfig(kind="toygcn", n_layers=2, receptive_field=N,
                        f_in=graph.feature_dim)
        plan = explore([cfg])
        assert plan.ops_ok and plan_covers(plan, cfg) == []


# -- specialize API ----------------------------------------------------------


class TestSpecialize:
    def test_force_dict_by_site(self):
        cfg = GNNConfig(kind="gcn", n_layers=3, receptive_field=N, f_in=8)
        prog, dec = specialize(lower(cfg), n=N, avg_edges=4.0,
                               f_in=8, f_hidden=cfg.f_hidden,
                               force={"layer0[0]": "dense",
                                      "inner[0]": "sg"})
        by_site = {d.site: d.mode for d in dec}
        assert by_site["layer0[0]"] == "dense"
        assert by_site["inner[0]"] == "sg"
        assert dec.mode == "mixed"

    def test_lru_lowering_cache_returns_same_program(self):
        cfg = GNNConfig(kind="gcn", n_layers=2, receptive_field=N, f_in=8)
        assert lower(cfg) is lower(cfg)

    def test_one_layer_program_reports_only_executed_ops(self):
        cfg = GNNConfig(kind="gcn", n_layers=1, receptive_field=N, f_in=8)
        prog = lower(cfg)
        assert not any(s.startswith("inner") for s, _ in prog.ops)
        sprog, dec = specialize(prog, n=N, avg_edges=100.0, f_in=8,
                                f_hidden=cfg.f_hidden)
        assert all(not d.site.startswith("inner") for d in dec)
        assert sprog.specialized

    def test_input_width_params_per_kind(self):
        """The engine's Pallas row-padding set is read off the program,
        not a hand-kept weight-name tuple."""
        from repro.core.program import input_width_params
        mk = lambda k: lower(GNNConfig(kind=k, n_layers=2,
                                       receptive_field=N, f_in=8))
        assert input_width_params(mk("gcn")) == ("w",)
        assert set(input_width_params(mk("sage"))) == {"w_neigh",
                                                       "w_self"}
        assert input_width_params(mk("gin")) == ("w1",)
        assert input_width_params(mk("gat")) == ("w",)

    def test_identity_layer_lowering_rejected(self):
        from repro.gnn.layers import init_gcn_layer

        @register_lowering("idkind",
                           layer_init=lambda c, k, fi, fo:
                           init_gcn_layer(k, fi, fo))
        def lower_idkind(cfg):
            lay = (Aggregate(norm="mean"),
                   Transform(w="w", b="b", out="z2"))   # never writes "h"
            return AckProgram(cfg.kind, lay, lay, (Readout(),),
                              cfg.n_layers)

        with pytest.raises(ValueError, match="identity"):
            lower(GNNConfig(kind="idkind", n_layers=2,
                            receptive_field=N, f_in=8))


# -- APPNP: propagation-only layer template ----------------------------------


class TestAPPNP:
    """APPNP stress-tests the op vocabulary: the inner section is
    propagation-ONLY (Aggregate + teleport Residual, no Transform)."""

    def _cfg(self, graph, n_layers=4):
        return GNNConfig(kind="appnp", n_layers=n_layers,
                         receptive_field=N, f_in=graph.feature_dim)

    def test_registered_and_propagation_only_inner(self, graph):
        assert "appnp" in registered_kinds()
        prog = lower(self._cfg(graph))
        assert not any(isinstance(op, Transform) for op in prog.inner)
        assert any(isinstance(op, Aggregate) for op in prog.inner)
        # layer0's MLP weight is the one the engine must row-pad
        from repro.core.program import input_width_params
        assert input_width_params(prog) == ("w",)

    def test_matches_true_appnp_power_iteration(self, graph):
        """Executor output == the ACTUAL APPNP recurrence: h0 = relu(X W
        + b) masked, then K-1 steps of z = (1-a) A_hat z + a h0 (teleport
        anchored at the layer-0 prediction, NOT the previous iterate),
        then max readout."""
        cfg = self._cfg(graph)
        a = cfg.ppr_alpha
        eng = DecoupledEngine(graph, cfg, batch_size=4)
        targets = np.arange(4)
        got = eng.infer(targets, overlap=False).embeddings
        sb = build_batch(graph, targets, N, e_pad=eng.e_pad,
                         num_threads=1)
        p = eng.params
        h0 = np.maximum(sb.feats @ np.asarray(p["layer0"]["w"])
                        + np.asarray(p["layer0"]["b"]), 0.0)
        h0 = h0 * sb.mask[..., None]
        # init pins 1 + teleport == alpha (teleport stays learnable)
        np.testing.assert_allclose(
            1.0 + np.asarray(p["layers"]["teleport"]), a, rtol=1e-6)
        z = h0
        for _ in range(cfg.n_layers - 1):
            z = (1 - a) * np.einsum("cij,cjf->cif", sb.adj, z) + a * h0
        want = np.where(sb.mask[..., None] > 0, z, -1e30).max(axis=1)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
        eng.close()

    def test_inner_aggregate_gets_own_mode_mux(self, graph):
        cfg = self._cfg(graph)
        _, dec = lower_and_specialize(cfg, force={"inner[0]": "sg"})
        by_site = {d.site: d.mode for d in dec}
        assert by_site["inner[0]"] == "sg"       # propagation goes sg
        assert by_site["layer0[0]"] == "dense"   # the MLP stays systolic

    def test_serves_under_shared_dse_plan(self, graph):
        """One DSEPlan admits gcn + appnp; both serve concurrently."""
        cfg = self._cfg(graph, n_layers=3)
        base = GNNConfig(kind="gcn", n_layers=3, receptive_field=N,
                         f_in=graph.feature_dim)
        appnp = DecoupledEngine(graph, cfg, batch_size=4)
        ref = DecoupledEngine(graph, base, batch_size=4)
        srv = GNNServer(max_wait_s=0.01)
        srv.register("appnp", appnp)
        srv.register("gcn", ref)
        assert plan_covers(srv.plan, cfg) == []
        srv.start()
        reqs = [srv.submit(i, model="appnp") for i in range(6)]
        reqs += [srv.submit(i, model="gcn") for i in range(4)]
        srv.drain(reqs, timeout=120)
        srv.stop()
        want = appnp.infer(np.arange(6), overlap=False).embeddings
        got = np.stack([r.embedding for r in reqs[:6]])
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
        ops = srv.report()["models"]["appnp"]["ack"]["ops"]
        assert any(o["op"].startswith("Aggregate") for o in ops)
        assert sum(o["op"].startswith("Transform") for o in ops) == 1
        appnp.close()
        ref.close()
