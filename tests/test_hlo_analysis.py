"""The roofline harness's HLO walker: trip-count multipliers, dot-FLOP
parsing, collective accounting — validated against cost_analysis and
analytic counts (the probe findings, frozen as regression tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import (analyze, multipliers,
                                       parse_module)


def _scan_matmul(L, M, K, N):
    def f(w, x):
        def body(h, wl):
            return jnp.dot(h, wl), None
        h, _ = jax.lax.scan(body, x, w)
        return h
    return jax.jit(f).lower(
        jax.ShapeDtypeStruct((L, K, N), jnp.float32),
        jax.ShapeDtypeStruct((M, K), jnp.float32)).compile()


def _cost(compiled) -> dict:
    """Normalize Compiled.cost_analysis() across jax versions (older
    releases return a one-per-device list of dicts)."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


class TestTripCountCorrection:
    @pytest.mark.parametrize("L", [2, 5, 9])
    def test_scan_flops_multiplied(self, L):
        M = K = N = 32
        compiled = _scan_matmul(L, M, K, N)
        s = analyze(compiled.as_text())
        analytic = 2.0 * L * M * K * N
        # dot flops exact; allow small epsilon for stray tiny dots
        assert abs(s.flops - analytic) / analytic < 0.01, (s.flops,
                                                           analytic)
        assert L in s.trip_counts

    def test_cost_analysis_undercounts_scans(self):
        """The reason the walker exists: XLA counts the body once."""
        L, M = 8, 32
        compiled = _scan_matmul(L, M, M, M)
        ca_flops = _cost(compiled)["flops"]
        analytic = 2.0 * L * M ** 3
        assert ca_flops < 0.3 * analytic            # ~1/L of the truth
        assert abs(analyze(compiled.as_text()).flops - analytic) \
            / analytic < 0.01

    def test_no_scan_matches_cost_analysis(self):
        """At multiplier 1 the walker agrees with XLA's own count."""
        compiled = jax.jit(lambda a, b: a @ b).lower(
            jax.ShapeDtypeStruct((64, 128), jnp.float32),
            jax.ShapeDtypeStruct((128, 32), jnp.float32)).compile()
        s = analyze(compiled.as_text())
        ca = _cost(compiled)["flops"]
        np.testing.assert_allclose(s.flops, ca, rtol=0.01)


class TestParser:
    SNIPPET = """\
HloModule test

%wide.body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %g = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %ar = f32[8,8]{1,0} all-reduce(%g), replica_groups=[2,4]<=[8], to_apply=%add
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%g, %ar)
}

%wide.cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  ROOT %lt = pred[] compare(%p, %p), direction=LT
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8]{1,0} parameter(0)
  %ag = f32[8,32]{1,0} all-gather(%x), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={1}
  %tup = (s32[], f32[8,8]{1,0}) tuple(%x, %x)
  %w = (s32[], f32[8,8]{1,0}) while(%tup), condition=%wide.cond, body=%wide.body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %r = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""

    def test_canned_module(self):
        s = analyze(self.SNIPPET, n_devices=8)
        # all-gather once at entry: 8*32*4 bytes result
        assert s.per_collective["all-gather"] == 8 * 32 * 4
        # all-reduce inside a trip-7 while: 7 * 8*8*4
        assert s.per_collective["all-reduce"] == 7 * 8 * 8 * 4
        assert s.trip_counts == [7]
        # ring factors: AG group of 4 -> 3/4; AR group of 4 -> 2 * 3/4
        expect_link = (8 * 32 * 4) * 3 / 4 + 7 * (8 * 8 * 4) * 2 * 3 / 4
        np.testing.assert_allclose(s.collective_link_bytes, expect_link)

    def test_multiplier_propagation(self):
        comps = parse_module(self.SNIPPET)
        m = multipliers(comps)
        assert m["main"] == 1.0
        assert m["wide.body"] == 7.0
        assert m["wide.cond"] == 8.0            # trips + 1 evaluations
