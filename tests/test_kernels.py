"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp ref.py oracles
(interpret mode on CPU; same code paths compile for TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.fused_gnn import fused_gnn_layer
from repro.kernels.gat_attention import gat_attention
from repro.kernels.scatter_gather import scatter_gather_aggregate

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _rand_subgraph(key, c, n, f, dtype, edge_frac=0.2):
    ks = jax.random.split(key, 4)
    h = jax.random.normal(ks[0], (c, n, f)).astype(dtype)
    adj = jax.random.uniform(ks[1], (c, n, n))
    adj = jnp.where(adj < edge_frac, adj, 0.0).astype(jnp.float32)
    k_valid = jax.random.randint(ks[2], (c,), n // 2, n + 1)
    mask = (jnp.arange(n)[None, :] < k_valid[:, None]).astype(jnp.float32)
    adj = adj * mask[:, :, None] * mask[:, None, :]
    h = h * mask[..., None].astype(dtype)
    return h, adj, mask


class TestFusedGNN:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("c,n,f_in,f_out", [
        (1, 8, 16, 16), (2, 64, 128, 256), (3, 128, 512, 256),
        (2, 256, 256, 512), (1, 64, 500, 256),  # unaligned f_in
    ])
    def test_matches_ref(self, c, n, f_in, f_out, dtype):
        key = jax.random.PRNGKey(n * f_in + f_out)
        h, adj, mask = _rand_subgraph(key, c, n, f_in, dtype)
        ks = jax.random.split(key, 3)
        wn = jax.random.normal(ks[0], (f_in, f_out)).astype(dtype) * 0.1
        ws = jax.random.normal(ks[1], (f_in, f_out)).astype(dtype) * 0.1
        b = jax.random.normal(ks[2], (f_out,)).astype(dtype) * 0.1
        for w_self in (None, ws):
            got = fused_gnn_layer(adj, h, wn, w_self, b, mask, act="relu",
                                  interpret=True)
            want = ref.fused_gnn_layer_ref(adj, h, wn, w_self, b, mask,
                                           act="relu")
            np.testing.assert_allclose(
                np.asarray(got, np.float32), np.asarray(want, np.float32),
                **TOL[dtype])

    def test_self_only_is_plain_matmul(self):
        """W_self-only = dense FT kernel (GIN layer 2 path)."""
        key = jax.random.PRNGKey(0)
        h, adj, mask = _rand_subgraph(key, 2, 32, 64, jnp.float32)
        ws = jax.random.normal(key, (64, 128)) * 0.1
        got = fused_gnn_layer(adj, h, None, ws, None, mask, act="none",
                              interpret=True)
        want = jnp.einsum("cnf,fg->cng", h, ws) * mask[..., None]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("block_f", [128, 256])
    def test_block_width_invariance(self, block_f):
        key = jax.random.PRNGKey(3)
        h, adj, mask = _rand_subgraph(key, 2, 64, 128, jnp.float32)
        w = jax.random.normal(key, (128, 512)) * 0.1
        got = fused_gnn_layer(adj, h, w, None, None, mask,
                              block_f=block_f, interpret=True)
        want = ref.fused_gnn_layer_ref(adj, h, w, None, None, mask)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


class TestScatterGather:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("c,n,f,e", [
        (1, 8, 16, 24), (2, 64, 128, 300), (2, 128, 256, 1000),
        (1, 256, 512, 130),  # e < block
    ])
    def test_matches_ref(self, c, n, f, e, dtype):
        key = jax.random.PRNGKey(e)
        ks = jax.random.split(key, 4)
        src = jax.random.randint(ks[0], (c, e), 0, n).astype(jnp.int32)
        dst = jax.random.randint(ks[1], (c, e), 0, n).astype(jnp.int32)
        w = jax.random.normal(ks[2], (c, e))
        # zero out a padding tail like real batches have
        w = jnp.where(jnp.arange(e)[None, :] < e - 7, w, 0.0)
        h = jax.random.normal(ks[3], (c, n, f)).astype(dtype)
        got = scatter_gather_aggregate(src, dst, w, h, interpret=True)
        want = ref.scatter_gather_aggregate_ref(src, dst, w, h)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **TOL[dtype])

    def test_accumulation_raw_hazard(self):
        """Many edges hitting ONE destination accumulate exactly (the
        paper's RAW-hazard case, resolved here by matmul reduction)."""
        c, n, f, e = 1, 16, 32, 64
        src = jnp.zeros((c, e), jnp.int32)
        dst = jnp.full((c, e), 3, jnp.int32)
        w = jnp.ones((c, e))
        h = jnp.ones((c, n, f))
        got = scatter_gather_aggregate(src, dst, w, h, interpret=True)
        assert float(got[0, 3, 0]) == e
        assert float(jnp.abs(got[0, :3]).sum()) == 0.0


class TestGATAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("c,n,f,heads", [
        (1, 8, 16, 1), (2, 64, 256, 4), (2, 128, 256, 8), (1, 256, 512, 4),
    ])
    def test_matches_ref(self, c, n, f, heads, dtype):
        key = jax.random.PRNGKey(n + heads)
        ks = jax.random.split(key, 4)
        z = jax.random.normal(ks[0], (c, n, f)).astype(dtype)
        s_src = jax.random.normal(ks[1], (c, n, heads))
        s_dst = jax.random.normal(ks[2], (c, n, heads))
        struct = (jax.random.uniform(ks[3], (c, n, n)) < 0.3).astype(
            jnp.float32)
        struct = struct + jnp.eye(n)[None]           # self loops
        got = gat_attention(z, s_src, s_dst, struct, n_heads=heads,
                            interpret=True)
        want = ref.gat_attention_ref(z, s_src, s_dst, struct,
                                     n_heads=heads)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **TOL[dtype])

    def test_rows_sum_to_one(self):
        """Attention over each destination's in-neighborhood is a proper
        distribution: aggregating constant features returns the constant."""
        c, n, f = 1, 32, 64
        z = jnp.ones((c, n, f))
        s_src = jnp.zeros((c, n, 1))
        s_dst = jnp.zeros((c, n, 1))
        struct = jnp.ones((c, n, n))
        got = gat_attention(z, s_src, s_dst, struct, n_heads=1,
                            interpret=True)
        np.testing.assert_allclose(np.asarray(got), 1.0, rtol=1e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("b,h,sq,sk,d,bq,bk", [
        (1, 2, 64, 64, 32, 32, 32),
        (2, 1, 128, 128, 64, 64, 32),
        (1, 2, 64, 128, 32, 32, 64),   # cross lengths (non-causal only)
    ])
    def test_matches_softmax_ref(self, b, h, sq, sk, d, bq, bk, causal):
        from repro.kernels.flash_attention import flash_attention
        if causal and sq != sk:
            pytest.skip("causal requires square")
        key = jax.random.PRNGKey(sq + sk)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, h, sq, d))
        k = jax.random.normal(ks[1], (b, h, sk, d))
        v = jax.random.normal(ks[2], (b, h, sk, d))
        got = flash_attention(q, k, v, causal=causal, block_q=bq,
                              block_k=bk, interpret=True)
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / (d ** 0.5)
        if causal:
            mask = jnp.tril(jnp.ones((sq, sk), bool))
            s = jnp.where(mask, s, -1e30)
        want = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1), v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_bf16(self):
        from repro.kernels.flash_attention import flash_attention
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (1, 2, 64, 32)).astype(jnp.bfloat16)
        k = jax.random.normal(ks[1], (1, 2, 64, 32)).astype(jnp.bfloat16)
        v = jax.random.normal(ks[2], (1, 2, 64, 32)).astype(jnp.bfloat16)
        got = flash_attention(q, k, v, block_q=32, block_k=32,
                              interpret=True)
        s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                       k.astype(jnp.float32)) / (32 ** 0.5)
        s = jnp.where(jnp.tril(jnp.ones((64, 64), bool)), s, -1e30)
        want = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1),
                          v.astype(jnp.float32))
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want), rtol=3e-2, atol=3e-2)
