"""Numerical correctness of the model-zoo building blocks against oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MLAConfig, MoEConfig, SSMConfig
from repro.models import mamba as mb
from repro.models.attention import (decode_attention, full_attention,
                                    init_attn)
from repro.models.mla import init_mla, mla_decode, mla_full
from repro.models.moe import (capacity, init_moe, moe_ffn,
                              moe_ffn_dense_oracle)
from repro.models.rope import apply_rope


class TestSSD:
    @pytest.mark.parametrize("chunk", [4, 8, 16])
    @pytest.mark.parametrize("seq", [16, 64])
    def test_chunked_matches_reference(self, chunk, seq):
        key = jax.random.PRNGKey(1)
        ks = jax.random.split(key, 4)
        b, H, P, N = 2, 3, 8, 16
        x = jax.random.normal(ks[0], (b, seq, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, seq, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
        B = jax.random.normal(ks[3], (b, seq, H, N))
        C = jax.random.normal(jax.random.fold_in(key, 9), (b, seq, H, N))
        y_ref = mb.ssd_reference(x, dt, A, B, C)
        y, state = mb.ssd_chunked(x, dt, A, B, C, chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)

    def test_chunked_state_continues(self):
        """Final state of chunked == state reached by step-by-step decode."""
        key = jax.random.PRNGKey(2)
        ks = jax.random.split(key, 5)
        b, seq, H, P, N = 1, 32, 2, 4, 8
        x = jax.random.normal(ks[0], (b, seq, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, seq, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
        B = jax.random.normal(ks[3], (b, seq, H, N))
        C = jax.random.normal(ks[4], (b, seq, H, N))
        _, state_c = mb.ssd_chunked(x, dt, A, B, C, 8)
        st = jnp.zeros((b, H, P, N))
        for t in range(seq):
            st, _ = mb.ssd_step(st, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        np.testing.assert_allclose(np.asarray(state_c), np.asarray(st),
                                   rtol=1e-4, atol=1e-4)

    def test_mamba_decode_matches_full(self):
        """Running the block token-by-token == full-sequence block."""
        cfg = SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=8,
                        chunk_size=8)
        d_model, b, seq = 16, 2, 16
        params = mb.init_mamba(jax.random.PRNGKey(3), d_model, cfg)
        x = jax.random.normal(jax.random.PRNGKey(4), (b, seq, d_model))
        y_full = mb.mamba_block(params, x, d_model, cfg)
        cache = mb.init_mamba_cache(d_model, cfg, b)
        ys = []
        for t in range(seq):
            y_t, cache = mb.mamba_decode(params, x[:, t:t + 1], cache,
                                         d_model, cfg)
            ys.append(y_t)
        y_dec = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                                   rtol=5e-4, atol=5e-4)


class TestMoE:
    def test_capacity_dispatch_matches_dense_oracle(self):
        """With generous capacity nothing drops -> exact match."""
        moe = MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                        capacity_factor=8.0)
        d_model, B, S = 16, 2, 16
        params = init_moe(jax.random.PRNGKey(5), d_model, moe)
        x = jax.random.normal(jax.random.PRNGKey(6), (B, S, d_model))
        y, aux = moe_ffn(params, x, moe)
        y_ref = moe_ffn_dense_oracle(params, x, moe)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5)
        assert np.isfinite(float(aux))

    def test_shared_expert(self):
        moe = MoEConfig(num_experts=4, num_shared=1, top_k=2,
                        d_ff_expert=16, d_ff_shared=32, capacity_factor=8.0)
        params = init_moe(jax.random.PRNGKey(7), 8, moe)
        x = jax.random.normal(jax.random.PRNGKey(8), (1, 8, 8))
        y, _ = moe_ffn(params, x, moe)
        y_ref = moe_ffn_dense_oracle(params, x, moe)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5)

    def test_capacity_drop_is_graceful(self):
        """Tiny capacity: output stays finite, dropped tokens pass through
        residual (here: contribute zero)."""
        moe = MoEConfig(num_experts=2, top_k=1, d_ff_expert=8,
                        capacity_factor=0.25)
        params = init_moe(jax.random.PRNGKey(9), 8, moe)
        x = jax.random.normal(jax.random.PRNGKey(10), (2, 16, 8))
        y, _ = moe_ffn(params, x, moe)
        assert bool(jnp.all(jnp.isfinite(y)))

    def test_capacity_rounding(self):
        moe = MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25,
                        d_ff_expert=8)
        c = capacity(1024, moe)
        assert c % 8 == 0 and c >= 1024 * 2 * 1.25 / 8 - 8


class TestMLA:
    def test_decode_matches_full(self):
        """Absorbed decode at position t == row t of materialized attn."""
        mla = MLAConfig(kv_lora_rank=16, q_lora_rank=12,
                        qk_nope_head_dim=8, qk_rope_head_dim=4,
                        v_head_dim=8)
        d_model, H, B, S = 24, 2, 2, 8
        params = init_mla(jax.random.PRNGKey(11), d_model, H, mla)
        x = jax.random.normal(jax.random.PRNGKey(12), (B, S, d_model))
        y_full, _ = mla_full(params, x, n_heads=H, mla=mla)
        ckv = jnp.zeros((B, S, mla.kv_lora_rank))
        kr = jnp.zeros((B, S, mla.qk_rope_head_dim))
        ys = []
        for t in range(S):
            y_t, ckv, kr = mla_decode(params, x[:, t:t + 1], ckv, kr, t,
                                      n_heads=H, mla=mla)
            ys.append(y_t)
        y_dec = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                                   rtol=1e-4, atol=1e-4)


class TestAttention:
    @pytest.mark.parametrize("n_kv", [1, 2, 4])
    def test_decode_matches_full(self, n_kv):
        d_model, H, Dh, B, S = 16, 4, 8, 2, 8
        params = init_attn(jax.random.PRNGKey(13), d_model, H, n_kv, Dh,
                           qkv_bias=True)
        x = jax.random.normal(jax.random.PRNGKey(14), (B, S, d_model))
        y_full = full_attention(params, x, n_heads=H, n_kv=n_kv, head_dim=Dh,
                                rope_fraction=0.5)
        kc = jnp.zeros((B, S, n_kv, Dh))
        vc = jnp.zeros((B, S, n_kv, Dh))
        ys = []
        for t in range(S):
            y_t, kc, vc = decode_attention(params, x[:, t:t + 1], kc, vc, t,
                                           n_heads=H, n_kv=n_kv, head_dim=Dh,
                                           rope_fraction=0.5)
            ys.append(y_t)
        y_dec = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                                   rtol=1e-4, atol=1e-4)

    def test_rope_preserves_norm_and_relativity(self):
        x = jax.random.normal(jax.random.PRNGKey(15), (1, 6, 2, 8))
        pos = jnp.arange(6)[None]
        y = apply_rope(x, pos)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)
        # relative property: <q_i, k_j> depends only on i-j
        q = jnp.ones((1, 6, 1, 8))
        k = jnp.ones((1, 6, 1, 8))
        qr, kr = apply_rope(q, pos), apply_rope(k, pos)
        s = np.einsum("bihd,bjhd->bij", np.asarray(qr), np.asarray(kr))[0]
        np.testing.assert_allclose(np.diag(s, 1), np.diag(s, 1)[0] *
                                   np.ones(5), rtol=1e-5)

    def test_partial_rope_passthrough(self):
        x = jax.random.normal(jax.random.PRNGKey(16), (1, 4, 1, 8))
        y = apply_rope(x, jnp.arange(4)[None], fraction=0.5)
        np.testing.assert_allclose(np.asarray(y[..., 4:]),
                                   np.asarray(x[..., 4:]))
        assert not np.allclose(np.asarray(y[..., :4]),
                               np.asarray(x[..., :4]))
