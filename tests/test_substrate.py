"""Substrate behaviour: checkpoint atomicity/resume, data pipeline
determinism + straggler skip, gradient compression, xent oracle, fault
injection + restart continuity."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.configs.registry import get_config
from repro.data.pipeline import (PrefetchIterator, TokenPipelineConfig,
                                 synthetic_batch)
from repro.distributed.compression import (compress_with_feedback,
                                           compression_wire_bytes,
                                           dequantize, init_residual,
                                           quantize)
from repro.train.loop import TrainJobConfig, train
from repro.train.xent import softmax_xent


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.bfloat16),
                      "d": jnp.int32(7)}}
        ckpt.save(str(tmp_path), 3, tree, extra={"loss": 1.5})
        got, step, extra = ckpt.restore(str(tmp_path), tree)
        assert step == 3 and extra["loss"] == 1.5
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
            assert a.dtype == b.dtype

    def test_uncommitted_ignored(self, tmp_path):
        tree = {"a": jnp.ones((2,))}
        ckpt.save(str(tmp_path), 1, tree)
        # simulate torn write: committed marker missing
        os.makedirs(tmp_path / "step_00000002")
        assert ckpt.committed_steps(str(tmp_path)) == [1]
        _, step, _ = ckpt.restore(str(tmp_path), tree)
        assert step == 1

    def test_prune_keeps_latest(self, tmp_path):
        tree = {"a": jnp.ones((2,))}
        for s in (1, 2, 3, 4):
            ckpt.save(str(tmp_path), s, tree)
        ckpt.prune(str(tmp_path), keep=2)
        assert ckpt.committed_steps(str(tmp_path)) == [3, 4]


class TestPipeline:
    def test_deterministic(self):
        cfg = TokenPipelineConfig(vocab_size=64, seq_len=16, global_batch=2)
        a = synthetic_batch(cfg, 5)
        b = synthetic_batch(cfg, 5)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = synthetic_batch(cfg, 6)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_prefetch_order(self):
        it = PrefetchIterator(lambda s: s, prefetch=2)
        got = [next(it) for _ in range(5)]
        it.close()
        assert got == [0, 1, 2, 3, 4]

    def test_straggler_skip(self):
        import time
        calls = {"n": 0}

        def slow_produce(step):
            if calls["n"] == 0 and step == 1:
                calls["n"] += 1
                time.sleep(0.8)          # one slow worker batch
            return step

        it = PrefetchIterator(slow_produce, prefetch=1,
                              straggler_timeout_s=0.15)
        got = [next(it) for _ in range(4)]
        it.close()
        assert got == [0, 1, 2, 3]
        assert it.stragglers_skipped >= 1

    def test_labels_are_shifted_tokens(self):
        cfg = TokenPipelineConfig(vocab_size=64, seq_len=16, global_batch=2)
        b = synthetic_batch(cfg, 0)
        np.testing.assert_array_equal(b["labels"][:, :-1],
                                      b["tokens"][:, 1:])


class TestCompression:
    def test_quant_dequant_bounded_error(self):
        x = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                        jnp.float32)
        q, s = quantize(x)
        err = jnp.abs(dequantize(q, s) - x).max()
        assert float(err) <= float(s) / 2 + 1e-6
        assert q.dtype == jnp.int8

    def test_error_feedback_unbiased_over_time(self):
        """With error feedback, the SUM of dequantized grads converges to
        the sum of true grads (residual stays bounded)."""
        rng = np.random.default_rng(1)
        g_true = {"w": jnp.asarray(rng.standard_normal((64,)), jnp.float32)}
        res = init_residual(g_true)
        total_sent = jnp.zeros((64,))
        steps = 50
        for _ in range(steps):
            q, res = compress_with_feedback(g_true, res)
            total_sent = total_sent + dequantize(*q["w"])
        drift = jnp.abs(total_sent / steps - g_true["w"]).max()
        # residual bounded by one quantization step -> drift ~ scale/steps
        assert float(drift) < 0.01

    def test_wire_bytes(self):
        p = {"w": jnp.zeros((1024,))}
        wb = compression_wire_bytes(p)
        assert wb["int8"] * 4 == wb["fp32"]


class TestXent:
    def test_matches_oracle(self):
        rng = np.random.default_rng(0)
        logits = jnp.asarray(rng.standard_normal((2, 5, 17)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, 17, (2, 5)), jnp.int32)
        loss, per_tok = softmax_xent(logits, labels)
        # oracle via jax.nn
        lp = jax.nn.log_softmax(logits, axis=-1)
        want = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
        np.testing.assert_allclose(np.asarray(per_tok), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(loss), float(want.mean()),
                                   rtol=1e-5)

    def test_mask(self):
        logits = jnp.zeros((1, 4, 8))
        labels = jnp.zeros((1, 4), jnp.int32)
        mask = jnp.asarray([[1.0, 1.0, 0.0, 0.0]])
        loss, _ = softmax_xent(logits, labels, mask)
        np.testing.assert_allclose(float(loss), np.log(8), rtol=1e-5)


class TestFaultTolerance:
    def test_failure_injection_and_resume(self, tmp_path):
        """Kill training mid-run; resume must continue the same loss curve
        (deterministic pipeline + checkpointed state)."""
        cfg = get_config("whisper-tiny", reduced=True)
        job = TrainJobConfig(steps=6, ckpt_every=2, seq_len=16,
                             global_batch=2,
                             ckpt_dir=str(tmp_path / "ck"))
        full_params, _, full_hist = train(cfg, TrainJobConfig(
            steps=6, ckpt_every=2, seq_len=16, global_batch=2,
            ckpt_dir=str(tmp_path / "ref")))
        with pytest.raises(RuntimeError, match="injected failure"):
            train(cfg, job, fail_at_step=4)
        assert ckpt.committed_steps(job.ckpt_dir) != []
        params2, _, hist2 = train(cfg, job)          # resume
        assert hist2[0]["step"] == 5
        # resumed losses equal the uninterrupted run's
        ref_tail = {h["step"]: h["loss"] for h in full_hist}
        for h in hist2:
            np.testing.assert_allclose(h["loss"], ref_tail[h["step"]],
                                       rtol=1e-4)
