"""End-to-end system behaviour: serving, GNN training, optimized-variant
equivalences (the SPerf changes must not alter numerics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MLAConfig, MoEConfig
from repro.core.engine import DecoupledEngine
from repro.gnn.model import GNNConfig
from repro.gnn.train import train_gnn
from repro.graphs.synthetic import get_graph
from repro.models.attention import full_attention, init_attn
from repro.models.mla import init_mla, mla_full
from repro.models.moe import init_moe, moe_ffn, moe_ffn_gather
from repro.serve.gnn_server import GNNServer


@pytest.fixture(scope="module")
def graph():
    return get_graph("flickr", scale=0.02, seed=1)


class TestServing:
    def test_server_end_to_end(self, graph):
        cfg = GNNConfig(kind="gcn", n_layers=2, receptive_field=32,
                        f_in=graph.feature_dim)
        eng = DecoupledEngine(graph, cfg, batch_size=8)
        server = GNNServer(eng, max_wait_s=0.01)
        server.start()
        rng = np.random.default_rng(0)
        reqs = [server.submit(int(t))
                for t in rng.integers(0, graph.num_vertices, 24)]
        server.drain(reqs, timeout=120)
        server.stop()
        assert all(r.embedding is not None for r in reqs)
        p = server.stats.percentiles()
        assert p["n"] == 24 and p["p99"] > 0
        # identical target through the server == direct engine call
        direct = eng.infer(np.array([reqs[0].target] * 8),
                           overlap=False).embeddings[0]
        np.testing.assert_allclose(reqs[0].embedding, direct, rtol=1e-5)


class TestGNNTraining:
    def test_loss_decreases(self, graph):
        cfg = GNNConfig(kind="gcn", n_layers=2, receptive_field=32,
                        f_in=graph.feature_dim, num_classes=7)
        out = train_gnn(graph, cfg, steps=30, batch_size=16, lr=3e-3,
                        eval_every=0)
        first = np.mean([h["loss"] for h in out["history"][:5]])
        last = np.mean([h["loss"] for h in out["history"][-5:]])
        assert last < first


class TestOptimizedVariants:
    """SPerf beyond-paper changes are exact rewrites — verify numerics."""

    def test_chunked_attention_matches_naive(self):
        key = jax.random.PRNGKey(0)
        p = init_attn(key, 64, 4, 2, 16)
        x = jax.random.normal(key, (2, 128, 64))
        for causal in (True, False):
            a = full_attention(p, x, n_heads=4, n_kv=2, head_dim=16,
                               causal=causal, chunk_q=0)
            b = full_attention(p, x, n_heads=4, n_kv=2, head_dim=16,
                               causal=causal, chunk_q=32)
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)

    def test_chunked_mla_matches_naive(self):
        key = jax.random.PRNGKey(1)
        mla = MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                        qk_rope_head_dim=8, v_head_dim=16)
        p = init_mla(key, 64, 4, mla)
        x = jax.random.normal(key, (2, 128, 64))
        a, _ = mla_full(p, x, n_heads=4, mla=mla, chunk_q=0)
        b, _ = mla_full(p, x, n_heads=4, mla=mla, chunk_q=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)

    def test_gather_moe_matches_scatter(self):
        key = jax.random.PRNGKey(2)
        moe = MoEConfig(num_experts=8, num_shared=1, top_k=2,
                        d_ff_expert=32, d_ff_shared=32,
                        capacity_factor=4.0)
        p = init_moe(key, 64, moe)
        x = jax.random.normal(key, (2, 16, 64))
        y1, a1 = moe_ffn(p, x, moe)
        y2, a2 = moe_ffn_gather(p, x, moe)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(a1), float(a2), rtol=1e-6)

    def test_gather_moe_grads_match(self):
        """Backward parity matters: the train cell differentiates it."""
        key = jax.random.PRNGKey(3)
        moe = MoEConfig(num_experts=4, top_k=2, d_ff_expert=16,
                        capacity_factor=4.0)
        p = init_moe(key, 32, moe)
        x = jax.random.normal(key, (1, 8, 32))

        def loss(fn, p):
            y, aux = fn(p, x, moe)
            return jnp.sum(y ** 2) + aux

        g1 = jax.grad(lambda p: loss(moe_ffn, p))(p)
        g2 = jax.grad(lambda p: loss(moe_ffn_gather, p))(p)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)
