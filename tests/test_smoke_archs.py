"""Per-arch smoke tests: reduced config, one forward + one train step +
one decode step on CPU; assert output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import ARCHS, get_config
from repro.launch.specs import decode_specs, train_specs
from repro.models.transformer import decode_step, init_params, train_logits
from repro.train.optim import AdamWConfig, init_opt
from repro.train.step import make_train_step

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")
DECODE_SHAPE = ShapeConfig("smoke_dec", seq_len=32, global_batch=2,
                           kind="decode")


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finite(arch, key):
    cfg = get_config(arch, reduced=True)
    params = init_params(cfg, key, max_seq=SMOKE_SHAPE.seq_len)
    batch = train_specs(cfg, SMOKE_SHAPE, mode="concrete")
    logits, extras = jax.jit(
        lambda p, b: train_logits(cfg, p, b, remat=False))(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    if cfg.moe is not None:
        assert bool(jnp.isfinite(extras["aux_loss"]))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_one_train_step_reduces_loss_shape(arch, key):
    cfg = get_config(arch, reduced=True)
    params = init_params(cfg, key, max_seq=SMOKE_SHAPE.seq_len)
    opt_cfg = AdamWConfig(lr=1e-3)
    opt_state = init_opt(params, opt_cfg)
    batch = train_specs(cfg, SMOKE_SHAPE, mode="concrete")
    step = jax.jit(make_train_step(cfg, opt_cfg, remat=True))
    params2, opt_state2, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert metrics["grad_norm"] > 0
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(params2)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))
    assert int(opt_state2.step) == 1


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_step(arch, key):
    cfg = get_config(arch, reduced=True)
    params = init_params(cfg, key, max_seq=DECODE_SHAPE.seq_len)
    d = decode_specs(cfg, DECODE_SHAPE, mode="concrete")
    logits, cache = jax.jit(
        lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))(
        params, d["cache"], d["token"], d["pos"])
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert jax.tree.structure(cache) == jax.tree.structure(d["cache"])
